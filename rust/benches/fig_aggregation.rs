//! FA — aggregation push-down: partial accumulators vs full document
//! ship, live and at DES scale.
//!
//! The tentpole under test is the two-phase aggregation pipeline
//! (`$match`/`$project`/`$group`/`$sort`/`$limit`): shards fold
//! matching records into per-group partial accumulators over the raw
//! encoding (no decode) and reply with one accumulator table; the
//! router merges the partials and finalizes. The live sweep holds the
//! corpus fixed and varies group cardinality, flipping
//! `--agg-partial` between push-down and the full-ship baseline, and
//! checks the reply-size law the push-down exists for: partial reply
//! rows scale with *groups × shards* while full-ship traffic scales
//! with *matched documents* — with both modes bit-identical to the
//! in-process reference executor. The DES table charges the same
//! sweep at paper scale with the calibrated `agg_doc_ns` /
//! `agg_merge_group_ns` terms.
//!
//! Run: `cargo bench --bench fig_aggregation` (add `--quick` for a
//! small sweep). See `docs/EXPERIMENTS.md` §3c for the recorded
//! results.

use std::time::Instant;

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::metrics::{names, Registry};
use hpcstore::mongo::aggregate::AggPipeline;
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::{Filter, SortDir};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::{human_bytes, human_count, human_duration_ns};

const SHARDS: u64 = 2;

fn main() {
    let quick = quick_mode();
    let docs: u64 = if quick { 1_200 } else { 12_000 };
    let reps: u64 = if quick { 3 } else { 8 };
    let group_sweep: &[u64] = if quick { &[4, 32] } else { &[4, 32, 256] };

    let mut report = Report::new(
        "Aggregation push-down — live 2-shard cluster, fixed corpus, group sweep",
    );
    report.set_custom(
        [
            "groups",
            "mode",
            "matched",
            "partial rows",
            "docs shipped",
            "reply bytes",
            "shard decodes",
            "kernel/scalar",
            "agg mean",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &groups in group_sweep {
        // Kernel-shaped pipeline: visible Int group key, count + min/max
        // on one shared f32-exact F64 field — the partial leg routes
        // through the compiled stats kernel; full-ship folds the same
        // algebra centrally on the router.
        let pipeline = AggPipeline::new()
            .matching(Filter::range("ts", 0i64, docs as i64))
            .group_by("node_id")
            .count("n")
            .min("lo", "m0")
            .max("hi", "m0")
            .sort("_id", SortDir::Asc);

        let corpus: Vec<Document> = (0..docs)
            .map(|n| {
                Document::new()
                    .set("ts", n as i64)
                    .set("node_id", (n % groups) as i64)
                    .set("m0", (n % 97) as f64)
            })
            .collect();
        let expected = pipeline.execute_docs(&corpus);

        for partial in [true, false] {
            let metrics = Registry::new();
            let mut cspec = ClusterSpec::small(2, 2);
            cspec.store.agg_partial = partial;
            let cluster = Cluster::start(
                cspec,
                |sid| Ok(Box::new(LocalDir::temp(&format!("figagg-{partial}-{sid}"))?)),
                Kernels::fallback(),
                metrics.clone(),
            )
            .unwrap();
            let client = cluster.client();
            client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
            for chunk in corpus.chunks(1_000) {
                client.insert_many(chunk.to_vec()).unwrap();
            }

            let decodes_before = metrics.counter(names::SHARD_FIND_DECODES).get();
            let mut total_ns = 0u64;
            for _ in 0..reps {
                let t = Instant::now();
                let rows = client.aggregate(pipeline.clone()).unwrap();
                total_ns += t.elapsed().as_nanos() as u64;
                assert_eq!(
                    rows, expected,
                    "groups={groups} partial={partial}: distributed result \
                     diverged from the reference executor"
                );
            }

            let partial_rows = metrics.counter(names::ROUTER_AGG_PARTIAL_ROWS).get();
            let shipped = metrics.counter(names::ROUTER_AGG_DOCS_SHIPPED).get();
            let reply_bytes = metrics.counter(names::ROUTER_AGG_REPLY_BYTES).get();
            let kernel = metrics.counter(names::SHARD_AGG_KERNEL_PATH).get();
            let scalar = metrics.counter(names::SHARD_AGG_SCALAR_PATH).get();
            let folded = metrics.counter(names::SHARD_AGG_DOCS).get();
            let decodes = metrics.counter(names::SHARD_FIND_DECODES).get() - decodes_before;

            // The reply-size law under test: push-down traffic is
            // bounded by group cardinality, full ship by match count.
            // Router-side counters count only version-uniform merges,
            // so they assert exactly; shard-side counters also tick on
            // attempts the router discards for a version mismatch, so
            // they are exact only when no retry happened.
            let retries = metrics.counter(names::ROUTER_AGG_RETRIES).get();
            if retries == 0 {
                assert_eq!(folded, reps * docs, "every aggregate folds every match once");
            } else {
                assert!(folded >= reps * docs);
            }
            if partial {
                assert_eq!(shipped, 0, "push-down must ship no documents");
                assert!(
                    partial_rows <= reps * groups * SHARDS,
                    "partial rows ({partial_rows}) exceed groups x shards"
                );
                assert!(partial_rows > 0);
                assert_eq!(decodes, 0, "the raw-probe fold must decode nothing");
                assert!(kernel > 0, "kernel-shaped pipeline must take the kernel path");
            } else {
                assert_eq!(shipped, reps * docs, "full ship moves every match");
                assert_eq!(partial_rows, 0);
                if retries == 0 {
                    assert_eq!(decodes, reps * docs, "full ship decodes every match");
                } else {
                    assert!(decodes >= reps * docs);
                }
            }

            report.add_row(vec![
                groups.to_string(),
                if partial { "partial".into() } else { "full-ship".to_string() },
                human_count(reps * docs),
                partial_rows.to_string(),
                shipped.to_string(),
                human_bytes(reply_bytes),
                human_count(decodes),
                format!("{kernel}/{scalar}"),
                human_duration_ns(total_ns / reps),
            ]);
            cluster.shutdown();
        }
    }
    report.print();
    println!(
        "\nclaim: with --agg-partial the shard replies carry one accumulator row per \
         live group (rows <= groups x shards, zero documents shipped, zero decodes) \
         while the full-ship baseline moves and decodes every matched document — and \
         both modes return bit-identical results to the reference executor\n"
    );

    // --- DES axis: the same sweep at paper scale. ---------------------
    let cost = CostModel::default().with_network_floor();
    let mut report = Report::new("Aggregation push-down — DES axis (32-node preset)");
    report.set_custom(
        ["groups", "mode", "aggregations", "reply bytes", "query virt ms"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &groups in &[8u32, 64, 512] {
        for partial in [true, false] {
            let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
            spec.monitored_nodes = 256;
            spec.aggregations = 64;
            spec.agg_partial = partial;
            spec.agg_groups = groups;
            let r = ClusterSim::new(spec).run();
            report.add_row(vec![
                groups.to_string(),
                if partial { "partial".into() } else { "full-ship".to_string() },
                r.aggregations.to_string(),
                human_bytes(r.agg_reply_bytes),
                format!("{:.2}", r.query_virt_ns as f64 / 1e6),
            ]);
        }
    }
    report.print();
    println!(
        "\nclaim: at paper scale the push-down reply traffic is flat in match count \
         and linear in group cardinality — the full-ship baseline pays per matched \
         document on both the fabric and the router merge\n"
    );
}
