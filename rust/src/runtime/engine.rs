//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! One [`Engine`] per process; it owns the `PjRtClient` and a cache of
//! compiled executables keyed by artifact name. Loading compiles once;
//! execution is lock-free after that (the `PjRtLoadedExecutable` is
//! internally thread-safe for `execute`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

/// A compiled HLO artifact, ready to execute.
///
/// NOTE: the underlying `PjRtClient` is `Rc`-based, so executables are
/// **not** `Send`. Cross-thread access goes through
/// [`crate::runtime::service::KernelService`], which owns the engine on a
/// dedicated thread.
#[derive(Clone)]
pub struct Executable {
    inner: Rc<xla::PjRtLoadedExecutable>,
    name: String,
}

impl Executable {
    /// Run the computation with the given input literals and return the
    /// elements of the result tuple (artifacts are lowered with
    /// `return_tuple=True`, so the output is always a tuple).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .inner
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact `{}`", self.name))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// PJRT engine: owns the CPU client and the executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Executable>>,
    artifact_dir: PathBuf,
}

impl Engine {
    /// Create an engine backed by the PJRT CPU client, loading artifacts
    /// from `artifact_dir` (usually `artifacts/`).
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: RefCell::new(HashMap::new()),
            artifact_dir: artifact_dir.into(),
        })
    }

    /// Platform name reported by PJRT (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) the artifact `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Executable> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let exe = self.compile_file(name, &path)?;
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile an HLO text file into an executable (no cache).
    pub fn compile_file(&self, name: &str, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        Ok(Executable {
            inner: Rc::new(exe),
            name: name.to_string(),
        })
    }

    /// True if the artifact file exists on disk.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }
}
