//! Quickstart: deploy a small sharded cluster, insert documents, run a
//! conditional find — the 40-line tour of the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::{CmpOp, Filter, FindOptions};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;

fn main() -> anyhow::Result<()> {
    // 3 shards, 2 routers; kernels fall back to scalar routing when
    // `make artifacts` hasn't run.
    let cluster = Cluster::start(
        ClusterSpec::small(3, 2),
        |sid| Ok(Box::new(LocalDir::temp(&format!("quickstart-{sid}"))?)),
        Kernels::load_or_fallback("artifacts"),
        Registry::new(),
    )?;
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).map_err(anyhow::Error::msg)?;
    client.create_index(IndexSpec::single("node_id")).map_err(anyhow::Error::msg)?;

    // Insert one hour of per-minute samples for 20 nodes.
    let docs: Vec<Document> = (0..60i64)
        .flat_map(|t| {
            (0..20i64).map(move |node| {
                Document::new()
                    .set("ts", 1_000_000 + t)
                    .set("node_id", node)
                    .set("cpu_user", (t as f64 / 60.0).sin().abs())
            })
        })
        .collect();
    let rep = client.insert_many(docs).map_err(anyhow::Error::msg)?;
    println!("inserted {} documents", rep.inserted);

    // The paper's query shape: node set + time range.
    let filter = Filter::And(vec![
        Filter::is_in("node_id", vec![Value::Int(3), Value::Int(7)]),
        Filter::cmp("ts", CmpOp::Gte, 1_000_010i64),
        Filter::cmp("ts", CmpOp::Lt, 1_000_020i64),
    ]);
    let results: Vec<Document> = client
        .find(filter, FindOptions::default())
        .map_err(anyhow::Error::msg)?
        .collect();
    println!("conditional find returned {} documents (expected 20)", results.len());

    let stats = cluster.stats();
    println!(
        "cluster: {} docs across {} shards ({} chunks, map v{})",
        stats.docs,
        stats.per_shard_docs.len(),
        stats.chunks,
        stats.map_version
    );
    cluster.shutdown();
    Ok(())
}
