//! Pure-Rust reference implementations of the AOT kernels.
//!
//! Three jobs:
//! 1. **Cross-check**: integration tests assert bit-equality between these
//!    and the HLO path on random batches (`rust/tests/runtime_roundtrip.rs`).
//! 2. **Baseline**: ablation bench A1 compares HLO routing vs this scalar
//!    path.
//! 3. **Fallback**: routers degrade to this when `artifacts/` is absent
//!    (e.g. unit tests that don't want a PJRT dependency).
//!
//! Semantics are specified by `python/compile/kernels/ref.py`.

use crate::util::hash::fnv1a_shard_key;

/// Chunk index for `hash`: count of inclusive-upper-bound boundaries
/// strictly below it (identical to `ref.chunk_of_hash`). `boundaries`
/// is sorted; tail padded with `u32::MAX`.
#[inline]
pub fn chunk_of_hash(hash: u32, boundaries: &[u32]) -> usize {
    // Binary search for the first boundary >= hash — equivalent to
    // counting boundaries < hash, but O(log C) for the scalar path.
    boundaries.partition_point(|&b| b < hash)
}

/// Scalar route: shard assignment + per-shard histogram + hashes.
///
/// Mirrors the `route_batch` artifact: given shard-key columns and the
/// chunk table, returns `(shard_of, counts, hashes)`.
pub fn route_batch(
    node_id: &[u32],
    ts_min: &[u32],
    boundaries: &[u32],
    chunk_to_shard: &[i32],
    num_shards: usize,
) -> (Vec<i32>, Vec<i32>, Vec<u32>) {
    assert_eq!(node_id.len(), ts_min.len());
    let mut shard_of = Vec::with_capacity(node_id.len());
    let mut hashes = Vec::with_capacity(node_id.len());
    let mut counts = vec![0i32; num_shards];
    for (&n, &t) in node_id.iter().zip(ts_min) {
        let h = fnv1a_shard_key(n, t);
        let chunk = chunk_of_hash(h, boundaries);
        let shard = chunk_to_shard[chunk];
        shard_of.push(shard);
        counts[shard as usize] += 1;
        hashes.push(h);
    }
    (shard_of, counts, hashes)
}

/// Scalar filter: `(mask, count)` for the conditional-find predicate.
pub fn filter_batch(
    ts_min: &[u32],
    node_id: &[u32],
    ts_lo: u32,
    ts_hi: u32,
    node_bitmap: &[u32],
) -> (Vec<i32>, i32) {
    assert_eq!(ts_min.len(), node_id.len());
    let mut mask = Vec::with_capacity(ts_min.len());
    let mut count = 0;
    for (&t, &n) in ts_min.iter().zip(node_id) {
        let word = node_bitmap
            .get((n >> 5) as usize)
            .copied()
            .unwrap_or(0);
        let bit = (word >> (n & 31)) & 1;
        let m = (ts_lo <= t && t < ts_hi && bit == 1) as i32;
        count += m;
        mask.push(m);
    }
    (mask, count)
}

/// Scalar stats: per-column `(min, max, mean)` over `[B, M]` row-major
/// metrics.
pub fn stats_batch(metrics: &[f32], b: usize, m: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(metrics.len(), b * m);
    assert!(b > 0, "empty batch");
    let mut mn = vec![f32::INFINITY; m];
    let mut mx = vec![f32::NEG_INFINITY; m];
    let mut sum = vec![0f32; m];
    for row in 0..b {
        for col in 0..m {
            let v = metrics[row * m + col];
            mn[col] = mn[col].min(v);
            mx[col] = mx[col].max(v);
            sum[col] += v;
        }
    }
    let mean = sum.iter().map(|s| s / b as f32).collect();
    (mn, mx, mean)
}

/// Build a node-membership bitmap sized for the `filter` artifact.
pub fn build_bitmap(node_ids: impl IntoIterator<Item = u32>, words: usize) -> Vec<u32> {
    let mut bm = vec![0u32; words];
    for n in node_ids {
        let w = (n >> 5) as usize;
        assert!(w < words, "node id {n} exceeds bitmap capacity");
        bm[w] |= 1 << (n & 31);
    }
    bm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, gens};
    use crate::util::rng::Pcg32;

    fn mk_boundaries(rng: &mut Pcg32, chunks: usize, cap: usize) -> Vec<u32> {
        let mut cuts: Vec<u32> = (0..chunks - 1).map(|_| rng.next_u32()).collect();
        cuts.sort_unstable();
        cuts.dedup();
        cuts.push(u32::MAX);
        cuts.resize(cap, u32::MAX);
        cuts
    }

    #[test]
    fn chunk_of_hash_counts_below() {
        let bounds = [10, 20, 30, u32::MAX];
        assert_eq!(chunk_of_hash(0, &bounds), 0);
        assert_eq!(chunk_of_hash(10, &bounds), 0); // inclusive upper bound
        assert_eq!(chunk_of_hash(11, &bounds), 1);
        assert_eq!(chunk_of_hash(30, &bounds), 2);
        assert_eq!(chunk_of_hash(31, &bounds), 3);
        assert_eq!(chunk_of_hash(u32::MAX, &bounds), 3);
    }

    #[test]
    fn route_counts_sum_to_batch() {
        let mut rng = Pcg32::seeded(3);
        let bounds = mk_boundaries(&mut rng, 15, 64);
        let c2s: Vec<i32> = (0..64).map(|i| (i % 15) as i32).collect();
        let node: Vec<u32> = (0..500).map(|_| rng.next_u32()).collect();
        let ts: Vec<u32> = (0..500).map(|_| rng.next_u32()).collect();
        let (shard_of, counts, hashes) = route_batch(&node, &ts, &bounds, &c2s, 15);
        assert_eq!(shard_of.len(), 500);
        assert_eq!(hashes.len(), 500);
        assert_eq!(counts.iter().sum::<i32>(), 500);
        for (&s, (&n, &t)) in shard_of.iter().zip(node.iter().zip(&ts)) {
            let h = fnv1a_shard_key(n, t);
            assert_eq!(s, c2s[chunk_of_hash(h, &bounds)]);
        }
    }

    #[test]
    fn route_binary_search_equals_linear_count() {
        // The O(log C) partition_point must agree with the O(C) count the
        // kernel uses, including at boundary-equal hashes.
        check(
            "bsearch-eq-count",
            &(|rng: &mut Pcg32| {
                let chunks = 1 + rng.next_bounded(63) as usize;
                let bounds = mk_boundaries(rng, chunks, 64);
                // Bias toward boundary values half the time.
                let h = if rng.next_bounded(2) == 0 {
                    bounds[rng.next_bounded(64) as usize]
                } else {
                    rng.next_u32()
                };
                (h, bounds)
            }),
            |(h, bounds)| {
                let linear = bounds.iter().filter(|&&b| b < *h).count();
                let binary = chunk_of_hash(*h, bounds);
                if linear == binary {
                    Ok(())
                } else {
                    Err(format!("linear={linear} binary={binary}"))
                }
            },
        );
    }

    #[test]
    fn filter_half_open_and_bitmap() {
        let bm = build_bitmap([7u32], 8);
        let ts = [100, 100, 200, 150, 99];
        let node = [7, 8, 7, 7, 7];
        let (mask, count) = filter_batch(&ts, &node, 100, 200, &bm);
        assert_eq!(mask, vec![1, 0, 0, 1, 0]);
        assert_eq!(count, 2);
    }

    #[test]
    fn filter_out_of_bitmap_node_is_excluded() {
        let bm = build_bitmap([1u32], 1); // only 32 ids representable
        let (mask, count) = filter_batch(&[5], &[4000], 0, 10, &bm);
        assert_eq!(mask, vec![0]);
        assert_eq!(count, 0);
    }

    #[test]
    fn stats_basic() {
        // 3 rows × 2 cols.
        let m = [1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0];
        let (mn, mx, mean) = stats_batch(&m, 3, 2);
        assert_eq!(mn, vec![1.0, 10.0]);
        assert_eq!(mx, vec![3.0, 30.0]);
        assert_eq!(mean, vec![2.0, 20.0]);
    }

    #[test]
    fn bitmap_round_trip() {
        let ids = [0u32, 31, 32, 100, 1023];
        let bm = build_bitmap(ids, 32);
        for n in 0..1024u32 {
            let want = ids.contains(&n);
            let got = (bm[(n >> 5) as usize] >> (n & 31)) & 1 == 1;
            assert_eq!(got, want, "node {n}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds bitmap capacity")]
    fn bitmap_rejects_oversized_id() {
        build_bitmap([64u32], 2);
    }

    #[test]
    fn property_route_histogram_consistent() {
        check(
            "route-histogram",
            &gens::vec_of(
                |rng: &mut Pcg32| (rng.next_u32(), rng.next_u32()),
                200,
            ),
            |keys| {
                let mut rng = Pcg32::seeded(7);
                let bounds = mk_boundaries(&mut rng, 7, 32);
                let c2s: Vec<i32> = (0..32).map(|i| (i % 7) as i32).collect();
                let node: Vec<u32> = keys.iter().map(|k| k.0).collect();
                let ts: Vec<u32> = keys.iter().map(|k| k.1).collect();
                let (shard_of, counts, _) = route_batch(&node, &ts, &bounds, &c2s, 7);
                let mut recount = vec![0i32; 7];
                for &s in &shard_of {
                    recount[s as usize] += 1;
                }
                if recount == counts {
                    Ok(())
                } else {
                    Err(format!("{recount:?} != {counts:?}"))
                }
            },
        );
    }
}
