//! Shard-local storage: a WiredTiger-like engine (record store + WAL +
//! checkpoints) with ordered secondary indexes, writing through a
//! pluggable [`io::StorageDir`] so shards can sit on the Lustre
//! simulator (live mode) or a plain local directory (tests).

pub mod engine;
pub mod index;
pub mod io;

pub use engine::{CollectionStats, Engine, RecordId};
pub use index::{encode_key, Index, IndexSpec};
pub use io::{LocalDir, StorageDir, StorageFile};
