//! Rule: **metrics registry** (the run-report surface).
//!
//! The bench harnesses and the paper's tables are assembled from
//! metric names looked up at report time, so a typo'd name at a call
//! site doesn't fail — it silently records into a counter nobody
//! reads. This rule forces every metric name through one declared
//! catalog (`metrics::names` in `rust/src/metrics/registry.rs`):
//!
//! 1. **no bare literals** — `.counter("...")` / `.observe("...")` /
//!    `.gauge("...")` with a string literal in non-test
//!    `rust/src/mongo/**` is flagged; call sites must use
//!    `names::<CONST>`;
//! 2. **no unknown constants** — `names::X` where `X` is not in the
//!    catalog (fixture trees; the compiler catches this in the real
//!    build);
//! 3. **no dead entries** — a catalog constant never referenced from
//!    non-test `rust/src/mongo/**` is flagged at its declaration;
//! 4. **docs stay honest** — the table between
//!    `<!-- metrics-catalog:begin -->` / `<!-- metrics-catalog:end -->`
//!    in `docs/ARCHITECTURE.md` must list exactly the catalog's names
//!    with matching kinds.

use super::lexer::TokKind;
use super::{SourceTree, Violation};

const RULE: &str = "metrics-registry";
const REGISTRY: &str = "rust/src/metrics/registry.rs";
const ARCH: &str = "docs/ARCHITECTURE.md";

pub fn check(tree: &SourceTree) -> Vec<Violation> {
    let Some(reg) = tree.lexed(REGISTRY) else { return Vec::new() };
    let mut out = Vec::new();

    // Catalog: const ident -> (metric name, decl line, kind from CATALOG).
    let t = &reg.tokens;
    let mut consts: Vec<(String, String, usize)> = Vec::new();
    let mut names_body = None;
    for i in 0..t.len() {
        if t[i].text == "mod" && t.get(i + 1).is_some_and(|n| n.text == "names") {
            names_body = Some(i + 2);
            break;
        }
    }
    if let Some(start) = names_body {
        let mut depth = 0i32;
        let mut i = start;
        while i < t.len() {
            match t[i].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            // `pub const X: &str = "role.metric";`
            if t[i].text == "const"
                && t.get(i + 1).is_some_and(|n| n.kind == TokKind::Ident)
                && t.get(i + 2).is_some_and(|c| c.text == ":")
                && t.get(i + 3).is_some_and(|a| a.text == "&")
                && t.get(i + 4).is_some_and(|s| s.text == "str")
                && t.get(i + 5).is_some_and(|e| e.text == "=")
                && t.get(i + 6).is_some_and(|v| v.kind == TokKind::Str)
            {
                consts.push((t[i + 1].text.clone(), t[i + 6].text.clone(), t[i + 1].line));
            }
            i += 1;
        }
    }

    // Kinds from the CATALOG table: `(IDENT, "kind")` pairs.
    let mut kinds: Vec<(String, String)> = Vec::new();
    for i in 0..t.len() {
        if t[i].text == "("
            && t.get(i + 1).is_some_and(|n| n.kind == TokKind::Ident)
            && t.get(i + 2).is_some_and(|c| c.text == ",")
            && t.get(i + 3).is_some_and(|k| k.kind == TokKind::Str)
            && t.get(i + 4).is_some_and(|c| c.text == ")")
            && consts.iter().any(|(name, _, _)| *name == t[i + 1].text)
        {
            kinds.push((t[i + 1].text.clone(), t[i + 3].text.clone()));
        }
    }
    for (name, _, line) in &consts {
        if !kinds.iter().any(|(n, _)| n == name) {
            out.push(Violation {
                file: REGISTRY.to_string(),
                line: *line,
                rule: RULE,
                message: format!("metric constant {name} is missing from the CATALOG kind table"),
            });
        }
    }

    // Call sites and references across non-test mongo code.
    let mut referenced: Vec<String> = Vec::new();
    for path in tree.paths_under("rust/src/mongo/", ".rs") {
        let f = tree.lexed(path).expect("listed path is present");
        let ft = &f.tokens;
        for i in 0..ft.len() {
            if f.is_test_line(ft[i].line) {
                continue;
            }
            let is_record_call = ft[i].text == "."
                && ft.get(i + 1).is_some_and(|m| {
                    matches!(m.text.as_str(), "counter" | "observe" | "gauge")
                })
                && ft.get(i + 2).is_some_and(|p| p.text == "(");
            if is_record_call {
                if let Some(arg) = ft.get(i + 3) {
                    if arg.kind == TokKind::Str {
                        out.push(Violation {
                            file: path.to_string(),
                            line: arg.line,
                            rule: RULE,
                            message: format!(
                                "bare metric-name literal \"{}\" — use a metrics::names constant so the catalog stays authoritative",
                                arg.text
                            ),
                        });
                    }
                }
            }
            if ft[i].text == "names"
                && ft.get(i + 1).is_some_and(|c| c.text == "::")
                && ft.get(i + 2).is_some_and(|n| n.kind == TokKind::Ident)
            {
                let ident = &ft[i + 2].text;
                if consts.iter().any(|(n, _, _)| n == ident) {
                    referenced.push(ident.clone());
                } else {
                    out.push(Violation {
                        file: path.to_string(),
                        line: ft[i + 2].line,
                        rule: RULE,
                        message: format!("names::{ident} is not declared in the metrics catalog"),
                    });
                }
            }
        }
    }
    for (name, value, line) in &consts {
        if !referenced.iter().any(|r| r == name) {
            out.push(Violation {
                file: REGISTRY.to_string(),
                line: *line,
                rule: RULE,
                message: format!(
                    "metric {name} (\"{value}\") is registered but never emitted from rust/src/mongo"
                ),
            });
        }
    }

    check_docs(tree, &consts, &kinds, &mut out);
    out
}

/// Cross-check the marker-delimited table in docs/ARCHITECTURE.md.
fn check_docs(
    tree: &SourceTree,
    consts: &[(String, String, usize)],
    kinds: &[(String, String)],
    out: &mut Vec<Violation>,
) {
    let Some(md) = tree.content(ARCH) else {
        out.push(Violation {
            file: ARCH.to_string(),
            line: 0,
            rule: RULE,
            message: "docs/ARCHITECTURE.md is missing — the metrics catalog table cannot be cross-checked".to_string(),
        });
        return;
    };
    let mut in_table = false;
    let mut saw_markers = false;
    let mut doc_rows: Vec<(String, String, usize)> = Vec::new(); // (name, kind, line)
    for (idx, line) in md.lines().enumerate() {
        let lineno = idx + 1;
        if line.contains("metrics-catalog:begin") {
            in_table = true;
            saw_markers = true;
            continue;
        }
        if line.contains("metrics-catalog:end") {
            in_table = false;
            continue;
        }
        if !in_table || !line.trim_start().starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.trim().trim_matches('|').split('|').collect();
        if cells.len() < 2 {
            continue;
        }
        let name_cell = cells[0].trim();
        if !name_cell.starts_with('`') {
            continue; // header or separator row
        }
        let name = name_cell.trim_matches('`').to_string();
        doc_rows.push((name, cells[1].trim().to_string(), lineno));
    }
    if !saw_markers {
        out.push(Violation {
            file: ARCH.to_string(),
            line: 0,
            rule: RULE,
            message: "no <!-- metrics-catalog:begin/end --> markers in docs/ARCHITECTURE.md — the metrics table is unchecked".to_string(),
        });
        return;
    }
    for (name, kind, lineno) in &doc_rows {
        match consts.iter().find(|(_, v, _)| v == name) {
            None => out.push(Violation {
                file: ARCH.to_string(),
                line: *lineno,
                rule: RULE,
                message: format!("docs list metric \"{name}\" which is not in the catalog"),
            }),
            Some((cname, _, _)) => {
                if let Some((_, ckind)) = kinds.iter().find(|(n, _)| n == cname) {
                    if ckind != kind {
                        out.push(Violation {
                            file: ARCH.to_string(),
                            line: *lineno,
                            rule: RULE,
                            message: format!(
                                "docs call \"{name}\" a {kind}; the catalog says {ckind}"
                            ),
                        });
                    }
                }
            }
        }
    }
    for (_, value, line) in consts {
        if !doc_rows.iter().any(|(n, _, _)| n == value) {
            out.push(Violation {
                file: REGISTRY.to_string(),
                line: *line,
                rule: RULE,
                message: format!("metric \"{value}\" is missing from the docs/ARCHITECTURE.md catalog table"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const REG: &str = "pub mod names {\n    pub const SHARD_FIND_NS: &str = \"shard.find_ns\";\n    pub const SHARD_SPLITS: &str = \"shard.splits\";\n    pub const CATALOG: &[(&str, &str)] = &[\n        (SHARD_FIND_NS, \"histogram\"),\n        (SHARD_SPLITS, \"counter\"),\n    ];\n}\n";
    const DOCS: &str = "<!-- metrics-catalog:begin -->\n| name | kind | description |\n| --- | --- | --- |\n| `shard.find_ns` | histogram | find latency |\n| `shard.splits` | counter | splits |\n<!-- metrics-catalog:end -->\n";

    fn tree(shard: &str, docs: &str) -> SourceTree {
        let mut t = SourceTree::new();
        t.add("rust/src/metrics/registry.rs", REG);
        t.add("rust/src/mongo/server/shard.rs", shard);
        t.add("docs/ARCHITECTURE.md", docs);
        t
    }

    #[test]
    fn catalogued_call_sites_pass() {
        let t = tree(
            "fn f(&self) { self.metrics.observe(names::SHARD_FIND_NS, 1); self.metrics.counter(names::SHARD_SPLITS).inc(); }",
            DOCS,
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn bare_literal_is_flagged_with_line() {
        let t = tree(
            "fn f(&self) { self.metrics.observe(names::SHARD_FIND_NS, 1); self.metrics.counter(names::SHARD_SPLITS).inc(); }\nfn g(&self) { self.metrics.counter(\"shard.splits\").inc(); }",
            DOCS,
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("bare metric-name literal"));
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn typo_constant_and_dead_entry_are_flagged() {
        let t = tree(
            "fn f(&self) { self.metrics.observe(names::SHARD_FIND_MS, 1); }",
            DOCS,
        );
        let v = check(&t);
        assert!(v.iter().any(|x| x.message.contains("SHARD_FIND_MS")), "{v:?}");
        // Both catalog entries are now unreferenced.
        assert!(v.iter().any(|x| x.message.contains("never emitted")), "{v:?}");
    }

    #[test]
    fn docs_drift_is_flagged() {
        let t = tree(
            "fn f(&self) { self.metrics.observe(names::SHARD_FIND_NS, 1); self.metrics.counter(names::SHARD_SPLITS).inc(); }",
            "<!-- metrics-catalog:begin -->\n| `shard.find_ns` | counter | wrong kind |\n| `shard.ghost` | counter | no such metric |\n<!-- metrics-catalog:end -->\n",
        );
        let v = check(&t);
        assert!(v.iter().any(|x| x.message.contains("the catalog says histogram")), "{v:?}");
        assert!(v.iter().any(|x| x.message.contains("shard.ghost")), "{v:?}");
        assert!(
            v.iter().any(|x| x.message.contains("shard.splits")
                && x.message.contains("missing from the docs")),
            "{v:?}"
        );
    }

    #[test]
    fn test_module_literals_are_ignored() {
        let t = tree(
            "fn f(&self) { self.metrics.observe(names::SHARD_FIND_NS, 1); self.metrics.counter(names::SHARD_SPLITS).inc(); }\n#[cfg(test)]\nmod tests {\n    fn t(m: &Registry) { m.counter(\"shard.splits\").inc(); }\n}\n",
            DOCS,
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }
}
