"""shard_route Pallas kernel vs pure-jnp oracle — bit-exact."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.shard_route import shard_route
from compile import model

RNG = np.random.default_rng(0xB1DE)


def make_boundaries(num_chunks, cap, rng=RNG):
    """Random sorted inclusive-upper-bound boundary vector, padded to cap."""
    cuts = np.sort(rng.choice(2**32 - 1, size=num_chunks - 1, replace=False))
    bounds = np.concatenate([cuts, [2**32 - 1]]).astype(np.uint32)
    pad = np.full(cap - num_chunks, 2**32 - 1, dtype=np.uint32)
    return np.concatenate([bounds, pad])


def make_c2s(num_chunks, num_shards, cap, rng=RNG):
    c2s = rng.integers(0, num_shards, size=num_chunks, dtype=np.int32)
    pad = np.full(cap - num_chunks, c2s[-1], dtype=np.int32)
    return np.concatenate([c2s, pad])


def run_both(node, ts, bounds, c2s, block_b, variant="searchsorted"):
    shard_k, hash_k = shard_route(
        jnp.asarray(node), jnp.asarray(ts), jnp.asarray(bounds), jnp.asarray(c2s),
        block_b=block_b, variant=variant,
    )
    shard_r, _, hash_r = ref.route_ref(
        jnp.asarray(node), jnp.asarray(ts), jnp.asarray(bounds), jnp.asarray(c2s),
        num_shards=model.ROUTE_S,
    )
    return (
        np.asarray(shard_k), np.asarray(hash_k),
        np.asarray(shard_r), np.asarray(hash_r),
    )


def test_fnv1a_known_vectors():
    """Pin the hash spec with hand-computed FNV-1a values.

    fnv1a(bytes) over the 8 LE bytes of (node_id, ts). Computed with the
    reference scalar implementation below — these exact constants are
    also asserted by rust/src/runtime/fallback.rs unit tests.
    """

    def scalar_fnv(node, ts):
        h = 2166136261
        for w in (node, ts):
            for s in (0, 8, 16, 24):
                h = ((h ^ ((w >> s) & 0xFF)) * 16777619) % 2**32
        return h

    cases = [(0, 0), (1, 0), (0, 1), (12345, 67890), (2**32 - 1, 2**32 - 1)]
    node = np.array([c[0] for c in cases], dtype=np.uint32)
    ts = np.array([c[1] for c in cases], dtype=np.uint32)
    got = np.asarray(ref.fnv1a_u32_pair(jnp.asarray(node), jnp.asarray(ts)))
    want = np.array([scalar_fnv(*c) for c in cases], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("variant", ["searchsorted", "compare_count"])
def test_kernel_matches_ref_default_shapes(variant):
    b, c = model.ROUTE_B, model.ROUTE_C
    node = RNG.integers(0, 28000, size=b, dtype=np.uint32)
    ts = RNG.integers(0, 2**22, size=b, dtype=np.uint32)
    bounds = make_boundaries(63, c)
    c2s = make_c2s(63, 63, c)
    sk, hk, sr, hr = run_both(node, ts, bounds, c2s, block_b=1024, variant=variant)
    np.testing.assert_array_equal(hk, hr)
    np.testing.assert_array_equal(sk, sr)


def test_variants_bit_identical():
    b, c = 512, 128
    node = RNG.integers(0, 2**32, size=b, dtype=np.uint32)
    ts = RNG.integers(0, 2**32, size=b, dtype=np.uint32)
    bounds = make_boundaries(31, c)
    c2s = make_c2s(31, 31, c)
    a = run_both(node, ts, bounds, c2s, block_b=256, variant="searchsorted")
    d = run_both(node, ts, bounds, c2s, block_b=256, variant="compare_count")
    np.testing.assert_array_equal(a[0], d[0])
    np.testing.assert_array_equal(a[1], d[1])


def test_single_chunk_routes_everything_to_one_shard():
    b, c = 256, model.ROUTE_C
    node = RNG.integers(0, 2**32, size=b, dtype=np.uint32)
    ts = RNG.integers(0, 2**32, size=b, dtype=np.uint32)
    bounds = make_boundaries(1, c)
    c2s = np.full(c, 5, dtype=np.int32)
    sk, _, sr, _ = run_both(node, ts, bounds, c2s, block_b=256)
    assert (sk == 5).all()
    np.testing.assert_array_equal(sk, sr)


def test_hash_extremes_hit_first_and_last_chunk():
    """Keys hashing to 0x0 / 0xFFFFFFFF stay inside [0, num_chunks)."""
    c = model.ROUTE_C
    num_chunks = 7
    bounds = make_boundaries(num_chunks, c)
    hashes = jnp.asarray(
        np.array([0, 1, 2**31, 2**32 - 2, 2**32 - 1], dtype=np.uint32)
    )
    chunk = np.asarray(ref.chunk_of_hash(hashes, jnp.asarray(bounds)))
    assert chunk.min() >= 0
    assert chunk.max() < num_chunks
    assert chunk[0] == 0
    assert chunk[-1] == num_chunks - 1


def test_boundary_inclusivity():
    """A hash exactly equal to boundary[j] belongs to chunk j (inclusive)."""
    c = model.ROUTE_C
    bounds = make_boundaries(4, c)
    h = jnp.asarray(bounds[:4])  # the four real boundaries
    chunk = np.asarray(ref.chunk_of_hash(h, jnp.asarray(bounds)))
    np.testing.assert_array_equal(chunk[:3], np.arange(3))


def test_histogram_counts_match_assignments():
    b, c = model.ROUTE_B, model.ROUTE_C
    node = RNG.integers(0, 28000, size=b, dtype=np.uint32)
    ts = RNG.integers(0, 2**22, size=b, dtype=np.uint32)
    bounds = make_boundaries(15, c)
    c2s = make_c2s(15, 15, c)
    shard_of, counts, _ = model.route_batch(
        jnp.asarray(node), jnp.asarray(ts), jnp.asarray(bounds), jnp.asarray(c2s)
    )
    shard_of, counts = np.asarray(shard_of), np.asarray(counts)
    want = np.bincount(shard_of, minlength=model.ROUTE_S)
    np.testing.assert_array_equal(counts, want)
    assert counts.sum() == b


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    log_b=st.integers(min_value=0, max_value=3),
    num_chunks=st.integers(min_value=1, max_value=64),
    block_pow=st.integers(min_value=0, max_value=2),
    variant=st.sampled_from(["searchsorted", "compare_count"]),
)
def test_property_kernel_equals_ref(data, log_b, num_chunks, block_pow, variant):
    """Hypothesis sweep over batch sizes, block sizes, chunk counts."""
    b = 64 * (2**log_b)
    block_b = min(b, 64 * (2**block_pow))
    c = 128
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    node = rng.integers(0, 2**32, size=b, dtype=np.uint32)
    ts = rng.integers(0, 2**32, size=b, dtype=np.uint32)
    bounds = make_boundaries(num_chunks, c, rng)
    c2s = make_c2s(num_chunks, 64, c, rng)
    sk, hk, sr, hr = run_both(node, ts, bounds, c2s, block_b=block_b, variant=variant)
    np.testing.assert_array_equal(hk, hr)
    np.testing.assert_array_equal(sk, sr)


def test_rejects_indivisible_block():
    node = jnp.zeros(100, jnp.uint32)
    bounds = jnp.full(8, 2**32 - 1, jnp.uint32)
    c2s = jnp.zeros(8, jnp.int32)
    with pytest.raises(ValueError, match="not divisible"):
        shard_route(node, node, bounds, c2s, block_b=64)
