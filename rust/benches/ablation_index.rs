//! A2 — index ablation: the paper's conditional find with both indexes
//! (ts, node_id → index intersection), a single index, a compound
//! index, and no index at all (full collection scan).

use hpcstore::benchkit::{Bench, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::queries::job_filter;
use hpcstore::workload::IngestDriver;

fn main() {
    let wl = WorkloadConfig {
        monitored_nodes: 128,
        metrics_per_doc: 20,
        days: 20.0 / 1440.0,
        query_jobs: 8,
        ..Default::default()
    };
    let jobs = generate_jobs(&wl);
    let bench = Bench::quick();
    let mut report = Report::new(&format!(
        "A2 — find plans vs indexes ({} docs, paper-shape conditional finds)",
        wl.total_docs()
    ));

    let cases: Vec<(&str, Vec<IndexSpec>)> = vec![
        ("no index (full scan)", vec![]),
        ("ts only", vec![IndexSpec::single("ts")]),
        ("node_id only", vec![IndexSpec::single("node_id")]),
        (
            "ts + node_id (intersection)",
            vec![IndexSpec::single("ts"), IndexSpec::single("node_id")],
        ),
        ("compound (node_id, ts)", vec![IndexSpec::compound(&["node_id", "ts"])]),
    ];
    for (label, specs) in cases {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 1),
            move |sid| Ok(Box::new(LocalDir::temp(&format!("a2-{sid}-{}", specs_key(label)))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        for spec in &specs {
            client.create_index(spec.clone()).unwrap();
        }
        IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 2)
            .run(&client)
            .unwrap();
        let mut i = 0usize;
        report.push(bench.run(label, 1.0, || {
            let job = &jobs[i % jobs.len()];
            i += 1;
            let n = client.count_documents(job_filter(job)).unwrap();
            assert_eq!(n as u64, job.expected_docs());
        }));
        cluster.shutdown();
    }
    report.print();
}

fn specs_key(label: &str) -> String {
    label.chars().filter(char::is_ascii_alphanumeric).collect()
}
