//! Balancer demo: ranged shard keys + time-ordered ingest create the
//! classic hot-chunk pathology (all writes land on the last chunk's
//! shard); the balancer migrates chunks until the cluster evens out.
//! Hashed keys (the default) avoid the skew entirely — this is ablation
//! A5 in example form.
//!
//! ```sh
//! cargo run --release --example chunk_rebalance
//! ```

use hpcstore::config::{ShardKeyKind, StoreConfig};
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::util::fmt::markdown_table;

fn run(kind: ShardKeyKind, balance: bool) -> anyhow::Result<Vec<u64>> {
    let mut spec = ClusterSpec::small(4, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: kind,
        max_chunk_docs: 400,
        balancer: balance,
        ..Default::default()
    };
    let label = format!("rebal-{}-{balance}", kind.name());
    let cluster = Cluster::start(
        spec,
        move |sid| Ok(Box::new(LocalDir::temp(&format!("{label}-{sid}"))?)),
        Kernels::fallback(),
        Registry::new(),
    )?;
    let client = cluster.client();
    // Time-ordered ingest: ts strictly increasing (the worst case for
    // ranged keys).
    for wave in 0..20i64 {
        let docs: Vec<Document> = (0..400i64)
            .map(|i| {
                Document::new()
                    .set("ts", wave * 400 + i)
                    .set("node_id", i % 16)
                    .set("m00", i as f64)
            })
            .collect();
        client.insert_many(docs).map_err(anyhow::Error::msg)?;
        if balance {
            cluster.run_balancer_round()?;
        }
    }
    let stats = cluster.stats();
    println!(
        "{:>6} key, balancer {:>3}: per-shard docs {:?}, {} migrations, {} chunks",
        kind.name(),
        if balance { "on" } else { "off" },
        stats.per_shard_docs,
        stats.migrations,
        stats.chunks,
    );
    let docs = stats.per_shard_docs.clone();
    cluster.shutdown();
    Ok(docs)
}

fn main() -> anyhow::Result<()> {
    println!("ingesting 8000 time-ordered docs into 4 shards...\n");
    let hashed = run(ShardKeyKind::Hashed, false)?;
    let ranged_off = run(ShardKeyKind::Ranged, false)?;
    let ranged_on = run(ShardKeyKind::Ranged, true)?;

    let spread = |v: &Vec<u64>| {
        let max = *v.iter().max().unwrap() as f64;
        let min = *v.iter().min().unwrap() as f64;
        format!("{:.1}", max / min.max(1.0))
    };
    let rows = vec![
        vec!["hashed".into(), "off".into(), format!("{hashed:?}"), spread(&hashed)],
        vec!["ranged".into(), "off".into(), format!("{ranged_off:?}"), spread(&ranged_off)],
        vec!["ranged".into(), "on".into(), format!("{ranged_on:?}"), spread(&ranged_on)],
    ];
    println!("\n## Shard-key / balancer ablation (A5)\n");
    print!(
        "{}",
        markdown_table(&["shard key", "balancer", "per-shard docs", "max/min"], &rows)
    );
    println!("\nhashed keys spread writes natively; ranged keys need the balancer.");
    Ok(())
}
