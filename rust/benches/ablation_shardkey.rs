//! A5 — hashed vs ranged shard keys under time-ordered ingest (live).
//!
//! Ranged keys send every monotonically-increasing timestamp to the
//! "top" chunk — one hot shard — while hashed keys (the route kernel's
//! ring) spread uniformly. With the balancer on, ranged recovers some
//! throughput at the cost of migrations.

use hpcstore::benchkit::Report;
use hpcstore::config::{ShardKeyKind, StoreConfig, WorkloadConfig};
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::IngestDriver;

fn run(kind: ShardKeyKind, balancer: bool, kernels: &Kernels) -> (f64, u64, Vec<u64>) {
    let mut spec = ClusterSpec::small(4, 2);
    spec.store = StoreConfig {
        shard_key: kind,
        max_chunk_docs: 2_000,
        balancer,
        ..Default::default()
    };
    let label = format!("a5-{}-{balancer}", kind.name());
    let cluster = Cluster::start(
        spec,
        move |sid| Ok(Box::new(LocalDir::temp(&format!("{label}-{sid}"))?)),
        kernels.clone(),
        Registry::new(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    let gen = OvisGenerator::new(WorkloadConfig {
        monitored_nodes: 64,
        metrics_per_doc: 30,
        days: 16.0 / 1440.0,
        ..Default::default()
    });
    // Interleave balancer rounds like the deployed heartbeat.
    let driver = IngestDriver::new(gen, 500, 4);
    let rep = driver.run(&client).unwrap();
    if balancer {
        for _ in 0..4 {
            cluster.run_balancer_round().unwrap();
        }
    }
    let stats = cluster.stats();
    let out = (rep.docs_per_sec, stats.migrations, stats.per_shard_docs.clone());
    cluster.shutdown();
    out
}

fn main() {
    let kernels = Kernels::load_or_fallback("artifacts");
    let mut report = Report::new("A5 — shard key kind under time-ordered ingest (live, 4 shards)");
    report.set_custom(
        ["key", "balancer", "docs/s", "migrations", "per-shard docs", "max/min"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for (kind, bal) in [
        (ShardKeyKind::Hashed, false),
        (ShardKeyKind::Ranged, false),
        (ShardKeyKind::Ranged, true),
    ] {
        let (dps, migrations, per_shard) = run(kind, bal, &kernels);
        let max = *per_shard.iter().max().unwrap() as f64;
        let min = *per_shard.iter().min().unwrap() as f64;
        report.add_row(vec![
            kind.name().to_string(),
            if bal { "on" } else { "off" }.to_string(),
            format!("{dps:.0}"),
            migrations.to_string(),
            format!("{per_shard:?}"),
            format!("{:.1}", max / min.max(1.0)),
        ]);
    }
    report.print();
}
