//! Crash matrix for the storage lifecycle (checkpoint compaction +
//! journal rotation): recovery must be byte-exact no matter where in
//! the checkpoint protocol a batch job is killed.
//!
//! The protocol has three windows a kill can land in:
//!   1. during the checkpoint *write* — `store.ckpt.tmp` is partial,
//!      the rename never ran, the old checkpoint is authoritative;
//!   2. between the write and the *swap* — `store.ckpt.tmp` is complete
//!      but unrenamed, same outcome as (1);
//!   3. after the swap, during the *truncation* — covered journal
//!      segments survive on disk and replay must skip (and delete)
//!      them, or documents would be applied twice.
//!
//! Plus the headline property: under sustained ingest writing several
//! times the compaction threshold, the on-disk journal stays bounded
//! and post-crash recovery replays only the post-checkpoint tail.

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use hpcstore::config::{ShardKeyKind, StoreConfig, WriteConcern};
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::Filter;
use hpcstore::mongo::server::replica::{OPLOG, RAFT_STATE};
use hpcstore::mongo::server::shard::COLLECTION;
use hpcstore::mongo::server::{ReplicaConfig, ShardServer};
use hpcstore::mongo::sharding::{ChunkMap, ShardKey};
use hpcstore::mongo::storage::{Engine, EngineOptions, LocalDir, StorageDir};
use hpcstore::mongo::wire::{rpc, ConfigRequest, ShardRequest};
use hpcstore::runtime::Kernels;
use hpcstore::util::ids::ShardId;
use hpcstore::util::rng::Pcg32;

fn doc(i: u64) -> Document {
    Document::new()
        .set("ts", i as i64)
        .set("node_id", (i % 16) as i64)
        .set("m0", i as f64 * 0.5)
        .set("m1", (i * 31) as f64)
}

fn batch(lo: u64, n: u64) -> Vec<Document> {
    (lo..lo + n).map(doc).collect()
}

fn lifecycle(checkpoint_bytes: u64) -> EngineOptions {
    EngineOptions {
        journal: true,
        compress_checkpoints: true,
        checkpoint_bytes,
        journal_segments: 4,
        full_checkpoint_chain: 4,
        ..EngineOptions::default()
    }
}

/// Manual-checkpoint options with an explicit rebase threshold (delta
/// lifecycle under test control).
fn manual(full_checkpoint_chain: u32) -> EngineOptions {
    EngineOptions {
        journal: true,
        compress_checkpoints: false,
        checkpoint_bytes: 0,
        journal_segments: 4,
        full_checkpoint_chain,
        ..EngineOptions::default()
    }
}

/// Sum of on-disk `journal-*.wal` sizes under `root`.
fn journal_files_bytes(root: &str) -> u64 {
    std::fs::read_dir(root)
        .unwrap()
        .filter_map(|e| {
            let e = e.unwrap();
            let name = e.file_name().to_string_lossy().into_owned();
            (name.starts_with("journal-") && name.ends_with(".wal"))
                .then(|| e.metadata().unwrap().len())
        })
        .sum()
}

// lint: journal-op(OP_INSERT_MANY) — every batch below is one multi-record
// journal frame whose replay is differentially checked after each kill.
#[test]
fn sustained_ingest_bounds_disk_and_replays_only_the_tail() {
    let threshold: u64 = 64 * 1024;
    let opts = lifecycle(threshold);
    let seg = opts.segment_bytes();
    let dir = LocalDir::temp("cm-bound").unwrap();
    let root = dir.describe();
    let mut total = 0u64;
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        // Write well past 4x the compaction threshold, the shard-server
        // pattern: group commit, then the background compaction hook.
        let mut written = 0u64;
        while written < 4 * threshold {
            let docs = batch(total, 64);
            total += 64;
            eng.insert_many("metrics", &docs).unwrap();
            let frame = eng.pending_journal_bytes() as u64;
            eng.sync().unwrap();
            written += frame;
            eng.maybe_checkpoint().unwrap();
            // Bounded steady state: at most one threshold plus one
            // segment of journal on disk, in memory and in real files.
            assert!(
                eng.journal_disk_bytes() <= threshold + seg,
                "engine journal {} exceeds bound",
                eng.journal_disk_bytes()
            );
            assert!(
                journal_files_bytes(&root) <= threshold + seg,
                "on-disk journal {} exceeds bound",
                journal_files_bytes(&root)
            );
        }
        assert!(eng.generation() >= 3, "expected repeated compaction");
        // Drop without checkpoint = kill.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total, "recovery must be exact");
    let rep = eng.recovery_report();
    assert!(rep.checkpoint_generation >= 3);
    assert!(
        rep.bytes_replayed <= threshold + seg,
        "replayed {} bytes — recovery must be tail-only, not O(total writes)",
        rep.bytes_replayed
    );
}

#[test]
fn kill_during_checkpoint_write_keeps_old_checkpoint_authoritative() {
    let dir = LocalDir::temp("cm-write").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // generation 1, the survivor
        eng.insert_many("metrics", &batch(20, 10)).unwrap();
        eng.sync().unwrap();
        // Killed mid-way through writing the generation-2 checkpoint:
        // a partial staging file is on disk, the rename never happened.
    }
    std::fs::write(
        Path::new(&root).join("store.ckpt.tmp"),
        b"HPCCKPT2\x02partial garbage from a dying writer",
    )
    .unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert!(
        !Path::new(&root).join("store.ckpt.tmp").exists(),
        "recovery must discard the partial staging file"
    );
}

#[test]
fn kill_between_checkpoint_write_and_swap_keeps_old_checkpoint() {
    let dir = LocalDir::temp("cm-swap").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 15)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(15, 5)).unwrap();
        eng.sync().unwrap();
    }
    // A *complete* staging file that was never renamed: even a fully
    // valid unrenamed checkpoint must be ignored — only the rename
    // publishes it.
    let published = std::fs::read(Path::new(&root).join("store.ckpt")).unwrap();
    std::fs::write(Path::new(&root).join("store.ckpt.tmp"), &published).unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 20);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert!(!Path::new(&root).join("store.ckpt.tmp").exists());
}

#[test]
fn kill_during_truncate_skips_and_deletes_covered_segments() {
    let dir = LocalDir::temp("cm-trunc").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 25)).unwrap();
        eng.sync().unwrap();
        // Keep a copy of the covered segment, checkpoint (which
        // truncates it), then put it back — exactly the disk state a
        // kill between the swap and the end of truncation leaves.
        let seg1 = std::fs::read(Path::new(&root).join("journal-000001.wal")).unwrap();
        let ck = eng.checkpoint().unwrap();
        assert!(ck.segments_truncated >= 1);
        assert!(!Path::new(&root).join("journal-000001.wal").exists());
        std::fs::write(Path::new(&root).join("journal-000001.wal"), &seg1).unwrap();
        eng.insert_many("metrics", &batch(25, 5)).unwrap();
        eng.sync().unwrap();
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    // Replaying the covered segment would double-apply its 25 documents.
    assert_eq!(eng.stats("metrics").docs, 30, "covered segment must not replay");
    let rep = eng.recovery_report();
    assert_eq!(rep.segments_skipped, 1);
    assert!(
        !Path::new(&root).join("journal-000001.wal").exists(),
        "recovery must finish the interrupted truncation"
    );
}

#[test]
fn recovery_replays_only_post_checkpoint_segments() {
    // Regression for the watermark logic: frames before the checkpoint
    // never replay, frames after it always do.
    let dir = LocalDir::temp("cm-tail").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        for b in 0..5 {
            eng.insert_many("metrics", &batch(b * 8, 8)).unwrap();
            eng.sync().unwrap();
        }
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(40, 7)).unwrap();
        eng.sync().unwrap();
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 47);
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 1);
    assert_eq!(rep.segments_replayed, 1, "only the tail segment");
    assert_eq!(rep.frames_replayed, 1, "only the post-checkpoint frame");
}

#[test]
fn legacy_single_file_journal_migrates_into_the_lifecycle() {
    let dir = LocalDir::temp("cm-legacy").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 12)).unwrap();
        eng.sync().unwrap();
    }
    // Rewrite the segment as the pre-rotation single-file layout.
    std::fs::rename(
        Path::new(&root).join("journal-000001.wal"),
        Path::new(&root).join("journal.wal"),
    )
    .unwrap();
    {
        let mut eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 12, "legacy journal must replay");
        let ck = eng.checkpoint().unwrap();
        assert!(ck.segments_truncated >= 1);
        assert!(
            !Path::new(&root).join("journal.wal").exists(),
            "checkpoint covers and removes the legacy journal"
        );
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 12);
    assert_eq!(eng.recovery_report().frames_replayed, 0);
}

#[test]
fn kill_after_swap_during_legacy_removal_does_not_double_apply() {
    // Migration window: the first v2 checkpoint already contains the
    // legacy journal's documents; a kill between the swap and the
    // legacy file's removal must not lead to a double replay.
    let dir = LocalDir::temp("cm-legacy-swap").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 10)).unwrap();
        eng.sync().unwrap();
    }
    std::fs::rename(
        Path::new(&root).join("journal-000001.wal"),
        Path::new(&root).join("journal.wal"),
    )
    .unwrap();
    let legacy = std::fs::read(Path::new(&root).join("journal.wal")).unwrap();
    {
        let mut eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 10);
        eng.checkpoint().unwrap(); // publishes v2 and removes journal.wal
    }
    // Put the legacy file back: the kill landed mid-removal.
    std::fs::write(Path::new(&root).join("journal.wal"), &legacy).unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        10,
        "legacy journal covered by a v2 checkpoint must not replay"
    );
    assert!(
        !Path::new(&root).join("journal.wal").exists(),
        "recovery must finish the interrupted legacy removal"
    );
}

#[test]
fn kill_during_delta_write_keeps_published_chain_authoritative() {
    // A kill while a delta checkpoint is being staged leaves a partial
    // `delta-NNNNNN.ckpt.tmp`: the rename never ran, so the published
    // chain (base + earlier deltas) plus the journal tail is the truth.
    let dir = LocalDir::temp("cm-delta-write").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 1: full
        eng.insert_many("metrics", &batch(20, 5)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 2: delta
        eng.insert_many("metrics", &batch(25, 5)).unwrap();
        eng.sync().unwrap();
        // Killed mid-way through staging the gen-3 delta.
    }
    let d2 = std::fs::read(Path::new(&root).join("delta-000002.ckpt")).unwrap();
    std::fs::write(Path::new(&root).join("delta-000003.ckpt.tmp"), &d2[..d2.len() / 2])
        .unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30);
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 2);
    assert_eq!(rep.deltas_folded, 1);
    assert_eq!(rep.frames_replayed, 1, "the uncheckpointed tail still replays");
    assert!(
        !Path::new(&root).join("delta-000003.ckpt.tmp").exists(),
        "recovery must discard the partial delta staging file"
    );
}

#[test]
fn kill_during_rebase_cleanup_never_refolds_superseded_chain() {
    // A rebase publishes the new full snapshot (atomic rename) and then
    // deletes the old chain. A kill between the two leaves stale deltas
    // next to a newer base; folding them would double-apply every
    // record they carry.
    let opts = manual(2);
    let dir = LocalDir::temp("cm-rebase").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 10)).unwrap();
        eng.sync().unwrap();
        assert!(eng.checkpoint().unwrap().full); // gen 1
        eng.insert_many("metrics", &batch(10, 5)).unwrap();
        eng.sync().unwrap();
        assert!(!eng.checkpoint().unwrap().full); // gen 2: delta
        eng.insert_many("metrics", &batch(15, 5)).unwrap();
        eng.sync().unwrap();
        assert!(!eng.checkpoint().unwrap().full); // gen 3: delta
        let d2 = std::fs::read(Path::new(&root).join("delta-000002.ckpt")).unwrap();
        let d3 = std::fs::read(Path::new(&root).join("delta-000003.ckpt")).unwrap();
        eng.insert_many("metrics", &batch(20, 5)).unwrap();
        eng.sync().unwrap();
        let ck = eng.checkpoint().unwrap(); // gen 4: rebase
        assert!(ck.full);
        assert!(!Path::new(&root).join("delta-000002.ckpt").exists());
        // Put the superseded chain back: the kill landed after the swap
        // but before the chain cleanup finished.
        std::fs::write(Path::new(&root).join("delta-000002.ckpt"), &d2).unwrap();
        std::fs::write(Path::new(&root).join("delta-000003.ckpt"), &d3).unwrap();
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        25,
        "stale chain under a newer base must not refold"
    );
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 4);
    assert_eq!(rep.deltas_folded, 0);
    for g in [2u64, 3] {
        assert!(
            !Path::new(&root).join(format!("delta-{g:06}.ckpt")).exists(),
            "recovery must finish the interrupted chain cleanup (delta {g})"
        );
    }
}

#[test]
fn restart_mid_chain_folds_deltas_and_tail_each_cycle() {
    // Job-queue reality under the delta lifecycle: every allocation
    // dies mid-chain with a journal tail beyond the newest delta. Each
    // restart must fold base + chain + tail exactly, and the next delta
    // must absorb the replayed tail.
    let opts = manual(16);
    let root = LocalDir::temp("cm-mid-chain").unwrap().describe();
    let mut total = 0u64;
    for cycle in 0..5u64 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        assert_eq!(eng.stats("metrics").docs, total, "cycle {cycle} lost data");
        if cycle > 0 {
            let rep = eng.recovery_report();
            assert_eq!(rep.checkpoint_generation, cycle);
            assert_eq!(rep.deltas_folded, cycle - 1, "cycle {cycle} chain length");
            assert_eq!(rep.frames_replayed, 1, "cycle {cycle} replays one tail frame");
        }
        eng.insert_many("metrics", &batch(total, 8)).unwrap();
        total += 8;
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // cycle c writes generation c+1
        eng.insert_many("metrics", &batch(total, 4)).unwrap();
        total += 4;
        eng.sync().unwrap();
        // Kill with a tail beyond the newest delta.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
    assert_eq!(eng.recovery_report().deltas_folded, 4);
    assert_eq!(eng.recovery_report().checkpoint_generation, 5);
}

#[test]
fn v2_store_opens_upgrades_and_chains_without_double_apply() {
    // Build a store, then rewrite its checkpoint into the legacy
    // `HPCCKPT2` layout (same body, pre-delta header) — exactly what a
    // PR-2-era job left on the shared filesystem.
    let dir = LocalDir::temp("cm-v2").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // v3 full, gen 1
        eng.insert_many("metrics", &batch(20, 6)).unwrap();
        eng.sync().unwrap(); // post-checkpoint tail
    }
    let ckpt = Path::new(&root).join("store.ckpt");
    let v3 = std::fs::read(&ckpt).unwrap();
    assert_eq!(&v3[..8], b"HPCCKPT3");
    assert_eq!(v3[8], 0, "store.ckpt must be a full snapshot");
    let mut v2 = b"HPCCKPT2".to_vec();
    v2.extend_from_slice(&v3[9..17]); // generation
    v2.extend_from_slice(&v3[25..33]); // covered_seq (drop base_generation)
    v2.extend_from_slice(&v3[33..]); // compressed flag + body
    std::fs::write(&ckpt, &v2).unwrap();

    // The v2 store opens: base loads, the tail replays exactly once,
    // and the first new checkpoint is a *delta* chaining directly on
    // the legacy base generation — no forced full rewrite.
    let mut eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 26);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert_eq!(eng.recovery_report().frames_replayed, 1);
    eng.insert_many("metrics", &batch(26, 4)).unwrap();
    eng.sync().unwrap();
    let ck = eng.checkpoint().unwrap(); // gen 2: delta over the v2 base
    assert!(!ck.full, "upgrading a v2 store must not force a full snapshot");
    drop(eng);

    // Mixed store (v2 base + v3 delta): the tail the delta covers was
    // truncated with it — nothing may double-apply.
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30, "v2 base + v3 delta must fold exactly");
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 2);
    assert_eq!(rep.deltas_folded, 1);
    assert_eq!(rep.frames_replayed, 0);
}

#[test]
fn compaction_trigger_accumulates_across_restarts() {
    // Each job writes only ~half the threshold and is then killed. The
    // replayed tail must seed the compaction trigger, so the *second*
    // job crosses the threshold and compacts — otherwise sub-threshold
    // jobs would grow the journal (and replay cost) without bound.
    let opts = lifecycle(32 * 1024);
    let root = LocalDir::temp("cm-trigger").unwrap().describe();
    let mut total = 0u64;
    for _cycle in 0..6 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let mut written = 0u64;
        while written < 16 * 1024 {
            eng.insert_many("metrics", &batch(total, 32)).unwrap();
            total += 32;
            let frame = eng.pending_journal_bytes() as u64;
            eng.sync().unwrap();
            written += frame;
            eng.maybe_checkpoint().unwrap();
        }
        // Kill (drop) — no teardown checkpoint.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
    assert!(
        eng.generation() >= 2,
        "cumulative tail bytes across restarts must trigger compaction, got generation {}",
        eng.generation()
    );
    // Replay stays bounded by roughly one threshold + one cycle, never
    // the whole history.
    assert!(
        eng.recovery_report().bytes_replayed
            <= opts.checkpoint_bytes + opts.segment_bytes() + 16 * 1024,
        "replayed {} bytes",
        eng.recovery_report().bytes_replayed
    );
}

#[test]
fn lifecycle_survives_repeated_kill_restart_cycles() {
    // Job-queue reality: every allocation ends in a kill. Run several
    // ingest-kill-recover cycles with compaction active and verify the
    // store is exact at every generation.
    let opts = lifecycle(32 * 1024);
    let root;
    {
        let dir = LocalDir::temp("cm-cycles").unwrap();
        root = dir.describe();
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.sync().unwrap();
    }
    let mut total = 0u64;
    for cycle in 0..5 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        assert_eq!(eng.stats("metrics").docs, total, "cycle {cycle} lost data");
        for b in 0..20 {
            eng.insert_many("metrics", &batch(total, 32)).unwrap();
            total += 32;
            eng.sync().unwrap();
            if b % 3 == 0 {
                eng.maybe_checkpoint().unwrap();
            }
        }
        // Kill (drop) — no teardown checkpoint.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
}

// ---------------------------------------------------------------------------
// Migration kill windows (streaming chunk migration — see
// `sharding::migration` and docs/ARCHITECTURE.md §6).
//
// A two-shard cluster with a ranged key and a single-node corpus puts
// every document into chunk 0 on shard 0. Each test drives the
// migration wire protocol by hand up to a precise M-state, "kills" the
// job (shutdown without a teardown checkpoint — storage-wise identical
// to a walltime kill, since every protocol step is group-committed),
// restarts on the same directories, and asserts the reconciliation
// pass leaves exactly-once data: no document lost, none duplicated.

/// Chunk 0 of a 2-shard × 1-chunk ranged pre-split covers positions
/// `[0, u64::MAX / 2]`.
const CHUNK0: (u64, u64) = (0, u64::MAX / 2);

fn mig_doc(ts: i64) -> Document {
    Document::new().set("ts", ts).set("node_id", 5i64).set("m0", ts as f64)
}

fn mig_spec() -> ClusterSpec {
    let mut spec = ClusterSpec::small(2, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        balancer: false, // the protocol is driven by hand here
        ..Default::default()
    };
    spec
}

fn mig_roots(label: &str) -> Vec<String> {
    (0..2)
        .map(|i| LocalDir::temp(&format!("{label}-{i}")).unwrap().describe())
        .collect()
}

fn mig_cluster(roots: &[String]) -> Cluster {
    let roots = roots.to_vec();
    Cluster::start(
        mig_spec(),
        move |sid| Ok(Box::new(LocalDir::new(&roots[sid.index()])?)),
        Kernels::fallback(),
        Registry::new(),
    )
    .unwrap()
}

/// Stream `limit`-sized batches of CHUNK0 from shard 0 into shard 1's
/// staging; stop early after `max_batches` (`None` = drain the range).
/// Returns the number of documents staged.
fn stream_batches(cluster: &Cluster, limit: usize, max_batches: Option<usize>) -> u64 {
    let shards = cluster.shard_mailboxes();
    let mut after = None;
    let mut staged = 0u64;
    let mut batches = 0usize;
    loop {
        let rep = rpc(&shards[0], |reply| ShardRequest::MigrateBatch {
            range: CHUNK0,
            after,
            limit,
            reply,
        })
        .unwrap()
        .unwrap();
        if let Some(last) = rep.last {
            after = Some(last);
        }
        if !rep.docs.is_empty() {
            staged += rep.docs.len() as u64;
            rpc(&shards[1], |reply| ShardRequest::StageChunk {
                range: CHUNK0,
                from: ShardId(0),
                docs: rep.docs,
                reply,
            })
            .unwrap()
            .unwrap();
            batches += 1;
        }
        if rep.done {
            break;
        }
        if let Some(mx) = max_batches {
            if batches >= mx {
                break;
            }
        }
    }
    staged
}

#[test]
fn kill_during_migration_stream_rolls_back_without_dup_or_loss() {
    let roots = mig_roots("mig-stream");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..600).map(mig_doc).collect()).unwrap();
        // Kill mid-stream: three 64-doc batches staged, no commit.
        let staged = stream_batches(&cluster, 64, Some(3));
        assert_eq!(staged, 192);
        cluster.shutdown();
    }
    {
        // Restart: reconciliation must roll the uncommitted staging
        // back — the donor still owns every document.
        let cluster = mig_cluster(&roots);
        assert_eq!(
            cluster.metrics().counter("cluster.migrations_rolled_back").get(),
            1
        );
        let client = cluster.client();
        assert_eq!(client.count_documents(Filter::True).unwrap(), 600);
        let stats = cluster.stats();
        assert_eq!(stats.per_shard_docs, vec![600, 0], "partial copy must be dropped");
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
    {
        // Reconciliation is idempotent: a third job finds nothing to do.
        let cluster = mig_cluster(&roots);
        assert_eq!(
            cluster.metrics().counter("cluster.migrations_rolled_back").get(),
            0
        );
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 600);
        cluster.shutdown();
    }
}

#[test]
fn kill_between_commit_marker_and_source_delete_rolls_forward() {
    let roots = mig_roots("mig-marker");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..500).map(mig_doc).collect()).unwrap();
        let staged = stream_batches(&cluster, 128, None);
        assert_eq!(staged, 500);
        // The durable commit marker — the roll-forward point — then the
        // kill lands before the source delete ever runs.
        let n = rpc(&cluster.shard_mailboxes()[1], |reply| ShardRequest::CommitStaged {
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(n, 500);
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 1);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            500,
            "roll-forward must neither lose nor duplicate"
        );
        let stats = cluster.stats();
        assert_eq!(stats.per_shard_docs, vec![0, 500], "data must end on the destination");
        let shard_stats = cluster.shard_stats();
        assert_eq!(shard_stats[1].staged_docs, 0);
        // The recovery's source delete carries the triggered compaction:
        // the moved-away documents left the donor's journal too.
        assert_eq!(
            shard_stats[0].journal_disk_bytes, 0,
            "post-delete compaction must truncate the donor journal"
        );
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 0);
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 500);
        cluster.shutdown();
    }
}

// lint: journal-op(OP_REMOVE_MANY) — the source delete is one atomic
// remove_many frame; this kill point replays it against the staged copy.
// lint: journal-op(OP_MOVE_MANY) — recovery's publish replays the staged →
// live move_many frame after the kill.
#[test]
fn kill_between_source_delete_and_publish_rolls_forward() {
    let roots = mig_roots("mig-delete");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..400).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 100, None), 400);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The source delete runs (one atomic remove_many frame +
        // compaction), then the kill lands before the publish.
        let del = rpc(&shards[0], |reply| ShardRequest::DeleteChunk {
            range: CHUNK0,
            compact: true,
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(del.removed, 400);
        assert!(del.compacted.is_some());
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            400,
            "the staged copy is the only copy — publish must finish"
        );
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 400]);
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
}

#[test]
fn kill_between_publish_and_source_delete_rolls_forward() {
    let roots = mig_roots("mig-publish");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..350).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 100, None), 350);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The live M4 order publishes FIRST (the orphan-read fix): the
        // destination goes live while the donor still holds its copy,
        // and the kill lands before the donor delete or ClearStaged.
        let n = rpc(&shards[1], |reply| ShardRequest::PublishStaged { reply })
            .unwrap()
            .unwrap();
        assert_eq!(n, 350);
        cluster.shutdown();
    }
    {
        // Restart: the drained staging meta + marker survive, so
        // recovery rolls forward — the donor delete removes the orphan
        // copy, the re-publish moves nothing, ClearStaged retires the
        // meta. No document is lost or duplicated.
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 1);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            350,
            "recovery must delete the donor's orphan copy exactly once"
        );
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 350]);
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
    {
        // Idempotent: a third job finds nothing to reconcile.
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 0);
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 350);
        cluster.shutdown();
    }
}

#[test]
fn kill_during_post_delete_compaction_recovers_exactly() {
    let roots = mig_roots("mig-compact");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..300).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 64, None), 300);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The range delete is durable (compact: false), and the kill
        // lands while the post-delete compaction is staging its
        // checkpoint file.
        let del = rpc(&shards[0], |reply| ShardRequest::DeleteChunk {
            range: CHUNK0,
            compact: false,
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(del.removed, 300);
        cluster.shutdown();
    }
    std::fs::write(
        Path::new(&roots[0]).join("store.ckpt.tmp"),
        b"HPCCKPT3\x00partial compaction garbage from a dying writer",
    )
    .unwrap();
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        assert_eq!(client.count_documents(Filter::True).unwrap(), 300);
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 300]);
        assert!(
            !Path::new(&roots[0]).join("store.ckpt.tmp").exists(),
            "recovery must discard the partial compaction staging file"
        );
        cluster.shutdown();
    }
}

// --- MVCC snapshot kill windows (ARCHITECTURE.md §9.4) ---------------
//
// Epochs, snapshot pins, and the reclaim garbage list are memory-only:
// a kill anywhere in the snapshot lifecycle must leave recovery exactly
// where the journal/checkpoint state machine puts it, with every
// reader-side structure forgotten.

#[test]
fn kill_during_reclaim_under_open_snapshot_replays_to_last_commit() {
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-mvcc-reclaim").unwrap();
    let root = dir.describe();
    let survivors: u64;
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 40)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(40, 20)).unwrap();
        eng.sync().unwrap();

        // A reader pins the 60-doc epoch, then the writer removes a
        // synced range and reclaims. The pin holds the floor back, so
        // the removed versions stay resident (IS1)...
        let reader = eng.reader();
        let snap = reader.snapshot();
        for rid in rids.iter().take(10) {
            eng.remove("metrics", *rid).unwrap();
        }
        eng.sync().unwrap();
        survivors = eng.stats("metrics").docs;
        let freed = eng.reclaim();
        assert_eq!(freed, 0, "open snapshot must hold the reclaim floor");
        assert!(eng.garbage_len() > 0, "the removed versions are pending reclaim");
        {
            let view = reader.view(&snap).unwrap();
            assert_eq!(view.doc_count("metrics"), 60, "pinned epoch still sees 60");
        }
        // ... and the kill lands here: snapshot open, garbage queued,
        // reclaim incomplete. Drop without checkpoint = kill.
    }
    let mut eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        survivors,
        "recovery must land on the last durable commit (the removes were synced)"
    );
    // All MVCC state died with the process: no pins survive a restart,
    // nothing is left to reclaim, and a fresh snapshot sees the
    // replayed live set.
    assert_eq!(eng.snapshots_open(), 0, "snapshot pins must not survive a kill");
    eng.reclaim();
    assert_eq!(eng.garbage_len(), 0, "a recovered store starts garbage-free");
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    assert_eq!(view.doc_count("metrics"), survivors);
}

#[test]
fn kill_mid_getmore_under_open_snapshot_drops_reader_state() {
    use std::sync::{mpsc, Arc};

    use hpcstore::mongo::query::FindOptions;
    use hpcstore::mongo::server::{ReadContext, ReadRequest};

    let opts = manual(4);
    let dir = LocalDir::temp("cm-mvcc-getmore").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 30)).unwrap();
        eng.sync().unwrap();

        // A cursor is mid-drain: find + one getMore served, the rest
        // unfetched, its snapshot pinned in the read context.
        let ctx = Arc::new(ReadContext::new(
            eng.reader(),
            Kernels::fallback(),
            Registry::new(),
            8,
        ));
        let (tx, rx) = mpsc::channel();
        ctx.serve(ReadRequest::Find {
            filter: Filter::True,
            opts: FindOptions::default().batch_size(8),
            reply: tx,
        });
        let first = rx.recv().unwrap().unwrap();
        let cursor = first.cursor.expect("30 docs at batch 8 leaves a cursor");
        let (tx, rx) = mpsc::channel();
        ctx.serve(ReadRequest::GetMore { cursor, reply: tx });
        rx.recv().unwrap().unwrap();
        assert_eq!(ctx.open_cursors(), 1);
        assert_eq!(eng.snapshots_open(), 1);

        // The writer commits past the pinned epoch, then the kill
        // lands before the next getMore: engine and reader state die
        // together (ctx is dropped with the shard).
        eng.insert_many("metrics", &batch(30, 10)).unwrap();
        eng.sync().unwrap();
    }
    let mut eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        40,
        "recovery replays every synced commit, including those past the pinned epoch"
    );
    assert_eq!(eng.snapshots_open(), 0, "cursor pins must not survive a kill");
    eng.reclaim();
    assert_eq!(eng.garbage_len(), 0);

    // A fresh read context over the recovered store serves the same
    // query from scratch — the dead cursor is gone, not resumable.
    let ctx = Arc::new(ReadContext::new(
        eng.reader(),
        Kernels::fallback(),
        Registry::new(),
        64,
    ));
    assert_eq!(ctx.open_cursors(), 0, "reader state starts empty after recovery");
    let (tx, rx) = mpsc::channel();
    ctx.serve(ReadRequest::Count { filter: Filter::True, reply: tx });
    assert_eq!(rx.recv().unwrap().unwrap().n, 40);
}

// --- CRUD journal ops kill windows (OP_UPDATE_MANY / OP_DELETE_MANY) --
//
// The full write path journals one frame per batch: an update frame
// carries `old_rid → new doc bytes` pairs, a delete frame carries rids
// only. The two windows that matter: a kill *after* the sync must
// replay the frame exactly once (no lost update, no double delete); a
// kill *before* the sync must leave the pre-mutation state — frames
// are atomic, never partial.

// lint: journal-op(OP_UPDATE_MANY) — the synced batch below is one
// update frame (kill old rid + insert new version per record); the kill
// lands before any checkpoint covers it, so recovery must replay each
// pair exactly once.
#[test]
fn kill_after_synced_update_replays_the_update_frame_exactly_once() {
    use hpcstore::mongo::bson::Value;
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-upd").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 30)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 1: the update frame is the only tail
        let updates: Vec<(RecordId, Document)> = rids
            .iter()
            .take(10)
            .enumerate()
            .map(|(i, &rid)| (rid, doc(i as u64).set("rev", 1i64)))
            .collect();
        eng.update_many("metrics", &updates).unwrap();
        eng.sync().unwrap();
        // Kill: the frame is durable, nothing covers it yet.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30, "updates are count-neutral");
    assert_eq!(
        eng.recovery_report().frames_replayed,
        1,
        "exactly the one update frame"
    );
    // Every kill+insert pair applied once: 10 documents carry the new
    // version, the other 20 the old, and none twice.
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    let mut seen = 0u64;
    let mut updated = 0u64;
    for (_rid, bytes) in view.scan_raw_from("metrics", None) {
        let d = Document::decode(bytes).unwrap();
        seen += 1;
        if d.get("rev").and_then(Value::as_i64) == Some(1) {
            updated += 1;
        }
    }
    assert_eq!(seen, 30);
    assert_eq!(updated, 10, "replayed update frame must hit each target once");
}

// lint: journal-op(OP_DELETE_MANY) — the synced rid-only batch below is
// one delete frame; replaying it twice would remove documents that were
// never targeted, replaying it zero times would resurrect the victims.
#[test]
fn kill_after_synced_delete_replays_the_delete_frame_exactly_once() {
    use hpcstore::mongo::bson::Value;
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-del").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 40)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        // Every third document: 14 victims of 40.
        let victims: Vec<RecordId> = rids.iter().copied().step_by(3).collect();
        let removed = eng.delete_many("metrics", &victims).unwrap();
        assert_eq!(removed.len(), victims.len());
        eng.sync().unwrap();
        // Kill: the delete frame is durable, the checkpoint predates it.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 26);
    assert_eq!(eng.recovery_report().frames_replayed, 1);
    // The surviving ts set is exactly the complement of the victims.
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    let mut ts: Vec<i64> = view
        .scan_raw_from("metrics", None)
        .map(|(_rid, bytes)| {
            Document::decode(bytes).unwrap().get("ts").and_then(Value::as_i64).unwrap()
        })
        .collect();
    ts.sort_unstable();
    let expect: Vec<i64> = (0..40i64).filter(|t| t % 3 != 0).collect();
    assert_eq!(ts, expect, "replayed delete frame must remove exactly the victims");
}

// ---------------------------------------------------------------------------
// Replica-set failover kill windows (oplog replication + Raft-inspired
// elections — docs/ARCHITECTURE.md §10).
//
// One *real* member runs on a spawned event loop; the test holds the
// mailboxes of the two other members of its 3-member set and plays
// leader / secondary / rival candidate by hand, which pins the protocol
// at exact states no timing trick could reach reliably. "Kill" is
// `Shutdown` + join: the event loop exits without checkpointing or
// handing anything off, storage-wise identical to a walltime kill
// (every protocol step that matters is group-committed first). Restart
// reopens the same directory, asserting the invariants IR1–IR4.

/// Spawn one replica-set member of a 1-shard × 3-member set on `root`.
/// `peers[0]` must be the spawned member's own mailbox; the test holds
/// the receivers behind `peers[1..]`. Returns the join handle, the
/// config-server mailbox receiver (held so shard→config RPCs fail soft
/// rather than surprise), and the chunk-map version writes must carry.
fn spawn_member(
    root: &str,
    member: u32,
    peers: Vec<mpsc::Sender<ShardRequest>>,
    rx: mpsc::Receiver<ShardRequest>,
    election_ms: u64,
    heartbeat_ms: u64,
    bootstrap: bool,
) -> (std::thread::JoinHandle<()>, mpsc::Receiver<ConfigRequest>, u64) {
    let (cfg_tx, cfg_rx) = mpsc::channel();
    let map = ChunkMap::pre_split(ShardKey { kind: ShardKeyKind::Hashed }, 1, 2);
    let version = map.version;
    let server = ShardServer::new(
        ShardId(0),
        Box::new(LocalDir::new(root).unwrap()),
        map,
        cfg_tx,
        Kernels::fallback(),
        Registry::new(),
        EngineOptions { journal: true, ..EngineOptions::default() },
        u64::MAX, // never report splits — no config server is playing
        64,
        0, // reads serve inline: no reader pool to tear down
        Some(ReplicaConfig {
            member,
            peers,
            election_timeout_ms: election_ms,
            heartbeat_ms,
            bootstrap_primary: bootstrap,
        }),
    )
    .unwrap();
    (server.spawn_with(rx), cfg_rx, version)
}

/// Receive from a fake peer's mailbox until `pred` yields, or panic
/// after 10 s. Messages `pred` rejects are dropped (heartbeats etc.).
fn recv_until<T>(
    rx: &mpsc::Receiver<ShardRequest>,
    what: &str,
    mut pred: impl FnMut(&ShardRequest) -> Option<T>,
) -> T {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(msg) => {
                if let Some(v) = pred(&msg) {
                    return v;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                panic!("member died while waiting for {what}");
            }
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
    }
}

/// A hand-built oplog no-op entry (what `become_primary` appends).
fn noop_entry(term: i64, index: i64) -> Document {
    Document::new().set("term", term).set("index", index).set("kind", "n")
}

/// A hand-built oplog insert entry, as the primary write path encodes
/// it: the batch rides in the `docs` array field.
fn insert_entry(term: i64, index: i64, docs: Vec<Document>) -> Document {
    Document::new()
        .set("term", term)
        .set("index", index)
        .set("kind", "i")
        .set("docs", Value::Array(docs.into_iter().map(Value::Doc).collect()))
}

/// All `ts` values carried by `kind: "i"` oplog entries in `eng`.
fn oplog_insert_ts(eng: &Engine) -> Vec<i64> {
    let mut ts: Vec<i64> = eng
        .scan(OPLOG)
        .filter(|(_, e)| e.get("kind").and_then(Value::as_str) == Some("i"))
        .flat_map(|(_, e)| match e.get("docs") {
            Some(Value::Array(items)) => items
                .iter()
                .filter_map(|v| match v {
                    Value::Doc(d) => d.get_i64("ts"),
                    _ => None,
                })
                .collect::<Vec<i64>>(),
            _ => Vec::new(),
        })
        .collect();
    ts.sort_unstable();
    ts
}

/// All `ts` values in the data collection of `eng`, sorted.
fn data_ts(eng: &Engine) -> Vec<i64> {
    let mut ts: Vec<i64> =
        eng.scan(COLLECTION).filter_map(|(_, d)| d.get_i64("ts")).collect();
    ts.sort_unstable();
    ts
}

// lint: journal-op(OP_MULTI) — the primary's data leg and its oplog
// entry below journal as ONE atomic multi-op frame; the kill lands after
// that group commit but before any secondary ack, and replay must
// restore both legs together (log presence ⇔ applied) or neither.
#[test]
fn primary_killed_mid_append_keeps_oplog_and_data_atomic_and_never_acks() {
    let root = LocalDir::temp("fo-append").unwrap().describe();
    let (tx0, rx0) = mpsc::channel();
    let (tx1, rx1) = mpsc::channel();
    let (tx2, _rx2) = mpsc::channel();
    let (join, _cfg, version) =
        spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10, true);

    let (reply_tx, reply_rx) = mpsc::channel();
    tx0.send(ShardRequest::InsertBatch {
        version,
        docs: batch(0, 5),
        wc: WriteConcern::Majority,
        reply: reply_tx,
    })
    .unwrap();

    // The entry fans out to the fake secondaries (retransmitted every
    // heartbeat until acked) — proof the append is past its group
    // commit...
    recv_until(&rx1, "insert fan-out", |m| match m {
        ShardRequest::Replicate { entries, .. }
            if entries
                .iter()
                .any(|e| e.get("kind").and_then(Value::as_str) == Some("i")) =>
        {
            Some(())
        }
        _ => None,
    });
    // ...but no ack ever arrives, so the w:majority reply must still be
    // parked (IR3: acknowledge only at majority durability).
    assert!(
        reply_rx.recv_timeout(Duration::from_millis(200)).is_err(),
        "w:majority must not release before a majority is durable"
    );

    tx0.send(ShardRequest::Shutdown).unwrap();
    join.join().unwrap();
    // The parked reply died with the member: the client side sees a dead
    // channel (typed ShardUnavailable at the router), never a false Ok.
    assert!(reply_rx.recv().is_err(), "a killed primary must not ack posthumously");

    // Recovery: the oplog entry and its data leg were one frame — both
    // replayed. Entry 1 is the bootstrap no-op, entry 2 the insert.
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats(COLLECTION).docs, 5);
    assert_eq!(eng.stats(OPLOG).docs, 2);
    assert_eq!(oplog_insert_ts(&eng), data_ts(&eng), "log presence ⇔ applied");
    let hard: Vec<Document> = eng.scan(RAFT_STATE).map(|(_, d)| d).collect();
    assert_eq!(hard.len(), 1);
    assert_eq!(hard[0].get_i64("term"), Some(1));
    drop(eng);

    // Restart-rejoin: the same directory under the same bootstrap flag
    // must NOT re-seed a primary — the member is no longer fresh. It
    // rejoins as a secondary with its persisted term and full log.
    let (tx0b, rx0b) = mpsc::channel();
    let (tx1b, _rx1b) = mpsc::channel();
    let (tx2b, _rx2b) = mpsc::channel();
    let (join_b, _cfg_b, _) =
        spawn_member(&root, 0, vec![tx0b.clone(), tx1b, tx2b], rx0b, 60_000, 10, true);
    let info = rpc(&tx0b, |reply| ShardRequest::RoleInfo { reply }).unwrap();
    assert_eq!(info.role, "secondary", "a restarted member never self-promotes");
    assert_eq!(info.term, 1);
    assert_eq!(info.last, (1, 2), "no-op + insert entries survive the kill");
    tx0b.send(ShardRequest::Shutdown).unwrap();
    join_b.join().unwrap();
}

#[test]
fn unacked_write_from_deposed_primary_is_discarded_by_resync_not_double_applied() {
    let root = LocalDir::temp("fo-resync").unwrap().describe();
    // Phase 1 — the kill window: a primary accepts a w:majority write
    // (appended + group-committed locally), no secondary ever acks, and
    // the member dies. The write is durable *locally* but uncommitted.
    {
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let (tx2, _rx2) = mpsc::channel();
        let (join, _cfg, version) =
            spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10, true);
        let (reply_tx, reply_rx) = mpsc::channel();
        tx0.send(ShardRequest::InsertBatch {
            version,
            docs: batch(0, 3),
            wc: WriteConcern::Majority,
            reply: reply_tx,
        })
        .unwrap();
        recv_until(&rx1, "insert fan-out", |m| match m {
            ShardRequest::Replicate { entries, .. } if !entries.is_empty() => Some(()),
            _ => None,
        });
        tx0.send(ShardRequest::Shutdown).unwrap();
        join.join().unwrap();
        assert!(reply_rx.recv().is_err(), "the uncommitted write must never ack");
    }

    // Phase 2 — the deposed member rejoins; meanwhile the other two
    // members elected a term-3 leader (the test, playing member 1)
    // that never saw the orphan entries. Its first append probe lands
    // on the divergent suffix and must be NACKed, never merged (IR4).
    let (tx0, rx0) = mpsc::channel();
    let (tx1, rx1) = mpsc::channel();
    let (tx2, _rx2) = mpsc::channel();
    let (join, _cfg, _) =
        spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10, true);
    tx0.send(ShardRequest::Replicate {
        term: 3,
        leader: 1,
        prev_term: 0,
        prev_index: 0,
        entries: vec![noop_entry(3, 1)],
        commit: 0,
        reset: false,
    })
    .unwrap();
    recv_until(&rx1, "divergence NACK", |m| match m {
        ShardRequest::ReplicationAck { member: 0, success: false, .. } => Some(()),
        _ => None,
    });

    // The leader answers a NACK with a full-log resync: wipe and
    // re-apply. The orphan write must vanish — it was never acked, and
    // it no longer exists anywhere in the set.
    tx0.send(ShardRequest::Replicate {
        term: 3,
        leader: 1,
        prev_term: 0,
        prev_index: 0,
        entries: vec![noop_entry(3, 1)],
        commit: 1,
        reset: true,
    })
    .unwrap();
    let ack = recv_until(&rx1, "resync ack", |m| match m {
        ShardRequest::ReplicationAck { member: 0, success: true, ack_index, .. } => {
            Some(*ack_index)
        }
        _ => None,
    });
    assert_eq!(ack, 1, "the resynced log is exactly the leader's");
    let n = rpc(&tx0, |reply| ShardRequest::Count { filter: Filter::True, reply })
        .unwrap()
        .unwrap()
        .n;
    assert_eq!(n, 0, "the discarded write must not survive the resync");
    let info = rpc(&tx0, |reply| ShardRequest::RoleInfo { reply }).unwrap();
    assert_eq!(info.term, 3);
    assert_eq!(info.last, (3, 1));
    assert_eq!(info.commit, 1);
    tx0.send(ShardRequest::Shutdown).unwrap();
    join.join().unwrap();

    // And the wipe is durable: a plain engine reopen shows no trace of
    // the orphan documents (no resurrection on the next restart).
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats(COLLECTION).docs, 0, "resync must be durable");
    assert_eq!(oplog_insert_ts(&eng), Vec::<i64>::new());
}

#[test]
fn candidate_killed_mid_election_rejoins_with_persisted_term_and_vote() {
    let root = LocalDir::temp("fo-election").unwrap().describe();
    let first_term;
    {
        // A lone-ish member with a fast election clock: its timeout
        // fires, it persists `{term+1, voted_for: self}` and solicits
        // votes — and the kill lands before any vote returns.
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let (tx2, _rx2) = mpsc::channel();
        let (join, _cfg, _) =
            spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 40, 10, false);
        first_term = recv_until(&rx1, "vote solicitation", |m| match m {
            ShardRequest::RequestVote { term, candidate: 0, .. } => Some(*term),
            _ => None,
        });
        assert!(first_term >= 1);
        tx0.send(ShardRequest::Shutdown).unwrap();
        join.join().unwrap();
    }

    // The candidacy's hard state survived the kill (it was journaled +
    // synced *before* any RequestVote left the member).
    {
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        let hard: Vec<Document> = eng.scan(RAFT_STATE).map(|(_, d)| d).collect();
        assert_eq!(hard.len(), 1, "hard state is a single document");
        let term = hard[0].get_i64("term").unwrap();
        assert!(term >= first_term as i64);
        assert_eq!(hard[0].get_i64("voted_for"), Some(0), "the self-vote persisted");
    }

    // Restart with a frozen election clock and probe IR1: a rival
    // asking for a vote in the persisted term must be refused — this
    // member already voted (for itself) in that term, and a kill must
    // not launder a second grant.
    let (tx0, rx0) = mpsc::channel();
    let (tx1, _rx1) = mpsc::channel();
    let (tx2, rx2) = mpsc::channel();
    let (join, _cfg, _) =
        spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10, false);
    let info = rpc(&tx0, |reply| ShardRequest::RoleInfo { reply }).unwrap();
    assert_eq!(info.role, "secondary", "a restarted candidate rejoins as secondary");
    assert!(info.term >= first_term);
    tx0.send(ShardRequest::RequestVote {
        term: info.term,
        candidate: 2,
        last_term: info.term,
        last_index: 1_000_000,
    })
    .unwrap();
    let granted = recv_until(&rx2, "same-term vote reply", |m| match m {
        ShardRequest::VoteReply { from: 0, granted, .. } => Some(*granted),
        _ => None,
    });
    assert!(!granted, "a persisted vote must never be re-granted after a kill (IR1)");

    // A higher term is a fresh ballot: the same rival now wins the vote
    // (the hard state moved on, it is not stuck).
    tx0.send(ShardRequest::RequestVote {
        term: info.term + 1,
        candidate: 2,
        last_term: info.term,
        last_index: 1_000_000,
    })
    .unwrap();
    let granted = recv_until(&rx2, "next-term vote reply", |m| match m {
        ShardRequest::VoteReply { from: 0, granted, .. } => Some(*granted),
        _ => None,
    });
    assert!(granted, "a new term frees the vote");
    tx0.send(ShardRequest::Shutdown).unwrap();
    join.join().unwrap();
}

// lint: journal-op(OP_MULTI) — each tailed entry below applies as one
// atomic frame (data leg + oplog leg) on the secondary; the kill lands
// after the ack, and the retransmitted window must verify against the
// recovered log instead of re-applying (the dedupe path).
#[test]
fn secondary_killed_mid_apply_dedupes_retransmission_and_catches_up() {
    let root = LocalDir::temp("fo-apply").unwrap().describe();
    let window = vec![
        noop_entry(1, 1),
        insert_entry(1, 2, batch(0, 4)),
        insert_entry(1, 3, batch(4, 2)),
    ];
    {
        // A pure secondary (election clock frozen) tails a 3-entry
        // window from the term-1 leader (the test, member 1), acks it,
        // and dies right after the ack leaves.
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let (tx2, _rx2) = mpsc::channel();
        let (join, _cfg, _) =
            spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10_000, false);
        tx0.send(ShardRequest::Replicate {
            term: 1,
            leader: 1,
            prev_term: 0,
            prev_index: 0,
            entries: window.clone(),
            commit: 0,
            reset: false,
        })
        .unwrap();
        let ack = recv_until(&rx1, "apply ack", |m| match m {
            ShardRequest::ReplicationAck { member: 0, success: true, ack_index, .. } => {
                Some(*ack_index)
            }
            _ => None,
        });
        assert_eq!(ack, 3, "the ack is a durability promise for the whole window");
        tx0.send(ShardRequest::Shutdown).unwrap();
        join.join().unwrap();
    }

    // The ack was honest: every acked entry and its data leg recovered.
    {
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats(OPLOG).docs, 3);
        assert_eq!(eng.stats(COLLECTION).docs, 6);
        assert_eq!(oplog_insert_ts(&eng), data_ts(&eng), "log presence ⇔ applied");
    }

    // The leader never processed the ack (from its side this member
    // died mid-apply) and retransmits the same window after the rejoin.
    // The recovered member must *verify* the entries it already holds —
    // ack again, apply nothing twice.
    let (tx0, rx0) = mpsc::channel();
    let (tx1, rx1) = mpsc::channel();
    let (tx2, _rx2) = mpsc::channel();
    let (join, _cfg, _) =
        spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 10_000, false);
    tx0.send(ShardRequest::Replicate {
        term: 1,
        leader: 1,
        prev_term: 0,
        prev_index: 0,
        entries: window,
        commit: 3,
        reset: false,
    })
    .unwrap();
    let ack = recv_until(&rx1, "retransmission ack", |m| match m {
        ShardRequest::ReplicationAck { member: 0, success: true, ack_index, .. } => {
            Some(*ack_index)
        }
        _ => None,
    });
    assert_eq!(ack, 3);
    let n = rpc(&tx0, |reply| ShardRequest::Count { filter: Filter::True, reply })
        .unwrap()
        .unwrap()
        .n;
    assert_eq!(n, 6, "a retransmitted window must never double-apply");
    let info = rpc(&tx0, |reply| ShardRequest::RoleInfo { reply }).unwrap();
    assert_eq!(info.last, (1, 3));
    assert_eq!(info.commit, 3, "the leader's commit index propagates on verify");

    // Catch-up tailing: the next entry appends cleanly where the
    // recovered log ends — a rejoined member needs no special path.
    tx0.send(ShardRequest::Replicate {
        term: 1,
        leader: 1,
        prev_term: 1,
        prev_index: 3,
        entries: vec![insert_entry(1, 4, batch(6, 3))],
        commit: 3,
        reset: false,
    })
    .unwrap();
    let ack = recv_until(&rx1, "catch-up ack", |m| match m {
        ShardRequest::ReplicationAck { member: 0, success: true, ack_index, .. } => {
            Some(*ack_index)
        }
        _ => None,
    });
    assert_eq!(ack, 4);
    let n = rpc(&tx0, |reply| ShardRequest::Count { filter: Filter::True, reply })
        .unwrap()
        .unwrap()
        .n;
    assert_eq!(n, 9);
    tx0.send(ShardRequest::Shutdown).unwrap();
    join.join().unwrap();
}

/// `FAILOVER_FUZZ_SEEDS`: a count (`16` → seeds 0..16) or an explicit
/// comma list; default 10 seeds (documented in docs/EXPERIMENTS.md).
fn failover_seeds() -> Vec<u64> {
    match std::env::var("FAILOVER_FUZZ_SEEDS") {
        Ok(s) if s.contains(',') => s
            .split(',')
            .map(|t| t.trim().parse().expect("FAILOVER_FUZZ_SEEDS: bad seed"))
            .collect(),
        Ok(s) => {
            let n: u64 = s.trim().parse().expect("FAILOVER_FUZZ_SEEDS: bad count");
            (0..n).collect()
        }
        Err(_) => (0..10).collect(),
    }
}

/// One randomized failover run: a primary takes w:majority batches while
/// a fake secondary acks a random, lagging subset of the oplog, then the
/// primary is killed at a random point. Judged after recovery:
/// every batch whose reply released `Ok` is present exactly once, no
/// document is ever present twice, and log presence ⇔ applied.
fn run_failover_seed(seed: u64) {
    let mut rng = Pcg32::seeded(seed);
    let root = LocalDir::temp(&format!("fo-fuzz-{seed}")).unwrap().describe();
    let (tx0, rx0) = mpsc::channel();
    let (tx1, rx1) = mpsc::channel();
    let (tx2, _rx2) = mpsc::channel();
    let (join, _cfg, version) =
        spawn_member(&root, 0, vec![tx0.clone(), tx1, tx2], rx0, 60_000, 2, true);

    // (ts range, parked reply) per batch, in issue order.
    let mut batches: Vec<(u64, u64, mpsc::Receiver<_>)> = Vec::new();
    let mut next_ts = 0u64;
    let ops = 6 + rng.next_bounded(12);
    for _ in 0..ops {
        let k = 1 + rng.next_bounded(8) as u64;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx0.send(ShardRequest::InsertBatch {
            version,
            docs: batch(next_ts, k),
            wc: WriteConcern::Majority,
            reply: reply_tx,
        })
        .unwrap();
        batches.push((next_ts, next_ts + k, reply_rx));
        next_ts += k;

        // Sometimes play the durable secondary: drain whatever the
        // primary has fanned out so far and ack the highest index seen.
        // Acks lag the log on purpose — that is the window under test.
        if rng.next_bounded(100) < 55 {
            std::thread::sleep(Duration::from_millis(5));
            let (mut high, mut term) = (0u64, 0u64);
            while let Ok(msg) = rx1.try_recv() {
                if let ShardRequest::Replicate { term: t, entries, .. } = msg {
                    for e in &entries {
                        high = high.max(e.get_i64("index").unwrap_or(0).max(0) as u64);
                    }
                    term = t;
                }
            }
            if high > 0 {
                tx0.send(ShardRequest::ReplicationAck {
                    member: 1,
                    term,
                    ack_index: high,
                    success: true,
                })
                .unwrap();
            }
        }
    }
    // Kill. The mailbox drains in order first, so every reply that will
    // ever release has released by the time join returns.
    tx0.send(ShardRequest::Shutdown).unwrap();
    join.join().unwrap();

    let mut acked: Vec<(u64, u64)> = Vec::new();
    for (lo, hi, reply_rx) in batches {
        if let Ok(Ok(rep)) = reply_rx.try_recv() {
            assert_eq!(
                rep.inserted,
                (hi - lo) as usize,
                "seed {seed}: acked batch reports its full size"
            );
            acked.push((lo, hi));
        }
        // Empty/disconnected = unacked (parked reply died with the
        // member); Ok(Err) cannot happen on a healthy primary.
    }

    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    let ts = data_ts(&eng);
    for w in ts.windows(2) {
        assert_ne!(w[0], w[1], "seed {seed}: document {} applied twice", w[0]);
    }
    for (lo, hi) in &acked {
        for t in *lo..*hi {
            assert!(
                ts.binary_search(&(t as i64)).is_ok(),
                "seed {seed}: w:majority-acked ts {t} lost in failover"
            );
        }
    }
    assert_eq!(
        oplog_insert_ts(&eng),
        ts,
        "seed {seed}: oplog entries and applied data must be the same fact"
    );
    drop(eng);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn failover_fuzz_acked_writes_survive_over_seed_matrix() {
    let seeds = failover_seeds();
    assert!(!seeds.is_empty(), "FAILOVER_FUZZ_SEEDS selected no seeds");
    for seed in seeds {
        run_failover_seed(seed);
    }
}

#[test]
fn unsynced_update_and_delete_frames_vanish_at_the_kill() {
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-crud-unsynced").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        let updates: Vec<(RecordId, Document)> =
            vec![(rids[0], doc(0).set("rev", 7i64))];
        eng.update_many("metrics", &updates).unwrap();
        eng.delete_many("metrics", &rids[5..10]).unwrap();
        // Kill before the sync: both frames were buffered only.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 20, "unsynced CRUD frames must vanish");
    assert_eq!(eng.recovery_report().frames_replayed, 0);
}
