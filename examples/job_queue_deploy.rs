//! End-to-end driver — the full paper pipeline on a real small workload.
//!
//! 1. Submit the deploy run-script as a job to the Torque/Moab-like
//!    scheduler; it is admitted onto a node allocation.
//! 2. The run script assigns roles (config/shard/router/client PEs),
//!    brings the sharded store up with every shard directory on the
//!    Lustre simulator, and publishes the router hostfile.
//! 3. The OVIS corpus is written as flat CSV onto Lustre (the paper's
//!    200 TB archive, scaled), then client PEs ingest it with
//!    `insertMany(ordered=false)` through the AOT route kernel.
//! 4. Concurrent conditional finds replay user-job metadata and verify
//!    the paper's count formula (nodes × duration).
//! 5. Teardown checkpoints to Lustre; a SECOND job reattaches to the
//!    same data and queries it — the transient-job persistence story.
//!
//! ```sh
//! make artifacts && cargo run --release --example job_queue_deploy
//! ```

use std::time::Instant;

use hpcstore::config::{LustreConfig, StoreConfig, Topology, WorkloadConfig};
use hpcstore::hpc::lustre::Lustre;
use hpcstore::hpc::runscript::RunScript;
use hpcstore::hpc::scheduler::{Job, Scheduler};
use hpcstore::mongo::query::Filter;
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::runtime::Kernels;
use hpcstore::util::fmt::{human_bytes, human_count};
use hpcstore::workload::csvstore;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::QueryDriver;

fn main() -> anyhow::Result<()> {
    let kernels = Kernels::load_or_fallback("artifacts");
    println!("== kernel backend: {:?}", kernels.backend());

    // The machine: a 64-node mini-Blue-Waters with a striped Lustre fs.
    let lustre = Lustre::mount(LustreConfig { osts: 8, ..Default::default() })?;
    let mut sched = Scheduler::new(64);

    // Workload: a real small corpus — 96 monitored nodes x 1 hour,
    // 75 metrics per sample (the paper's OVIS shape).
    let wl = WorkloadConfig {
        monitored_nodes: 96,
        metrics_per_doc: 75,
        days: 60.0 / 1440.0,
        query_jobs: 24,
        ..Default::default()
    };
    let gen = OvisGenerator::new(wl.clone());

    // The corpus lands on Lustre as flat CSV first (the archive form).
    let csv_dir = lustre.dir("scratch/ovis_csv")?;
    let t = Instant::now();
    let files = csvstore::write_corpus(&gen, &csv_dir, 15)?;
    println!(
        "== corpus: {} docs ({} CSV) in {} files on lustre [{:.1}s]",
        human_count(gen.total_docs()),
        human_bytes(csvstore::corpus_bytes(&gen)),
        files.len(),
        t.elapsed().as_secs_f64()
    );

    // ---- JOB 1: deploy + ingest + query --------------------------------
    let topo = Topology::small(4, 2, 4);
    let script = RunScript::new(topo.clone(), StoreConfig::default(), lustre.clone(), kernels);
    let job1 = sched.submit(Job::new("mongo-ingest", topo.total_nodes, 3600))?;
    let hosts = sched.hosts_of(job1).expect("admitted").to_vec();
    println!("== job1 admitted on {} hosts; deploying cluster...", hosts.len());
    let dep = script.deploy(&hosts)?;
    let client = dep.client_from_hostfile()?;
    client.create_index(IndexSpec::single("ts")).map_err(anyhow::Error::msg)?;
    client.create_index(IndexSpec::single("node_id")).map_err(anyhow::Error::msg)?;

    // Ingest: PE threads stream disjoint CSV files → insertMany.
    let t = Instant::now();
    let pes = dep.client_pes().max(4);
    let mut handles = Vec::new();
    for pe in 0..pes {
        let files: Vec<String> = files
            .iter()
            .enumerate()
            .filter(|(i, _)| i % pes == pe)
            .map(|(_, f)| f.clone())
            .collect();
        let client = client.pinned(pe);
        let dir = lustre.dir("scratch/ovis_csv")?;
        handles.push(std::thread::spawn(move || -> anyhow::Result<u64> {
            let mut inserted = 0u64;
            for f in files {
                let docs = csvstore::read_slice(&dir, &f)?;
                for chunk in docs.chunks(1000) {
                    inserted += client
                        .insert_many(chunk.to_vec())
                        .map_err(anyhow::Error::msg)?
                        .inserted as u64;
                }
            }
            Ok(inserted)
        }));
    }
    let mut total = 0;
    for h in handles {
        total += h.join().expect("PE panicked")?;
    }
    let ingest_s = t.elapsed().as_secs_f64();
    println!(
        "== ingest: {} docs in {ingest_s:.1}s over {pes} PEs → {} docs/s",
        human_count(total),
        human_count((total as f64 / ingest_s) as u64)
    );
    assert_eq!(total, gen.total_docs(), "every CSV row must be ingested");

    // Queries: the paper's concurrent conditional finds.
    let report = QueryDriver::new(generate_jobs(&wl), pes).run(&client)?;
    println!("== queries: {}", report.summary());
    assert_eq!(report.count_mismatches, 0, "paper count formula must hold");

    let stats = dep.cluster.stats();
    println!(
        "== store: {} docs, {} chunks (map v{}), per-shard {:?}",
        human_count(stats.docs),
        stats.chunks,
        stats.map_version,
        stats.per_shard_docs
    );
    dep.teardown()?;
    sched.complete(job1)?;
    println!(
        "== job1 done; lustre holds {} across {} OSTs {:?}",
        human_bytes(lustre.total_written()),
        lustre.config().osts,
        lustre.ost_written().iter().map(|b| human_bytes(*b)).collect::<Vec<_>>()
    );

    // ---- JOB 2: reattach and query the persisted store ------------------
    let job2 = sched.submit(Job::new("mongo-requery", topo.total_nodes, 3600))?;
    let hosts2 = sched.hosts_of(job2).expect("admitted").to_vec();
    println!("== job2 admitted; redeploying over the same Lustre scratch...");
    let dep2 = script.deploy(&hosts2)?;
    let client2 = dep2.client_from_hostfile()?;
    let count = client2.count_documents(Filter::True).map_err(anyhow::Error::msg)?;
    println!("== job2 sees {} persisted docs", human_count(count as u64));
    assert_eq!(count as u64, gen.total_docs(), "persistence across jobs");
    let report2 = QueryDriver::new(generate_jobs(&wl), 4).run(&client2)?;
    assert_eq!(report2.count_mismatches, 0);
    println!("== job2 queries: {}", report2.summary());
    dep2.teardown()?;
    sched.complete(job2)?;

    println!("\nEND-TO-END OK — all layers composed (scheduler → runscript → lustre → store → kernels → workload)");
    Ok(())
}
