//! Full-pipeline integration (the job_queue_deploy example, test-sized):
//! scheduler → run script → Lustre CSV corpus → ingest → conditional
//! finds → teardown → second job reattaches.

use hpcstore::config::{LustreConfig, StoreConfig, Topology, WorkloadConfig};
use hpcstore::hpc::lustre::Lustre;
use hpcstore::hpc::runscript::RunScript;
use hpcstore::hpc::scheduler::{Job, JobState, Scheduler};
use hpcstore::mongo::query::Filter;
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::runtime::Kernels;
use hpcstore::workload::csvstore;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::QueryDriver;

#[test]
fn scheduler_runscript_csv_ingest_query_persist() {
    let lustre = Lustre::mount(LustreConfig { osts: 4, ..Default::default() }).unwrap();
    let mut sched = Scheduler::new(16);

    let wl = WorkloadConfig {
        monitored_nodes: 24,
        metrics_per_doc: 10,
        days: 20.0 / 1440.0,
        query_jobs: 8,
        ..Default::default()
    };
    let gen = OvisGenerator::new(wl.clone());

    // Corpus to CSV on the shared filesystem.
    let csv_dir = lustre.dir("scratch/csv").unwrap();
    let files = csvstore::write_corpus(&gen, &csv_dir, 7).unwrap();
    assert_eq!(files.len(), 3);

    let topo = Topology::small(2, 1, 2);
    let script = RunScript::new(
        topo.clone(),
        StoreConfig::default(),
        lustre.clone(),
        Kernels::fallback(),
    );

    // Job 1: ingest from CSV, query, teardown.
    let job = sched.submit(Job::new("ingest", topo.total_nodes, 600)).unwrap();
    let hosts = sched.hosts_of(job).unwrap().to_vec();
    let dep = script.deploy(&hosts).unwrap();
    let client = dep.client_from_hostfile().unwrap();
    client.create_index(IndexSpec::single("ts")).unwrap();
    client.create_index(IndexSpec::single("node_id")).unwrap();

    let mut ingested = 0u64;
    for f in &files {
        let docs = csvstore::read_slice(&csv_dir, f).unwrap();
        for chunk in docs.chunks(200) {
            ingested += client.insert_many(chunk.to_vec()).unwrap().inserted as u64;
        }
    }
    assert_eq!(ingested, gen.total_docs());

    let report = QueryDriver::new(generate_jobs(&wl), 2).run(&client).unwrap();
    assert_eq!(report.count_mismatches, 0, "paper count formula must hold");
    assert_eq!(report.queries, 8);

    dep.teardown().unwrap();
    sched.complete(job).unwrap();
    assert!(matches!(sched.state(job), JobState::Completed { .. }));
    assert!(lustre.total_written() > 0);
    // Striping spread the store over multiple OSTs.
    let touched = lustre.ost_written().iter().filter(|&&b| b > 0).count();
    assert!(touched >= 2, "expected striping across OSTs");

    // Job 2: fresh allocation, same scratch → data persists.
    let job2 = sched.submit(Job::new("requery", topo.total_nodes, 600)).unwrap();
    let hosts2 = sched.hosts_of(job2).unwrap().to_vec();
    let dep2 = script.deploy(&hosts2).unwrap();
    let client2 = dep2.client_from_hostfile().unwrap();
    assert_eq!(
        client2.count_documents(Filter::True).unwrap() as u64,
        gen.total_docs()
    );
    let report2 = QueryDriver::new(generate_jobs(&wl), 2).run(&client2).unwrap();
    assert_eq!(report2.count_mismatches, 0);
    dep2.teardown().unwrap();
    sched.complete(job2).unwrap();
}

#[test]
fn walltime_kill_then_recovery_from_journal() {
    // A job killed before checkpoint must still recover synced writes
    // from the journal on the next deployment.
    let lustre = Lustre::mount(LustreConfig::default()).unwrap();
    let topo = Topology::small(2, 1, 1);
    let script = RunScript::new(
        topo.clone(),
        StoreConfig::default(),
        lustre.clone(),
        Kernels::fallback(),
    );
    let hosts: Vec<u32> = (0..topo.total_nodes).collect();
    {
        let dep = script.deploy(&hosts).unwrap();
        let client = dep.client_from_hostfile().unwrap();
        let docs: Vec<_> = (0..300)
            .map(|i| {
                hpcstore::mongo::bson::Document::new()
                    .set("ts", i as i64)
                    .set("node_id", (i % 6) as i64)
            })
            .collect();
        client.insert_many(docs).unwrap();
        // Walltime kill: no checkpoint.
        dep.kill();
    }
    {
        let dep = script.deploy(&hosts).unwrap();
        let client = dep.client_from_hostfile().unwrap();
        // insert_many group-commits per batch, so all 300 are journaled.
        assert_eq!(client.count_documents(Filter::True).unwrap(), 300);
        dep.teardown().unwrap();
    }
}
