//! Config-server state: the sharded cluster's metadata authority.
//!
//! "Config servers store the metadata for a sharded cluster ... the list
//! of chunks on every shard and the ranges that define the chunks"
//! (paper §3.1). [`ConfigState`] is the pure, testable state machine; the
//! live cluster hosts it on the config-server thread(s) behind the wire
//! layer. A small CSRS-style replica set is modeled: every mutation is
//! applied to the primary and synchronously acked by the mirrors, and
//! reads may be served by any member.

use anyhow::{bail, Result};

use super::chunk::{ChunkMap, MigrationHandoff, ShardKey};
use super::migration::MState;
use crate::util::ids::ShardId;

/// Outcome of a version-guarded mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VersionCheck {
    Ok,
    /// Caller's cached map is stale; it must refresh before retrying.
    Stale { current: u64 },
}

/// A chunk migration in flight, carrying its M-state (see
/// [`super::migration`] for the protocol). The key-position `range` is
/// the migration's stable identity: chunk *indices* shift as other
/// chunks split, so ownership is flipped by range, and splits of the
/// migrating range itself are refused while the migration runs
/// (invariant IM3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Migration {
    pub chunk: usize,
    /// Inclusive key-position bounds of the migrating chunk.
    pub range: (u64, u64),
    pub from: ShardId,
    pub to: ShardId,
    pub state: MState,
}

/// The metadata state machine.
#[derive(Clone, Debug)]
pub struct ConfigState {
    shards: Vec<ShardId>,
    map: ChunkMap,
    /// Synchronous mirrors (replica count - 1). Kept bit-identical to
    /// the primary map; a read may be served from any of them.
    mirrors: Vec<ChunkMap>,
    migration: Option<Migration>,
    /// Mutation log length (diagnostics; equals number of committed
    /// metadata changes).
    pub oplog_len: u64,
}

impl ConfigState {
    /// Initialize with `num_shards` registered shards and a pre-split
    /// chunk table (`chunks_per_shard` chunks each).
    pub fn new(key: ShardKey, num_shards: u32, chunks_per_shard: u32, replicas: u32) -> Self {
        let map = ChunkMap::pre_split(key, num_shards, chunks_per_shard);
        let mirrors = vec![map.clone(); replicas.saturating_sub(1) as usize];
        Self {
            shards: (0..num_shards).map(ShardId).collect(),
            map,
            mirrors,
            migration: None,
            oplog_len: 0,
        }
    }

    pub fn shards(&self) -> &[ShardId] {
        &self.shards
    }

    /// Current map (primary read).
    pub fn map(&self) -> &ChunkMap {
        &self.map
    }

    /// Read from mirror `i` (tests assert replica consistency).
    pub fn mirror(&self, i: usize) -> Option<&ChunkMap> {
        self.mirrors.get(i)
    }

    pub fn version(&self) -> u64 {
        self.map.version
    }

    fn replicate(&mut self) {
        for m in &mut self.mirrors {
            *m = self.map.clone();
        }
        self.oplog_len += 1;
    }

    /// Version-guarded chunk split requested by a shard that saw a chunk
    /// exceed the split threshold. Idempotent under stale versions: a
    /// stale requester gets `Stale` and refreshes.
    pub fn split_chunk(
        &mut self,
        seen_version: u64,
        chunk: usize,
        at: u64,
    ) -> Result<VersionCheck> {
        if seen_version != self.map.version {
            return Ok(VersionCheck::Stale { current: self.map.version });
        }
        // Invariant IM3: the migrating range is immutable while the
        // migration runs — a split inside it would tear the range out
        // from under the streamed cursor and the eventual flip.
        if let Some(m) = &self.migration {
            if chunk < self.map.num_chunks() {
                let (lo, hi) = self.map.chunk_range(chunk);
                if lo <= m.range.1 && m.range.0 <= hi {
                    bail!("chunk {chunk} overlaps the in-flight migration range");
                }
            }
        }
        self.map.split(chunk, at)?;
        debug_assert!(self.map.validate().is_ok());
        self.replicate();
        Ok(VersionCheck::Ok)
    }

    /// Begin migrating `chunk` to `to` (M1, `Streaming`). Only one
    /// migration at a time (MongoDB serializes per-collection
    /// migrations through the config server — this serialization is one
    /// of the scaling costs the DES models). Records the handoff in the
    /// chunk map (version bump) so every shard and router learns —
    /// atomically with map propagation — which range has copies in
    /// motion (read filtering + write fencing, ARCHITECTURE.md §6.3).
    pub fn begin_migration(&mut self, chunk: usize, to: ShardId) -> Result<Migration> {
        if self.migration.is_some() {
            bail!("a migration is already in flight");
        }
        if self.map.handoff.is_some() {
            // A post-marker abort keeps the handoff: the donor's orphan
            // copies still need filtering until the next job's recovery
            // reconciles the data. Overwriting it would unfilter them.
            bail!("unreconciled handoff from an aborted migration");
        }
        if chunk >= self.map.num_chunks() {
            bail!("no chunk {chunk}");
        }
        if !self.shards.contains(&to) {
            bail!("unknown destination {to}");
        }
        let from = self.map.owners[chunk];
        if from == to {
            bail!("chunk {chunk} already on {to}");
        }
        let m = Migration {
            chunk,
            range: self.map.chunk_range(chunk),
            from,
            to,
            state: MState::Streaming,
        };
        self.map.handoff = Some(MigrationHandoff { range: m.range, from, published: false });
        self.map.version += 1;
        debug_assert!(self.map.validate().is_ok());
        self.migration = Some(m.clone());
        self.replicate();
        Ok(m)
    }

    /// Flip the in-flight migration's ownership (M2, `Flipped`): the
    /// chunk is relocated by *range* — indices may have shifted as
    /// other chunks split — reassigned to the destination, and the map
    /// version bumps. The migration stays in flight until
    /// [`Self::finish_migration`] (or an abort).
    pub fn commit_migration(&mut self) -> Result<u64> {
        let m = self
            .migration
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("no migration in flight"))?;
        if m.state != MState::Streaming {
            bail!("migration already {}", m.state);
        }
        let range = m.range;
        let to = m.to;
        let chunk = self.map.chunk_of(range.0);
        if self.map.chunk_range(chunk) != range {
            bail!("migrating range mutated under the flip (IM3 violated)");
        }
        self.map.move_chunk(chunk, to)?;
        debug_assert!(self.map.validate().is_ok());
        // lint: allow(panic, presence was checked at function entry; move_chunk
        // cannot clear the field, this re-borrow only satisfies the borrow checker)
        let m = self.migration.as_mut().expect("checked above");
        m.chunk = chunk;
        m.state = MState::Flipped;
        self.replicate();
        Ok(self.map.version)
    }

    /// Record a coordinator-observed state transition. States only move
    /// forward; regressions are rejected.
    pub fn advance_migration(&mut self, state: MState) -> Result<()> {
        let m = self
            .migration
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("no migration in flight"))?;
        if state <= m.state {
            bail!("migration cannot regress from {} to {state}", m.state);
        }
        m.state = state;
        Ok(())
    }

    /// Mark the in-flight migration's staged copy as published on the
    /// destination: from this map version on, the donor's remaining
    /// copies of the range are orphans and every reader must drop them.
    /// Returns the new map version.
    pub fn publish_migration(&mut self) -> Result<u64> {
        let m = self
            .migration
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no migration in flight"))?;
        if m.state < MState::Committed {
            bail!("cannot publish an uncommitted migration ({})", m.state);
        }
        let h = self
            .map
            .handoff
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("no handoff recorded for the migration"))?;
        if h.published {
            bail!("handoff already published");
        }
        h.published = true;
        self.map.version += 1;
        debug_assert!(self.map.validate().is_ok());
        self.replicate();
        Ok(self.map.version)
    }

    /// Clear a finished migration (after M4 cleanup): drops the handoff
    /// — the donor's copy is deleted, reads need no filtering — and
    /// bumps the version. Returns the map version.
    pub fn finish_migration(&mut self) -> Result<u64> {
        let m = self
            .migration
            .take()
            .ok_or_else(|| anyhow::anyhow!("no migration in flight"))?;
        if m.state < MState::Flipped {
            self.migration = Some(m);
            bail!("cannot finish an unflipped migration");
        }
        if self.map.handoff.is_some() {
            self.map.handoff = None;
            self.map.version += 1;
            debug_assert!(self.map.validate().is_ok());
            self.replicate();
        }
        Ok(self.map.version)
    }

    /// Abort the in-flight migration. If the owner map was already
    /// flipped but the destination has *not* durably committed, the
    /// flip is rolled back (the donor still owns the data). A
    /// `Committed`/`Cleanup` migration is cleared without unflipping:
    /// from the commit marker on, the protocol only rolls forward (the
    /// next job's recovery pass finishes it).
    pub fn abort_migration(&mut self) -> Option<Migration> {
        let m = self.migration.take()?;
        let mut mutated = false;
        if m.state == MState::Flipped {
            let chunk = self.map.chunk_of(m.range.0);
            if self.map.chunk_range(chunk) == m.range {
                let _ = self.map.move_chunk(chunk, m.from);
                mutated = true;
            }
        }
        // A rolled-back migration drops its handoff (the donor owns and
        // holds everything again); a committed one keeps it — the
        // published flag is what keeps the donor's orphan copies
        // filtered until the next job's recovery deletes them.
        if m.state < MState::Committed && self.map.handoff.is_some() {
            self.map.handoff = None;
            if !mutated {
                self.map.version += 1;
            }
            mutated = true;
        }
        if mutated {
            debug_assert!(self.map.validate().is_ok());
            self.replicate();
        }
        Some(m)
    }

    pub fn migration(&self) -> Option<&Migration> {
        self.migration.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ConfigState {
        ConfigState::new(ShardKey::hashed(), 4, 2, 3)
    }

    #[test]
    fn initial_state_is_pre_split() {
        let s = state();
        assert_eq!(s.shards().len(), 4);
        assert_eq!(s.map().num_chunks(), 8);
        assert_eq!(s.version(), 1);
        assert_eq!(s.mirror(0).unwrap(), s.map());
        assert_eq!(s.mirror(1).unwrap(), s.map());
        assert!(s.mirror(2).is_none()); // replicas=3 → 2 mirrors
    }

    #[test]
    fn split_bumps_version_and_replicates() {
        let mut s = state();
        let (lo, hi) = s.map().chunk_range(0);
        let r = s.split_chunk(1, 0, lo + (hi - lo) / 2).unwrap();
        assert_eq!(r, VersionCheck::Ok);
        assert_eq!(s.version(), 2);
        assert_eq!(s.map().num_chunks(), 9);
        assert_eq!(s.mirror(0).unwrap().num_chunks(), 9);
        assert_eq!(s.oplog_len, 1);
    }

    #[test]
    fn stale_split_is_rejected_without_mutation() {
        let mut s = state();
        let (lo, hi) = s.map().chunk_range(0);
        s.split_chunk(1, 0, lo + (hi - lo) / 2).unwrap();
        // Second requester still thinks version is 1.
        let r = s.split_chunk(1, 1, 0).unwrap();
        assert_eq!(r, VersionCheck::Stale { current: 2 });
        assert_eq!(s.map().num_chunks(), 9); // unchanged
    }

    #[test]
    fn migration_lifecycle() {
        let mut s = state();
        let from = s.map().owners[0];
        let to = ShardId((from.0 + 1) % 4);
        let m = s.begin_migration(0, to).unwrap();
        assert_eq!(m.from, from);
        assert_eq!(m.state, MState::Streaming);
        assert_eq!(m.range, s.map().chunk_range(0));
        // Begin records the (unpublished) handoff and bumps the map.
        assert_eq!(s.version(), 2);
        let h = s.map().handoff.expect("begin records the handoff");
        assert_eq!((h.range, h.from, h.published), (m.range, from, false));
        assert_eq!(s.mirror(0).unwrap().handoff, Some(h));
        // Only one at a time.
        assert!(s.begin_migration(1, to).is_err());
        let v = s.commit_migration().unwrap();
        assert_eq!(v, 3);
        assert_eq!(s.map().owners[0], to);
        assert_eq!(s.mirror(1).unwrap().owners[0], to);
        // The flip keeps the migration in flight (M2) until cleanup.
        assert_eq!(s.migration().unwrap().state, MState::Flipped);
        assert!(s.commit_migration().is_err(), "cannot flip twice");
        assert!(s.publish_migration().is_err(), "publish needs the marker");
        s.advance_migration(MState::Committed).unwrap();
        assert!(
            s.advance_migration(MState::Streaming).is_err(),
            "states only move forward"
        );
        let v = s.publish_migration().unwrap();
        assert_eq!(v, 4);
        assert!(s.map().handoff.unwrap().published);
        assert!(s.publish_migration().is_err(), "cannot publish twice");
        let v = s.finish_migration().unwrap();
        assert_eq!(v, 5, "finish drops the handoff with a version bump");
        assert!(s.map().handoff.is_none());
        assert!(s.migration().is_none());
    }

    #[test]
    fn migration_abort_releases_lock() {
        let mut s = state();
        let to = ShardId((s.map().owners[0].0 + 1) % 4);
        s.begin_migration(0, to).unwrap();
        let aborted = s.abort_migration().unwrap();
        assert_eq!(aborted.state, MState::Streaming);
        assert!(s.map().handoff.is_none(), "rolled-back abort drops the handoff");
        assert!(s.begin_migration(0, to).is_ok());
    }

    #[test]
    fn abort_after_flip_rolls_the_owner_map_back() {
        let mut s = state();
        let from = s.map().owners[0];
        let to = ShardId((from.0 + 1) % 4);
        s.begin_migration(0, to).unwrap();
        s.commit_migration().unwrap();
        assert_eq!(s.map().owners[0], to);
        s.abort_migration().unwrap();
        assert_eq!(s.map().owners[0], from, "flip must roll back pre-marker");
        assert_eq!(s.mirror(0).unwrap().owners[0], from);
        assert!(s.migration().is_none());
    }

    #[test]
    fn abort_after_commit_marker_never_unflips() {
        let mut s = state();
        let to = ShardId((s.map().owners[0].0 + 1) % 4);
        s.begin_migration(0, to).unwrap();
        s.commit_migration().unwrap();
        s.advance_migration(MState::Committed).unwrap();
        s.abort_migration().unwrap();
        assert_eq!(
            s.map().owners[0],
            to,
            "a committed migration only rolls forward"
        );
        assert!(
            s.map().handoff.is_some(),
            "post-marker abort keeps the handoff: the donor's copies still need filtering"
        );
        assert!(
            s.begin_migration(1, ShardId(2)).is_err(),
            "no new migration until the handoff is reconciled"
        );
    }

    #[test]
    fn splits_avoid_the_migrating_range_and_flip_relocates_by_range() {
        let mut s = state();
        let to = ShardId((s.map().owners[0].0 + 1) % 4);
        let m = s.begin_migration(2, to).unwrap();
        // Splitting the migrating chunk is refused (IM3) ...
        let (lo, hi) = s.map().chunk_range(2);
        assert!(s.split_chunk(2, 2, lo + (hi - lo) / 2).is_err());
        // ... but a split of chunk 0 is fine and shifts indices.
        let (lo0, hi0) = s.map().chunk_range(0);
        assert_eq!(s.split_chunk(2, 0, lo0 + (hi0 - lo0) / 2).unwrap(), VersionCheck::Ok);
        // The flip still lands on the migrated *range*, now at index 3.
        s.commit_migration().unwrap();
        let flipped = s.migration().unwrap();
        assert_eq!(flipped.chunk, 3);
        assert_eq!(s.map().chunk_range(3), m.range);
        assert_eq!(s.map().owners[3], to);
    }

    #[test]
    fn migration_validations() {
        let mut s = state();
        let owner = s.map().owners[0];
        assert!(s.begin_migration(0, owner).is_err()); // same shard
        assert!(s.begin_migration(99, ShardId(1)).is_err()); // no chunk
        assert!(s.begin_migration(0, ShardId(99)).is_err()); // no shard
        assert!(s.commit_migration().is_err()); // nothing in flight
        assert!(s.advance_migration(MState::Committed).is_err());
        assert!(s.finish_migration().is_err());
        // Finishing before the flip is a protocol error.
        s.begin_migration(0, ShardId((owner.0 + 1) % 4)).unwrap();
        assert!(s.finish_migration().is_err());
        assert!(s.migration().is_some(), "failed finish must not drop the lock");
    }
}
