//! `pallas-lint`: offline static analysis for the project's cross-file
//! protocol invariants (ARCHITECTURE.md §8).
//!
//! The repo's correctness story leans on invariants no single file can
//! see: every journal op needs a replay arm *and* a crash test, every
//! wire variant a dispatch arm *and* a reply, every metric literal a
//! catalog entry, every `StoreConfig` field a CLI flag and a docs row.
//! Five rule families machine-check them over a lexed token stream
//! ([`lexer`]) — no `syn`, no build, no network:
//!
//! | rule | invariant |
//! |---|---|
//! | [`journal`]  | journal-op exhaustiveness (encode ↔ replay ↔ crash test) |
//! | [`wire`]     | wire pairing (variant ↔ dispatch arm ↔ reply) |
//! | [`metrics`]  | metric names resolve against one catalog + docs table |
//! | [`knobs`]    | `StoreConfig` field ↔ CLI flag ↔ EXPERIMENTS.md row |
//! | [`panics`]   | no unannotated panic paths; no guard held across send/recv |
//!
//! Rules run over a [`SourceTree`] — a path→content map — so the same
//! code path checks both the real repository (the `rust/tests/lint.rs`
//! driver and the `pallas-lint` binary) and the known-bad fixture
//! trees in each rule's self-tests.

pub mod journal;
pub mod knobs;
pub mod lexer;
pub mod metrics;
pub mod panics;
pub mod wire;

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use lexer::SourceFile;

/// One lint finding, pointing at a repo-relative `file:line`.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Repo-relative path with forward slashes.
    pub file: String,
    /// 1-indexed line (0 when the finding is file-scoped).
    pub line: usize,
    /// Stable rule family name (`journal-op`, `wire-pairing`,
    /// `metrics-registry`, `knob-coverage`, `panic-path`).
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// The analyzed file set: repo-relative path → contents.
///
/// Built either from a repository root ([`SourceTree::from_repo_root`])
/// or assembled by hand for fixture tests ([`SourceTree::add`]). Rust
/// files are lexed once, on first access, and cached.
#[derive(Default)]
pub struct SourceTree {
    files: BTreeMap<String, String>,
    lexed: std::cell::RefCell<BTreeMap<String, std::rc::Rc<SourceFile>>>,
}

impl SourceTree {
    /// Empty tree (fixture tests add files with [`SourceTree::add`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one file. `path` is repo-relative with forward slashes
    /// (e.g. `rust/src/mongo/wire.rs`).
    pub fn add(&mut self, path: &str, content: &str) -> &mut Self {
        self.files.insert(path.to_string(), content.to_string());
        self
    }

    /// Load the lint surface from a repository checkout: every `.rs`
    /// under `rust/src/` and `rust/tests/`, plus the two docs files the
    /// rules cross-check.
    pub fn from_repo_root(root: &Path) -> std::io::Result<Self> {
        let mut tree = Self::new();
        for dir in ["rust/src", "rust/tests"] {
            collect_rs(&root.join(dir), root, &mut tree)?;
        }
        for doc in ["docs/ARCHITECTURE.md", "docs/EXPERIMENTS.md"] {
            if let Ok(content) = std::fs::read_to_string(root.join(doc)) {
                tree.add(doc, &content);
            }
        }
        Ok(tree)
    }

    /// Raw contents of one file, if present.
    pub fn content(&self, path: &str) -> Option<&str> {
        self.files.get(path).map(String::as_str)
    }

    /// Lexed view of one Rust file, if present (cached).
    pub fn lexed(&self, path: &str) -> Option<std::rc::Rc<SourceFile>> {
        if let Some(f) = self.lexed.borrow().get(path) {
            return Some(f.clone());
        }
        let content = self.files.get(path)?;
        let f = std::rc::Rc::new(SourceFile::lex(content));
        self.lexed.borrow_mut().insert(path.to_string(), f.clone());
        Some(f)
    }

    /// Paths matching `prefix` and `suffix` (both may be empty).
    pub fn paths_under<'a>(
        &'a self,
        prefix: &'a str,
        suffix: &'a str,
    ) -> impl Iterator<Item = &'a str> {
        self.files
            .keys()
            .filter(move |p| p.starts_with(prefix) && p.ends_with(suffix))
            .map(String::as_str)
    }
}

fn collect_rs(dir: &Path, root: &Path, tree: &mut SourceTree) -> std::io::Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // absent subtree: nothing to lint
    };
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, root, tree)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            tree.add(&rel, &std::fs::read_to_string(&path)?);
        }
    }
    Ok(())
}

/// Run every rule family over the tree; findings are sorted by file
/// then line so output (and test failures) are deterministic.
pub fn run_all(tree: &SourceTree) -> Vec<Violation> {
    let mut v = Vec::new();
    v.extend(journal::check(tree));
    v.extend(wire::check(tree));
    v.extend(metrics::check(tree));
    v.extend(knobs::check(tree));
    v.extend(panics::check(tree));
    v.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    v
}
