//! Tiny CLI argument parser (clap is not in the vendored set).
//!
//! Supports `binary <subcommand> [--flag value] [--switch] [positional]`.
//! Flags may appear as `--key value` or `--key=value`. Unknown flags are
//! errors; `-h/--help` renders generated usage.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declarative flag spec for help rendering.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub value_hint: Option<&'static str>,
    pub help: &'static str,
}

/// Declarative subcommand spec.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// Parsed invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => Ok(Some(
                s.parse()
                    .map_err(|_| anyhow::anyhow!("flag --{name} expects an integer, got `{s}`"))?,
            )),
        }
    }

    /// Integer flag with a default (batch sizes, flush intervals, ...).
    pub fn get_u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.get_u64(name)?.unwrap_or(default))
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => Ok(Some(
                s.parse()
                    .map_err(|_| anyhow::anyhow!("flag --{name} expects a number, got `{s}`"))?,
            )),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// The CLI definition: subcommands with their flags.
pub struct Cli {
    pub binary: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Parse `argv[1..]`. Returns `Ok(None)` if help was requested (and
    /// printed).
    pub fn parse(&self, argv: &[String]) -> Result<Option<Args>> {
        if argv.is_empty() || argv[0] == "-h" || argv[0] == "--help" || argv[0] == "help" {
            println!("{}", self.usage());
            return Ok(None);
        }
        let command = argv[0].clone();
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == command)
            .ok_or_else(|| {
                anyhow::anyhow!("unknown subcommand `{command}`\n\n{}", self.usage())
            })?;

        let mut args = Args {
            command: command.clone(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "-h" || tok == "--help" {
                println!("{}", self.command_usage(spec));
                return Ok(None);
            }
            if let Some(flag) = tok.strip_prefix("--") {
                let (name, inline_val) = match flag.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (flag.to_string(), None),
                };
                let fs = spec.flags.iter().find(|f| f.name == name).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown flag --{name} for `{command}`\n\n{}",
                        self.command_usage(spec)
                    )
                })?;
                match (fs.value_hint.is_some(), inline_val) {
                    (true, Some(v)) => {
                        args.flags.insert(name, v);
                    }
                    (true, None) => {
                        i += 1;
                        if i >= argv.len() {
                            bail!("flag --{name} expects a value");
                        }
                        args.flags.insert(name, argv[i].clone());
                    }
                    (false, None) => args.switches.push(name),
                    (false, Some(_)) => bail!("switch --{name} takes no value"),
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Some(args))
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n",
            self.binary, self.about, self.binary);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str("\nRun `<command> --help` for flags.");
        out
    }

    fn command_usage(&self, spec: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n\nFLAGS:\n", self.binary, spec.name, spec.about);
        for f in &spec.flags {
            let lhs = match f.value_hint {
                Some(hint) => format!("--{} <{}>", f.name, hint),
                None => format!("--{}", f.name),
            };
            out.push_str(&format!("  {lhs:<28} {}\n", f.help));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            binary: "hpcstore",
            about: "test",
            commands: vec![CommandSpec {
                name: "ingest",
                about: "run ingest",
                flags: vec![
                    FlagSpec { name: "nodes", value_hint: Some("N"), help: "node count" },
                    FlagSpec { name: "days", value_hint: Some("D"), help: "days" },
                    FlagSpec { name: "verbose", value_hint: None, help: "chatty" },
                ],
            }],
        }
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = cli()
            .parse(&sv(&["ingest", "--nodes", "32", "--days=3.5", "--verbose", "pos1"]))
            .unwrap()
            .unwrap();
        assert_eq!(a.command, "ingest");
        assert_eq!(a.get_u64("nodes").unwrap(), Some(32));
        assert_eq!(a.get_u64_or("nodes", 1).unwrap(), 32);
        assert_eq!(a.get_u64_or("absent", 7).unwrap(), 7);
        assert_eq!(a.get_f64("days").unwrap(), Some(3.5));
        assert!(a.has_switch("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_command_and_flag_error() {
        assert!(cli().parse(&sv(&["nope"])).is_err());
        assert!(cli().parse(&sv(&["ingest", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&sv(&["ingest", "--nodes"])).is_err());
        assert!(cli().parse(&sv(&["ingest", "--verbose=x"])).is_err());
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = cli().parse(&sv(&["ingest", "--nodes", "abc"])).unwrap().unwrap();
        assert!(a.get_u64("nodes").is_err());
    }

    #[test]
    fn help_returns_none() {
        assert!(cli().parse(&sv(&["--help"])).unwrap().is_none());
        assert!(cli().parse(&sv(&["ingest", "--help"])).unwrap().is_none());
        assert!(cli().parse(&sv(&[])).unwrap().is_none());
    }
}
