//! A from-scratch sharded document store (the paper's "MongoDB").
//!
//! Role topology mirrors a sharded MongoDB cluster (paper §3.1):
//!
//! * [`sharding::config_server`] — cluster metadata: shard registry and
//!   the versioned chunk table ("the list of chunks on every shard and
//!   the ranges that define the chunks").
//! * [`server::shard`] — shard servers: each holds a subset of the
//!   sharded data in a WiredTiger-like storage engine
//!   ([`storage::engine`]) with secondary indexes ([`storage::index`]),
//!   journaling to its assigned Lustre directory.
//! * [`server::router`] — `mongos` routers: "the only interface to a
//!   sharded cluster from the perspective of applications"; they
//!   partition `insertMany` batches with the AOT route kernel and
//!   scatter/gather `find`s.
//!
//! [`client`] is the pymongo-analogue the run-script workloads use.

pub mod aggregate;
pub mod bson;
pub mod client;
pub mod cluster;
pub mod query;
pub mod server;
pub mod sharding;
pub mod storage;
pub mod wire;

pub use aggregate::{AccOp, AggPipeline};
pub use bson::{Document, Value};
pub use client::{BulkWriter, MongoClient};
pub use cluster::Cluster;
pub use query::Filter;
