//! Balancer policy: keep chunk counts even across shards.
//!
//! MongoDB's balancer moves chunks from the most-loaded to the
//! least-loaded shard while the spread exceeds a threshold. The policy
//! here is pure (list of proposed moves); the cluster layer executes the
//! moves through the config server's migration protocol one at a time.

use crate::util::ids::ShardId;

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BalancerPolicy {
    /// Start balancing when `max - min` chunk counts exceed this.
    pub threshold: u32,
    /// Max moves proposed per round (migrations serialize; keep rounds
    /// short).
    pub max_moves_per_round: usize,
}

impl Default for BalancerPolicy {
    fn default() -> Self {
        Self { threshold: 2, max_moves_per_round: 4 }
    }
}

/// A proposed move of one chunk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProposedMove {
    pub chunk: usize,
    pub from: ShardId,
    pub to: ShardId,
}

/// Plan moves given the chunk→owner table.
///
/// Greedy: while spread > threshold, move one chunk from the current
/// max shard to the current min shard. Deterministic (lowest-index chunk
/// of the donor moves first).
pub fn plan_moves(owners: &[ShardId], num_shards: usize, policy: BalancerPolicy) -> Vec<ProposedMove> {
    let mut counts = vec![0i64; num_shards];
    for o in owners {
        counts[o.index()] += 1;
    }
    // Donor chunk queue per shard (ascending chunk index).
    let mut chunks_of: Vec<Vec<usize>> = vec![Vec::new(); num_shards];
    for (idx, o) in owners.iter().enumerate() {
        chunks_of[o.index()].push(idx);
    }
    let mut moves = Vec::new();
    let mut moved: std::collections::BTreeSet<usize> = Default::default();
    while moves.len() < policy.max_moves_per_round {
        let (max_s, &max_c) = counts
            .iter()
            .enumerate()
            .max_by_key(|(i, c)| (**c, usize::MAX - i))
            .unwrap();
        let (min_s, &min_c) = counts
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (**c, *i))
            .unwrap();
        if max_c - min_c <= policy.threshold as i64 {
            break;
        }
        // First not-yet-moved chunk of the donor.
        let Some(&chunk) = chunks_of[max_s].iter().find(|c| !moved.contains(c)) else {
            break;
        };
        moved.insert(chunk);
        counts[max_s] -= 1;
        counts[min_s] += 1;
        moves.push(ProposedMove {
            chunk,
            from: ShardId(max_s as u32),
            to: ShardId(min_s as u32),
        });
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    fn owners(counts: &[u32]) -> Vec<ShardId> {
        let mut v = Vec::new();
        for (s, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                v.push(ShardId(s as u32));
            }
        }
        v
    }

    #[test]
    fn balanced_cluster_proposes_nothing() {
        let o = owners(&[3, 3, 3, 4]);
        assert!(plan_moves(&o, 4, BalancerPolicy::default()).is_empty());
    }

    #[test]
    fn skewed_cluster_moves_from_max_to_min() {
        let o = owners(&[8, 1, 3]);
        let moves = plan_moves(&o, 3, BalancerPolicy::default());
        assert!(!moves.is_empty());
        assert_eq!(moves[0].from, ShardId(0));
        assert_eq!(moves[0].to, ShardId(1));
        // Simulate and verify spread shrinks monotonically.
        let mut counts = [8i64, 1, 3];
        for m in &moves {
            counts[m.from.index()] -= 1;
            counts[m.to.index()] += 1;
        }
        let spread = counts.iter().max().unwrap() - counts.iter().min().unwrap();
        assert!(spread <= 8 - 1 - moves.len() as i64 + moves.len() as i64); // sanity
        assert!(spread < 7);
    }

    #[test]
    fn respects_move_cap() {
        let o = owners(&[20, 0]);
        let policy = BalancerPolicy { threshold: 2, max_moves_per_round: 3 };
        let moves = plan_moves(&o, 2, policy);
        assert_eq!(moves.len(), 3);
        // Distinct chunks each time.
        let set: std::collections::BTreeSet<_> = moves.iter().map(|m| m.chunk).collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn empty_shard_receives_first() {
        let o = owners(&[4, 4, 0]);
        let moves = plan_moves(&o, 3, BalancerPolicy { threshold: 1, max_moves_per_round: 8 });
        assert!(moves.iter().all(|m| m.to == ShardId(2)));
    }

    #[test]
    fn deterministic_plans() {
        let o = owners(&[9, 2, 5, 0]);
        let a = plan_moves(&o, 4, BalancerPolicy::default());
        let b = plan_moves(&o, 4, BalancerPolicy::default());
        assert_eq!(a, b);
    }

    #[test]
    fn convergence_property() {
        use crate::testing::check;
        use crate::util::rng::Pcg32;
        check(
            "balancer-converges",
            &(|rng: &mut Pcg32| {
                let shards = 2 + rng.next_bounded(8) as usize;
                let counts: Vec<u32> = (0..shards).map(|_| rng.next_bounded(20)).collect();
                counts
            }),
            |counts| {
                let shards = counts.len();
                let mut o = owners(counts);
                let policy = BalancerPolicy { threshold: 2, max_moves_per_round: 64 };
                // Apply rounds until fixpoint; must converge quickly.
                for _ in 0..50 {
                    let moves = plan_moves(&o, shards, policy);
                    if moves.is_empty() {
                        // Spread must now be within threshold.
                        let mut c = vec![0i64; shards];
                        for s in &o {
                            c[s.index()] += 1;
                        }
                        let spread = c.iter().max().unwrap() - c.iter().min().unwrap();
                        return if spread <= 2 + 1 {
                            Ok(())
                        } else {
                            Err(format!("converged with spread {spread}"))
                        };
                    }
                    // Execute moves by reassigning owners (chunk indices
                    // here index into `o`).
                    for m in moves {
                        o[m.chunk] = m.to;
                    }
                }
                Err("did not converge in 50 rounds".into())
            },
        );
    }
}
