//! Wall-clock vs virtual-clock abstraction.
//!
//! Live components time themselves with [`SystemClock`]; the DES and unit
//! tests drive a [`ManualClock`]. All times are nanoseconds since an
//! arbitrary epoch (process start for the system clock).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Nanosecond time source.
pub trait Clock: Send + Sync {
    /// Nanoseconds since the clock's epoch.
    fn now_ns(&self) -> u64;

    fn now_us(&self) -> u64 {
        self.now_ns() / 1_000
    }

    fn now_ms(&self) -> u64 {
        self.now_ns() / 1_000_000
    }
}

/// Monotonic wall clock anchored at construction.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }
}

/// Manually advanced clock (tests, DES).
#[derive(Clone, Default)]
pub struct ManualClock {
    ns: Arc<AtomicU64>,
}

impl ManualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance_ns(&self, d: u64) {
        self.ns.fetch_add(d, Ordering::SeqCst);
    }

    pub fn set_ns(&self, t: u64) {
        self.ns.store(t, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance_ns(1_500);
        assert_eq!(c.now_ns(), 1_500);
        assert_eq!(c.now_us(), 1);
        c.set_ns(2_000_000);
        assert_eq!(c.now_ms(), 2);
    }

    #[test]
    fn manual_clock_shared_between_clones() {
        let c = ManualClock::new();
        let c2 = c.clone();
        c.advance_ns(10);
        assert_eq!(c2.now_ns(), 10);
    }
}
