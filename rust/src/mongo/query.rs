//! Query model: filter AST, matcher, find options, and index-bound
//! extraction for the planner.
//!
//! Covers the operators the paper's workload needs (`$eq $ne $gt $gte
//! $lt $lte $in $and $or`) over the total value order defined in
//! [`Value::cmp_total`].

use std::cmp::Ordering;

use super::bson::{Document, RawDoc, Value};

/// Comparison operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Gt,
    Gte,
    Lt,
    Lte,
}

impl CmpOp {
    fn eval(self, ord: Ordering) -> bool {
        match self {
            CmpOp::Eq => ord == Ordering::Equal,
            CmpOp::Ne => ord != Ordering::Equal,
            CmpOp::Gt => ord == Ordering::Greater,
            CmpOp::Gte => ord != Ordering::Less,
            CmpOp::Lt => ord == Ordering::Less,
            CmpOp::Lte => ord != Ordering::Greater,
        }
    }
}

/// Filter AST.
#[derive(Clone, Debug, PartialEq)]
pub enum Filter {
    /// Matches everything (empty filter `{}`).
    True,
    /// `{field: {$op: value}}`
    Cmp { field: String, op: CmpOp, value: Value },
    /// `{field: {$in: [values]}}`
    In { field: String, values: Vec<Value> },
    /// `{$and: [...]}` — also the implicit conjunction form.
    And(Vec<Filter>),
    /// `{$or: [...]}`
    Or(Vec<Filter>),
}

impl Default for Filter {
    /// The empty filter `{}`.
    fn default() -> Self {
        Filter::True
    }
}

impl Filter {
    /// `{field: value}` equality shorthand.
    pub fn eq(field: &str, value: impl Into<Value>) -> Filter {
        Filter::Cmp { field: field.into(), op: CmpOp::Eq, value: value.into() }
    }

    pub fn cmp(field: &str, op: CmpOp, value: impl Into<Value>) -> Filter {
        Filter::Cmp { field: field.into(), op, value: value.into() }
    }

    /// Half-open range `lo <= field < hi` (the paper's timestamp
    /// condition).
    pub fn range(field: &str, lo: impl Into<Value>, hi: impl Into<Value>) -> Filter {
        Filter::And(vec![
            Filter::cmp(field, CmpOp::Gte, lo),
            Filter::cmp(field, CmpOp::Lt, hi),
        ])
    }

    pub fn is_in(field: &str, values: Vec<Value>) -> Filter {
        Filter::In { field: field.into(), values }
    }

    pub fn and(filters: Vec<Filter>) -> Filter {
        Filter::And(filters)
    }

    /// Does `doc` satisfy this filter? Missing fields never match a
    /// comparison (Mongo-style for the operators we support).
    pub fn matches(&self, doc: &Document) -> bool {
        match self {
            Filter::True => true,
            Filter::Cmp { field, op, value } => match doc.get(field) {
                Some(v) if v.type_rank() == value.type_rank() => {
                    op.eval(v.cmp_total(value))
                }
                Some(v) => {
                    // Cross-class comparison only meaningful for $ne.
                    *op == CmpOp::Ne && v.cmp_total(value) != Ordering::Equal
                }
                None => false,
            },
            Filter::In { field, values } => match doc.get(field) {
                Some(v) => values.iter().any(|w| v.cmp_total(w) == Ordering::Equal),
                None => false,
            },
            Filter::And(fs) => fs.iter().all(|f| f.matches(doc)),
            Filter::Or(fs) => fs.iter().any(|f| f.matches(doc)),
        }
    }

    /// [`Filter::matches`] evaluated against the *encoded* record
    /// bytes: fields are sought with [`RawDoc::get`] (a skip-scan), so
    /// a rejected candidate costs no allocation and no full
    /// [`Document`] decode. Agrees with `matches` on every
    /// document/filter pair — sealed by the differential property test
    /// `raw_matcher_agrees_with_decoded_matcher` below.
    pub fn matches_raw(&self, doc: &RawDoc) -> bool {
        match self {
            Filter::True => true,
            Filter::Cmp { field, op, value } => match doc.get(field) {
                Some(v) if v.type_rank() == value.type_rank() => {
                    op.eval(v.cmp_total(value))
                }
                Some(v) => {
                    // Cross-class comparison only meaningful for $ne.
                    *op == CmpOp::Ne && v.cmp_total(value) != Ordering::Equal
                }
                None => false,
            },
            Filter::In { field, values } => match doc.get(field) {
                Some(v) => values.iter().any(|w| v.cmp_total(w) == Ordering::Equal),
                None => false,
            },
            Filter::And(fs) => fs.iter().all(|f| f.matches_raw(doc)),
            Filter::Or(fs) => fs.iter().any(|f| f.matches_raw(doc)),
        }
    }

    /// Extract a single-field range bound `[lo, hi)` usable by an index
    /// scan, if this filter (or a conjunct of it) constrains `field`.
    ///
    /// Returns `(lo, hi)` where `None` means unbounded on that side.
    /// Conservative: `$or`/`$in` terms yield no single range (the planner
    /// handles `$in` separately via point lookups).
    pub fn index_range(&self, field: &str) -> Option<(Option<Value>, Option<Value>)> {
        fn merge(
            acc: &mut (Option<Value>, Option<Value>),
            op: CmpOp,
            value: &Value,
        ) {
            match op {
                // lo is inclusive: $gt v tightens to v + ulp — we keep v
                // and let the residual filter drop equal keys.
                CmpOp::Gte | CmpOp::Gt => {
                    let tighter = match &acc.0 {
                        None => true,
                        Some(cur) => value.cmp_total(cur) == Ordering::Greater,
                    };
                    if tighter {
                        acc.0 = Some(value.clone());
                    }
                }
                CmpOp::Lt | CmpOp::Lte => {
                    let tighter = match &acc.1 {
                        None => true,
                        Some(cur) => value.cmp_total(cur) == Ordering::Less,
                    };
                    if tighter {
                        acc.1 = Some(value.clone());
                    }
                }
                CmpOp::Eq => {
                    acc.0 = Some(value.clone());
                    acc.1 = Some(value.clone());
                }
                CmpOp::Ne => {}
            }
        }
        let mut acc = (None, None);
        let mut constrained = false;
        match self {
            Filter::Cmp { field: f, op, value } if f == field && *op != CmpOp::Ne => {
                merge(&mut acc, *op, value);
                constrained = true;
            }
            Filter::And(fs) => {
                for f in fs {
                    if let Filter::Cmp { field: ff, op, value } = f {
                        if ff == field && *op != CmpOp::Ne {
                            merge(&mut acc, *op, value);
                            constrained = true;
                        }
                    }
                }
            }
            _ => {}
        }
        constrained.then_some(acc)
    }

    /// The `$in` value list for `field`, if this filter (or a top-level
    /// conjunct) has one.
    pub fn in_values(&self, field: &str) -> Option<&[Value]> {
        match self {
            Filter::In { field: f, values } if f == field => Some(values),
            Filter::And(fs) => fs.iter().find_map(|f| match f {
                Filter::In { field: ff, values } if ff == field => Some(values.as_slice()),
                _ => None,
            }),
            _ => None,
        }
    }

    /// Wire-size estimate for transport accounting.
    pub fn encoded_len(&self) -> usize {
        match self {
            Filter::True => 1,
            Filter::Cmp { field, value, .. } => 2 + field.len() + 9 + value_size(value),
            Filter::In { field, values } => {
                2 + field.len() + values.iter().map(value_size).sum::<usize>()
            }
            Filter::And(fs) | Filter::Or(fs) => {
                1 + fs.iter().map(Filter::encoded_len).sum::<usize>()
            }
        }
    }
}

fn value_size(v: &Value) -> usize {
    match v {
        Value::Null => 1,
        Value::Bool(_) => 2,
        Value::Int(_) | Value::F64(_) => 9,
        Value::Str(s) => 5 + s.len(),
        Value::Array(items) => 3 + items.iter().map(value_size).sum::<usize>(),
        Value::Doc(d) => d.encoded_len(),
    }
}

/// Sort direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SortDir {
    Asc,
    Desc,
}

/// Options for `find`.
#[derive(Clone, Debug, Default)]
pub struct FindOptions {
    pub projection: Option<Vec<String>>,
    pub sort: Option<(String, SortDir)>,
    pub limit: Option<usize>,
    pub batch_size: Option<usize>,
}

impl FindOptions {
    pub fn limit(mut self, n: usize) -> Self {
        self.limit = Some(n);
        self
    }

    pub fn project(mut self, fields: &[&str]) -> Self {
        self.projection = Some(fields.iter().map(|s| s.to_string()).collect());
        self
    }

    pub fn sort(mut self, field: &str, dir: SortDir) -> Self {
        self.sort = Some((field.to_string(), dir));
        self
    }

    pub fn batch_size(mut self, n: usize) -> Self {
        self.batch_size = Some(n);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node).set("m0", 1.5)
    }

    #[test]
    fn comparison_operators() {
        let d = doc(100, 7);
        assert!(Filter::eq("node_id", 7i64).matches(&d));
        assert!(!Filter::eq("node_id", 8i64).matches(&d));
        assert!(Filter::cmp("ts", CmpOp::Gte, 100i64).matches(&d));
        assert!(!Filter::cmp("ts", CmpOp::Gt, 100i64).matches(&d));
        assert!(Filter::cmp("ts", CmpOp::Lt, 101i64).matches(&d));
        assert!(Filter::cmp("ts", CmpOp::Ne, 99i64).matches(&d));
        assert!(!Filter::cmp("missing", CmpOp::Eq, 1i64).matches(&d));
    }

    #[test]
    fn range_is_half_open() {
        let f = Filter::range("ts", 100i64, 200i64);
        assert!(f.matches(&doc(100, 1)));
        assert!(f.matches(&doc(199, 1)));
        assert!(!f.matches(&doc(200, 1)));
        assert!(!f.matches(&doc(99, 1)));
    }

    #[test]
    fn in_and_or() {
        let f = Filter::is_in("node_id", vec![Value::Int(1), Value::Int(3)]);
        assert!(f.matches(&doc(0, 1)));
        assert!(f.matches(&doc(0, 3)));
        assert!(!f.matches(&doc(0, 2)));

        let f = Filter::Or(vec![Filter::eq("node_id", 9i64), Filter::eq("ts", 5i64)]);
        assert!(f.matches(&doc(5, 0)));
        assert!(f.matches(&doc(0, 9)));
        assert!(!f.matches(&doc(1, 1)));
    }

    #[test]
    fn the_papers_query_shape() {
        // find({node_id: {$in: jobs_nodes}, ts: {$gte: t0, $lt: t1}})
        let f = Filter::and(vec![
            Filter::is_in("node_id", vec![Value::Int(4), Value::Int(5)]),
            Filter::cmp("ts", CmpOp::Gte, 1000i64),
            Filter::cmp("ts", CmpOp::Lt, 2000i64),
        ]);
        assert!(f.matches(&doc(1500, 4)));
        assert!(!f.matches(&doc(2500, 4)));
        assert!(!f.matches(&doc(1500, 6)));
        // Planner hooks:
        let (lo, hi) = f.index_range("ts").unwrap();
        assert_eq!(lo, Some(Value::Int(1000)));
        assert_eq!(hi, Some(Value::Int(2000)));
        assert_eq!(f.in_values("node_id").unwrap().len(), 2);
    }

    #[test]
    fn index_range_extraction() {
        let f = Filter::eq("a", 5i64);
        let (lo, hi) = f.index_range("a").unwrap();
        assert_eq!(lo, hi);
        assert!(f.index_range("b").is_none());

        // Tightest bounds win.
        let f = Filter::and(vec![
            Filter::cmp("x", CmpOp::Gte, 10i64),
            Filter::cmp("x", CmpOp::Gte, 20i64),
            Filter::cmp("x", CmpOp::Lt, 100i64),
            Filter::cmp("x", CmpOp::Lte, 90i64),
        ]);
        let (lo, hi) = f.index_range("x").unwrap();
        assert_eq!(lo, Some(Value::Int(20)));
        assert_eq!(hi, Some(Value::Int(90)));

        // $or yields nothing.
        assert!(Filter::Or(vec![Filter::eq("x", 1i64)]).index_range("x").is_none());
    }

    #[test]
    fn cross_type_never_matches_cmp() {
        let d = Document::new().set("v", "abc");
        assert!(!Filter::cmp("v", CmpOp::Gt, 5i64).matches(&d));
        assert!(Filter::cmp("v", CmpOp::Ne, 5i64).matches(&d));
    }

    #[test]
    fn true_matches_everything() {
        assert!(Filter::True.matches(&Document::new()));
    }

    #[test]
    fn raw_matcher_matches_the_papers_shape() {
        let f = Filter::and(vec![
            Filter::is_in("node_id", vec![Value::Int(4), Value::Int(5)]),
            Filter::cmp("ts", CmpOp::Gte, 1000i64),
            Filter::cmp("ts", CmpOp::Lt, 2000i64),
        ]);
        for (ts, node, want) in
            [(1500, 4, true), (2500, 4, false), (1500, 6, false), (2000, 5, false)]
        {
            let enc = doc(ts, node).encode();
            assert_eq!(f.matches_raw(&RawDoc::new(&enc)), want, "ts={ts} node={node}");
        }
    }

    /// Differential property: the raw-bytes evaluator and the decoded
    /// matcher must agree on randomized document/filter pairs covering
    /// every operator, type class (incl. containers), missing fields,
    /// and cross-class comparisons.
    #[test]
    fn raw_matcher_agrees_with_decoded_matcher() {
        use crate::testing::{check_with, gens, Gen};
        use crate::util::rng::Pcg32;

        const FIELDS: [&str; 5] = ["ts", "node_id", "name", "load", "extra"];

        fn rand_value(rng: &mut Pcg32, depth: u32) -> Value {
            match rng.next_bounded(if depth == 0 { 7 } else { 5 }) {
                0 => Value::Null,
                1 => Value::Bool(rng.next_bounded(2) == 1),
                2 => Value::Int(rng.next_bounded(20) as i64 - 10),
                3 => Value::F64((rng.next_f64() - 0.5) * 8.0),
                4 => Value::Str(gens::ident(4).generate(rng)),
                5 => Value::Array(
                    (0..rng.next_bounded(3)).map(|_| rand_value(rng, depth + 1)).collect(),
                ),
                _ => {
                    let mut d = Document::new();
                    for i in 0..rng.next_bounded(3) {
                        d.put(&format!("k{i}"), rand_value(rng, depth + 1));
                    }
                    Value::Doc(d)
                }
            }
        }

        fn rand_doc(rng: &mut Pcg32) -> Document {
            let mut d = Document::new();
            for f in FIELDS {
                // Leave some fields missing so absent-field semantics
                // are exercised.
                if rng.next_bounded(4) > 0 {
                    d.put(f, rand_value(rng, 0));
                }
            }
            d
        }

        fn rand_filter(rng: &mut Pcg32, depth: u32) -> Filter {
            let field = FIELDS[rng.next_bounded(FIELDS.len() as u32) as usize];
            match rng.next_bounded(if depth == 0 { 9 } else { 7 }) {
                0 => Filter::True,
                1 => Filter::cmp(field, CmpOp::Eq, rand_value(rng, 1)),
                2 => Filter::cmp(field, CmpOp::Ne, rand_value(rng, 1)),
                3 => Filter::cmp(field, CmpOp::Gt, rand_value(rng, 1)),
                4 => Filter::cmp(field, CmpOp::Gte, rand_value(rng, 1)),
                5 => Filter::cmp(field, CmpOp::Lt, rand_value(rng, 1)),
                6 => Filter::cmp(field, CmpOp::Lte, rand_value(rng, 1)),
                7 => Filter::Or(
                    (0..1 + rng.next_bounded(3))
                        .map(|_| rand_filter(rng, depth + 1))
                        .collect(),
                ),
                _ => Filter::is_in(
                    field,
                    (0..rng.next_bounded(4)).map(|_| rand_value(rng, 1)).collect(),
                ),
            }
        }

        check_with(
            "raw-matcher-differential",
            0xBEEF,
            512,
            &(|rng: &mut Pcg32| {
                let doc = rand_doc(rng);
                let conjuncts = (1..=1 + rng.next_bounded(3))
                    .map(|_| rand_filter(rng, 0))
                    .collect();
                (doc, Filter::And(conjuncts))
            }),
            |(doc, filter)| {
                let enc = doc.encode();
                let decoded = filter.matches(doc);
                let raw = filter.matches_raw(&RawDoc::new(&enc));
                if decoded == raw {
                    Ok(())
                } else {
                    Err(format!("decoded {decoded} != raw {raw}"))
                }
            },
        );
    }
}
