//! FF — replica-set failover: unavailability window vs election timeout,
//! and zero-loss `w:majority` acknowledgement under a primary kill.
//!
//! The claim under test is the tentpole of the replica-set refactor: a
//! `w:majority`-acknowledged write survives the death of the primary
//! that accepted it, and the write outage a client sees is bounded by
//! the election timeout, not by any human intervention. Rows run a live
//! one-shard, three-member cluster with a background client inserting
//! uniquely-numbered documents at `w:majority`; mid-stream the current
//! primary is killed (its event loop exits without handoff, exactly
//! like a crashed mongod). The `insert max` column is the stall that
//! client actually rode through — router-side `NotPrimary` retries with
//! jittered backoff until a secondary wins the election and starts
//! acking again.
//!
//! After the drill every acknowledged `ts` is read back and must appear
//! **exactly once** (zero loss — invariant IR3 — and no double-apply —
//! invariant IR4); unacknowledged documents may appear at most once
//! (the router never blind-resends an ambiguous write).
//!
//! The second table is the DES axis at paper scale
//! (`SimSpec::{replicas, write_concern}`): what majority acknowledgement
//! costs in ingest throughput versus `w:1`'s background replication.
//!
//! Run: `cargo bench --bench fig_failover` (add `--quick` for one row).
//! See `docs/EXPERIMENTS.md` for the recorded-results template.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WriteConcern;
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::{Filter, FindOptions};
use hpcstore::mongo::storage::LocalDir;
use hpcstore::mongo::wire::{rpc, ShardRequest};
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_count;

fn doc(ts: i64) -> Document {
    Document::new()
        .set("ts", ts)
        .set("node_id", ts % 17)
        .set("m0", ts as f64 * 0.5)
}

/// Poll the members of one shard until one reports the primary role.
fn find_primary(cluster: &Cluster, shard: usize, deadline: Duration) -> usize {
    let t = Instant::now();
    loop {
        for (m, tx) in cluster.member_mailboxes(shard).iter().enumerate() {
            if let Ok(info) = rpc(tx, |reply| ShardRequest::RoleInfo { reply }) {
                if info.role == "primary" {
                    return m;
                }
            }
        }
        assert!(
            t.elapsed() < deadline,
            "no member of shard {shard} became primary within {deadline:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn main() {
    let probe_batch: usize = if quick_mode() { 20 } else { 40 };
    // Sweep the election timeout: the failover window a client rides
    // through should track it (detection + randomized candidacy delay),
    // not some fixed recovery constant.
    let timeouts: &[u64] = if quick_mode() { &[150] } else { &[300, 150, 80] };

    let mut report = Report::new(
        "Failover — w:majority under a primary kill (live 1-shard × 3-member cluster)",
    );
    report.set_custom(
        [
            "election ms",
            "acked docs",
            "failed batches",
            "insert mean",
            "insert max",
            "elections",
            "acked readback",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &election_ms in timeouts {
        let mut spec = ClusterSpec::small(1, 1);
        spec.store.replicas = 3;
        spec.store.write_concern = WriteConcern::Majority;
        spec.store.election_timeout_ms = election_ms;
        spec.store.heartbeat_ms = (election_ms / 5).max(10);
        // The writer must ride through one full failover inside a single
        // insertMany call: give the router retry loop generous headroom.
        spec.store.write_retry_ms = 10_000;
        let label_dir = format!("figfail-{election_ms}");
        let cluster = Cluster::start(
            spec,
            move |sid| Ok(Box::new(LocalDir::temp(&format!("{label_dir}-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();

        let primary = find_primary(&cluster, 0, Duration::from_secs(5));

        // Background client: w:majority inserts with unique increasing
        // ts. A failed batch is recorded and *abandoned* — fresh ts only,
        // never a blind resend of an ambiguous write — so "acked" below
        // is exactly the set the cluster promised to keep.
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let stop = stop.clone();
            let c = cluster.client();
            std::thread::spawn(move || -> (Vec<f64>, Vec<i64>, usize) {
                let (mut lat, mut acked, mut failed) = (Vec::new(), Vec::new(), 0usize);
                let mut ts = 0i64;
                while !stop.load(Ordering::Relaxed) {
                    let batch: Vec<Document> =
                        (0..probe_batch as i64).map(|i| doc(ts + i)).collect();
                    let t = Instant::now();
                    match c.insert_many(batch) {
                        Ok(_) => {
                            lat.push(t.elapsed().as_nanos() as f64);
                            acked.extend(ts..ts + probe_batch as i64);
                        }
                        Err(_) => failed += 1,
                    }
                    ts += probe_batch as i64;
                }
                (lat, acked, failed)
            })
        };

        // Let the writer establish a baseline, then kill the primary
        // mid-stream and keep writing through the election.
        std::thread::sleep(Duration::from_millis(400));
        cluster.kill_member(0, primary);
        std::thread::sleep(Duration::from_millis(4 * election_ms.max(200)));
        stop.store(true, Ordering::Relaxed);
        let (lat, acked, failed) = writer.join().unwrap();
        let mean = lat.iter().sum::<f64>() / lat.len().max(1) as f64;
        let max = lat.iter().cloned().fold(0.0f64, f64::max);

        // A new primary must exist among the survivors, and the kill
        // must have forced at least one real election beyond bootstrap.
        let new_primary = find_primary(&cluster, 0, Duration::from_secs(5));
        assert_ne!(new_primary, primary, "the killed member cannot be primary");
        let elections = cluster.metrics().counter("shard.elections").get();

        // Let the commit index propagate to the surviving secondary so
        // the readback below is member-independent, then tally every ts.
        std::thread::sleep(Duration::from_millis(300));
        let mut counts: HashMap<i64, u32> = HashMap::new();
        let mut cursor = cluster
            .client()
            .find(Filter::True, FindOptions::default())
            .unwrap();
        for d in cursor.by_ref() {
            let ts = d.get("ts").and_then(Value::as_i64).unwrap();
            *counts.entry(ts).or_insert(0) += 1;
        }
        assert!(
            cursor.error().is_none(),
            "readback must drain cleanly: {:?}",
            cursor.error()
        );
        for ts in &acked {
            assert_eq!(
                counts.get(ts).copied().unwrap_or(0),
                1,
                "w:majority-acked ts {ts} must survive failover exactly once"
            );
        }
        for (ts, n) in &counts {
            assert_eq!(*n, 1, "ts {ts} applied {n} times — double-apply");
        }

        report.add_row(vec![
            election_ms.to_string(),
            human_count(acked.len() as u64),
            failed.to_string(),
            format!("{:.2} ms", mean / 1e6),
            format!("{:.2} ms", max / 1e6),
            elections.to_string(),
            "exactly-once".into(),
        ]);
        cluster.shutdown();
    }
    report.print();
    println!(
        "\nclaim: every w:majority-acked write survives the primary's death \
         (exactly-once readback), and the insert stall a client rides through \
         tracks the election timeout\n"
    );

    // --- DES axis: what majority acknowledgement costs at paper scale. ---
    let cost = CostModel::default().with_network_floor();
    let axes: &[(u32, WriteConcern, &str)] = &[
        (1, WriteConcern::Majority, "1 (no replication)"),
        (3, WriteConcern::One, "3, w:1"),
        (3, WriteConcern::Majority, "3, w:majority"),
    ];
    let mut report = Report::new("Failover — DES replication axis (32-node preset)");
    report.set_custom(
        ["replicas / wc", "ingest virt s", "docs/s"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &(replicas, wc, label) in axes {
        let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
        spec.monitored_nodes = 256;
        spec.replicas = replicas;
        spec.write_concern = wc;
        let r = ClusterSim::new(spec).run();
        report.add_row(vec![
            label.to_string(),
            format!("{:.1}", r.ingest_virt_ns as f64 / 1e9),
            human_count(r.docs_per_sec as u64),
        ]);
    }
    report.print();
    println!(
        "\nclaim: w:majority trades ingest throughput for the zero-loss \
         guarantee above; w:1 keeps replication off the ack path as \
         background utilization\n"
    );
}
