//! Rule: **wire pairing** (invariant I3).
//!
//! Every request enum variant (`ShardRequest` / `ConfigRequest` in
//! `mongo/wire.rs`, `RouterRequest` in `mongo/server/router.rs`) is a
//! protocol message, and a message nobody dispatches is a hang: the
//! sender blocks on a reply channel whose sender side was dropped.
//! For every variant of every `*Request` enum this rule requires, in
//! non-test `rust/src/mongo/**` code:
//!
//! 1. a **dispatch arm** — `Enum::Variant ... =>` in some match (a
//!    variant swallowed by a `_ =>` wildcard does not count, and any
//!    wildcard arm in a match that dispatches request variants is
//!    itself flagged: it would silently absorb the *next* variant
//!    someone adds);
//! 2. a **reply counterpart** — a `reply:` field in the variant, or an
//!    explicit `// lint: allow(no_reply, <reason>)` annotation for
//!    genuinely one-way messages (map pushes, shutdown).

use super::lexer::TokKind;
use super::{SourceTree, Violation};

const RULE: &str = "wire-pairing";
const ENUM_FILES: &[&str] =
    &["rust/src/mongo/wire.rs", "rust/src/mongo/server/router.rs"];

struct Variant {
    enum_name: String,
    name: String,
    file: String,
    line: usize,
    has_reply: bool,
}

pub fn check(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut variants: Vec<Variant> = Vec::new();
    for &path in ENUM_FILES {
        if tree.lexed(path).is_some() {
            collect_variants(tree, path, &mut variants);
        }
    }
    let enum_names: Vec<&str> =
        variants.iter().map(|v| v.enum_name.as_str()).collect();

    // Scan every non-test mongo file once for dispatch arms and
    // wildcard arms inside request-dispatch matches.
    let mut dispatched: Vec<(String, String)> = Vec::new(); // (enum, variant)
    for path in tree.paths_under("rust/src/mongo/", ".rs") {
        let f = tree.lexed(path).expect("listed path is present");
        scan_matches(&f, path, &enum_names, &mut dispatched, &mut out);
    }

    for v in &variants {
        if !dispatched.iter().any(|(e, n)| *e == v.enum_name && *n == v.name) {
            out.push(Violation {
                file: v.file.clone(),
                line: v.line,
                rule: RULE,
                message: format!(
                    "{}::{} has no dispatch arm in rust/src/mongo — a sender of this message would hang",
                    v.enum_name, v.name
                ),
            });
        }
        if !v.has_reply {
            let f = tree.lexed(&v.file).expect("variant file is present");
            if !f.annotated(v.line, "lint: allow(no_reply") {
                out.push(Violation {
                    file: v.file.clone(),
                    line: v.line,
                    rule: RULE,
                    message: format!(
                        "{}::{} carries no `reply` channel and no `// lint: allow(no_reply, <reason>)` annotation",
                        v.enum_name, v.name
                    ),
                });
            }
        }
    }
    out
}

/// Parse every `enum <X>Request {{ ... }}` in `path` into `variants`.
fn collect_variants(tree: &SourceTree, path: &str, variants: &mut Vec<Variant>) {
    let f = tree.lexed(path).expect("caller checked presence");
    let t = &f.tokens;
    let mut i = 0;
    while i + 2 < t.len() {
        let is_request_enum = t[i].text == "enum"
            && t[i + 1].kind == TokKind::Ident
            && t[i + 1].text.ends_with("Request")
            && t[i + 2].text == "{";
        if !is_request_enum {
            i += 1;
            continue;
        }
        let enum_name = t[i + 1].text.clone();
        let mut j = i + 3; // first token inside the enum body
        let (mut bdepth, mut pdepth, mut brdepth) = (1i32, 0i32, 0i32);
        let mut expecting = true; // next ident at depth 1 starts a variant
        while j < t.len() && bdepth > 0 {
            let at_variant_level = bdepth == 1 && pdepth == 0 && brdepth == 0;
            match t[j].text.as_str() {
                "#" if at_variant_level && t.get(j + 1).is_some_and(|n| n.text == "[") => {
                    // Skip an attribute without treating its contents
                    // as variant tokens.
                    j += 1;
                    let mut d = 0i32;
                    while j < t.len() {
                        match t[j].text.as_str() {
                            "[" => d += 1,
                            "]" => {
                                d -= 1;
                                if d == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                }
                "{" => bdepth += 1,
                "}" => bdepth -= 1,
                "(" => pdepth += 1,
                ")" => pdepth -= 1,
                "[" => brdepth += 1,
                "]" => brdepth -= 1,
                "," if at_variant_level => expecting = true,
                _ if expecting && at_variant_level && t[j].kind == TokKind::Ident => {
                    expecting = false;
                    // Struct variants list fields in the `{ ... }` that
                    // follows; a `reply` field there is the counterpart.
                    let mut has_reply = false;
                    if t.get(j + 1).is_some_and(|n| n.text == "{") {
                        let mut k = j + 2;
                        let mut d = 1i32;
                        while k < t.len() && d > 0 {
                            match t[k].text.as_str() {
                                "{" => d += 1,
                                "}" => d -= 1,
                                "reply" if d == 1 => has_reply = true,
                                _ => {}
                            }
                            k += 1;
                        }
                    }
                    variants.push(Variant {
                        enum_name: enum_name.clone(),
                        name: t[j].text.clone(),
                        file: path.to_string(),
                        line: t[j].line,
                        has_reply,
                    });
                }
                _ => {}
            }
            j += 1;
        }
        i = j;
    }
}

/// Find match blocks, record `Enum::Variant =>` dispatch arms, and
/// flag `_ =>` wildcards inside matches that dispatch request enums.
fn scan_matches(
    f: &super::lexer::SourceFile,
    path: &str,
    enum_names: &[&str],
    dispatched: &mut Vec<(String, String)>,
    out: &mut Vec<Violation>,
) {
    let t = &f.tokens;
    let mut i = 0;
    while i < t.len() {
        if t[i].text != "match" || f.is_test_line(t[i].line) {
            i += 1;
            continue;
        }
        // Find the match block's `{` (skip the scrutinee expression;
        // struct literals cannot appear unparenthesized there).
        let mut j = i + 1;
        let mut pdepth = 0i32;
        while j < t.len() {
            match t[j].text.as_str() {
                "(" | "[" => pdepth += 1,
                ")" | "]" => pdepth -= 1,
                "{" if pdepth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if j >= t.len() {
            break;
        }
        // Walk the arms at depth 1 of the block.
        let mut k = j + 1;
        let mut depth = 1i32;
        let mut arm_dispatches = false;
        let mut wildcards: Vec<usize> = Vec::new(); // lines of `_ =>`
        while k < t.len() && depth > 0 {
            match t[k].text.as_str() {
                "{" => depth += 1,
                "}" => depth -= 1,
                "_" if depth == 1 && t.get(k + 1).is_some_and(|n| n.text == "=>") => {
                    wildcards.push(t[k].line);
                }
                _ if depth == 1
                    && t[k].kind == TokKind::Ident
                    && enum_names.contains(&t[k].text.as_str())
                    && t.get(k + 1).is_some_and(|c| c.text == "::")
                    && t.get(k + 2).is_some_and(|v| v.kind == TokKind::Ident) =>
                {
                    // `Enum::Variant` then an optional bound pattern,
                    // then `=>` (or `|`, continuing the same arm).
                    let mut m = k + 3;
                    if t.get(m).is_some_and(|p| p.text == "{" || p.text == "(") {
                        let open = t[m].text.clone();
                        let close = if open == "{" { "}" } else { ")" };
                        let mut d = 1i32;
                        m += 1;
                        while m < t.len() && d > 0 {
                            if t[m].text == open {
                                d += 1;
                            } else if t[m].text == close {
                                d -= 1;
                            }
                            m += 1;
                        }
                    }
                    if t.get(m).is_some_and(|a| a.text == "=>" || a.text == "|") {
                        arm_dispatches = true;
                        dispatched.push((t[k].text.clone(), t[k + 2].text.clone()));
                    }
                }
                _ => {}
            }
            k += 1;
        }
        if arm_dispatches {
            for line in wildcards {
                out.push(Violation {
                    file: path.to_string(),
                    line,
                    rule: RULE,
                    message: "wildcard `_ =>` in a request-dispatch match — it would silently swallow the next variant added to the protocol".to_string(),
                });
            }
        }
        i += 1; // nested matches get their own pass
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD_WIRE: &str = "pub enum ShardRequest {\n    Insert { doc: Document, reply: Reply<u64> },\n    // lint: allow(no_reply, one-way push)\n    SetMap { map: ChunkMap },\n}\n";

    fn tree(wire: &str, server: &str) -> SourceTree {
        let mut t = SourceTree::new();
        t.add("rust/src/mongo/wire.rs", wire);
        t.add("rust/src/mongo/server/shard.rs", server);
        t
    }

    #[test]
    fn paired_variants_pass() {
        let t = tree(
            GOOD_WIRE,
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} ShardRequest::SetMap { map } => {} } }",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn undispatched_variant_is_flagged_at_its_declaration() {
        let t = tree(
            GOOD_WIRE,
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("SetMap") && v[0].message.contains("no dispatch arm"));
        assert_eq!((v[0].file.as_str(), v[0].line), ("rust/src/mongo/wire.rs", 4));
    }

    #[test]
    fn wildcard_in_dispatch_match_is_flagged() {
        let t = tree(
            GOOD_WIRE,
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} ShardRequest::SetMap { map } => {} _ => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("wildcard"));
        assert_eq!(v[0].file, "rust/src/mongo/server/shard.rs");
    }

    #[test]
    fn wildcard_in_unrelated_match_is_fine() {
        let t = tree(
            GOOD_WIRE,
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} ShardRequest::SetMap { map } => {} } }\nfn other(x: u8) { match x { 1 => {} _ => {} } }",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn missing_reply_without_annotation_is_flagged() {
        let t = tree(
            "pub enum ShardRequest {\n    Insert { doc: Document, reply: Reply<u64> },\n    SetMap { map: ChunkMap },\n}\n",
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} ShardRequest::SetMap { map } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("no `reply` channel"));
    }

    #[test]
    fn crud_write_variants_pair_across_both_enum_files() {
        // The CRUD write path added Update/Delete to *both* enum files
        // (RouterRequest lives in router.rs, the second entry of
        // ENUM_FILES) plus the migration's one-way ClearStaged push:
        // variants must be collected from both files and their dispatch
        // arms found wherever they live.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    Update { filter: Filter, set: Document, reply: Reply<UpdateReply> },\n    Delete { filter: Filter, reply: Reply<DeleteReply> },\n    // lint: allow(no_reply, one-way staging cleanup after publish)\n    ClearStaged { range: (u64, u64) },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/router.rs",
            "pub enum RouterRequest {\n    Update { filter: Filter, set: Document, reply: Reply<Result<UpdateReply, WireError>> },\n    Delete { filter: Filter, reply: Reply<Result<DeleteReply, WireError>> },\n}\nfn run(&mut self) { match req { RouterRequest::Update { filter, set, reply } => {} RouterRequest::Delete { filter, reply } => {} } }",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::Update { filter, set, reply } => {} ShardRequest::Delete { filter, reply } => {} ShardRequest::ClearStaged { range } => {} } }",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn undispatched_crud_variant_is_flagged() {
        // Forgetting the shard-side arm for a freshly added write op is
        // exactly the hang this rule exists for: the router would block
        // on a reply channel nobody serves.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    Update { set: Document, reply: Reply<UpdateReply> },\n    Delete { filter: Filter, reply: Reply<DeleteReply> },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::Update { set, reply } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("Delete") && v[0].message.contains("no dispatch arm"));
    }

    #[test]
    fn aggregate_variants_pair_across_both_enum_files() {
        // The aggregation push-down adds Aggregate to both enum files:
        // the router scatters ShardRequest::Aggregate (served via the
        // read-path dispatch in shard.rs) and clients send
        // RouterRequest::Aggregate (served on the router event loop).
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    Aggregate { pipeline: AggPipeline, partial: bool, reply: Reply<Result<AggregateReply, WireError>> },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/router.rs",
            "pub enum RouterRequest {\n    Aggregate { pipeline: AggPipeline, reply: Reply<Result<Vec<Document>, WireError>> },\n}\nfn run(&mut self) { match req { RouterRequest::Aggregate { pipeline, reply } => {} } }",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::Aggregate { pipeline, partial, reply } => {} } }",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn undispatched_aggregate_variant_is_flagged() {
        // An Aggregate variant nobody serves is the scatter-side hang:
        // the router would block on every shard's reply channel.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    Aggregate { pipeline: AggPipeline, partial: bool, reply: Reply<Result<AggregateReply, WireError>> },\n    Count { filter: Filter, reply: Reply<Result<CountReply, WireError>> },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::Count { filter, reply } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("Aggregate") && v[0].message.contains("no dispatch arm"));
    }

    #[test]
    fn dispatch_in_test_code_does_not_count() {
        let t = tree(
            GOOD_WIRE,
            "fn run(&mut self) { match req { ShardRequest::Insert { doc, reply } => {} } }\n#[cfg(test)]\nmod tests {\n    fn t() { match req { ShardRequest::SetMap { map } => {} } }\n}\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("SetMap"));
    }

    #[test]
    fn replication_one_way_variants_pass_with_no_reply_annotations() {
        // The replica-set protocol is four one-way mailbox messages
        // (Replicate, ReplicationAck, RequestVote, VoteReply): a
        // blocking reply channel would deadlock two event loops
        // messaging each other, so each carries the allow(no_reply)
        // annotation — and each still needs its dispatch arm.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    // lint: allow(no_reply, one-way; follower acks via ReplicationAck)\n    Replicate { term: u64, entries: Vec<Document>, commit: u64, reset: bool },\n    // lint: allow(no_reply, one-way; leader folds acks on its own loop)\n    ReplicationAck { member: u32, term: u64, ack_index: u64, success: bool },\n    // lint: allow(no_reply, one-way; votes return as VoteReply messages)\n    RequestVote { term: u64, candidate: u32, last_term: u64, last_index: u64 },\n    // lint: allow(no_reply, one-way; answer to RequestVote)\n    VoteReply { term: u64, from: u32, granted: bool },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::Replicate { term, entries, commit, reset } => {} ShardRequest::ReplicationAck { member, term, ack_index, success } => {} ShardRequest::RequestVote { term, candidate, last_term, last_index } => {} ShardRequest::VoteReply { term, from, granted } => {} } }",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn unannotated_replication_message_is_flagged() {
        // A one-way replication message without the allow(no_reply)
        // annotation must be flagged: either it should carry a reply
        // channel, or the author must state why it cannot.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    ReplicationAck { member: u32, term: u64, ack_index: u64, success: bool },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::ReplicationAck { member, term, ack_index, success } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(
            v[0].message.contains("ReplicationAck")
                && v[0].message.contains("no `reply` channel"),
            "{:?}",
            v[0]
        );
    }

    #[test]
    fn undispatched_replication_message_is_flagged() {
        // An annotated one-way message still needs a dispatch arm: a
        // Replicate nobody serves means secondaries silently never
        // tail the oplog.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/wire.rs",
            "pub enum ShardRequest {\n    // lint: allow(no_reply, one-way; follower acks via ReplicationAck)\n    Replicate { term: u64, entries: Vec<Document>, commit: u64, reset: bool },\n    // lint: allow(no_reply, one-way; leader folds acks on its own loop)\n    ReplicationAck { member: u32, term: u64, ack_index: u64, success: bool },\n}\n",
        );
        t.add(
            "rust/src/mongo/server/shard.rs",
            "fn run(&mut self) { match req { ShardRequest::ReplicationAck { member, term, ack_index, success } => {} } }",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(
            v[0].message.contains("Replicate") && v[0].message.contains("no dispatch arm"),
            "{:?}",
            v[0]
        );
    }
}
