//! Kernel service: thread-safe access to the PJRT executables.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and not `Send`, so one
//! dedicated thread owns the [`Engine`] and all compiled executables;
//! routers and shards talk to it through a cloneable [`Kernels`] handle
//! over an mpsc channel. With one host CPU this also serializes XLA
//! execution realistically (one "accelerator" shared by the cluster).
//!
//! [`Kernels`] hides batching details: requests of any length are split
//! into fixed-shape artifact batches, padded, executed, and the outputs
//! truncated/corrected (histogram padding contributions are subtracted).
//! When artifacts are absent the handle degrades to the pure-Rust
//! fallback (`runtime::fallback`) with identical semantics.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use super::engine::Engine;
use super::fallback;
use super::manifest::{Manifest, Shapes, BUILT_SHAPES};
use crate::metrics::Registry;
use crate::util::hash::fnv1a_shard_key;

/// Result of routing a key batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteOutput {
    pub shard_of: Vec<i32>,
    /// Per-shard document counts (length = requested `num_shards`).
    pub counts: Vec<i32>,
    pub hashes: Vec<u32>,
}

/// Result of a filter batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FilterOutput {
    pub mask: Vec<i32>,
    pub count: i32,
}

/// Result of a stats batch.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsOutput {
    pub min: Vec<f32>,
    pub max: Vec<f32>,
    pub mean: Vec<f32>,
}

enum Request {
    Route {
        node: Vec<u32>,
        ts: Vec<u32>,
        boundaries: Vec<u32>,
        chunk_to_shard: Vec<i32>,
        reply: mpsc::Sender<Result<(Vec<i32>, Vec<i32>, Vec<u32>)>>,
    },
    Filter {
        ts: Vec<u32>,
        node: Vec<u32>,
        ts_lo: u32,
        ts_hi: u32,
        bitmap: Vec<u32>,
        reply: mpsc::Sender<Result<(Vec<i32>, i32)>>,
    },
    Stats {
        metrics: Vec<f32>,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
    },
    Shutdown,
}

/// Which execution path a [`Kernels`] handle uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT HLO artifacts on the PJRT service thread.
    Hlo,
    /// Pure-Rust scalar fallback (no artifacts needed).
    Fallback,
}

struct ServiceShared {
    tx: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Drop for ServiceShared {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Cloneable handle to the kernel execution layer.
#[derive(Clone)]
pub struct Kernels {
    backend: Backend,
    shapes: Shapes,
    service: Option<Arc<ServiceShared>>,
    metrics: Registry,
}

impl Kernels {
    /// Load artifacts from `dir` and start the PJRT service thread.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir: PathBuf = dir.into();
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let shapes = manifest.shapes;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-kernels".to_string())
            .spawn(move || service_main(dir, manifest, rx, ready_tx))
            .context("spawning kernel service thread")?;
        ready_rx
            .recv()
            .context("kernel service thread died during startup")??;
        Ok(Self {
            backend: Backend::Hlo,
            shapes,
            service: Some(Arc::new(ServiceShared { tx, join: Some(join) })),
            metrics: Registry::new(),
        })
    }

    /// Without the `pjrt` feature the HLO path is not compiled in; the
    /// manifest is still validated so shape mismatches surface, then the
    /// caller is told to fall back (see [`Self::load_or_fallback`]).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir: PathBuf = dir.into();
        let _manifest = Manifest::load(&dir)?;
        anyhow::bail!(
            "PJRT runtime not compiled in (build with `--features pjrt` and the xla \
             dependency to execute AOT artifacts); using the scalar fallback"
        )
    }

    /// Pure-Rust fallback handle (no artifacts, no PJRT).
    pub fn fallback() -> Self {
        Self {
            backend: Backend::Fallback,
            shapes: BUILT_SHAPES,
            service: None,
            metrics: Registry::new(),
        }
    }

    /// Load artifacts if present, else fall back (logged to stderr).
    pub fn load_or_fallback(dir: impl Into<PathBuf>) -> Self {
        let dir: PathBuf = dir.into();
        match Self::load(&dir) {
            Ok(k) => k,
            Err(e) => {
                eprintln!("warn: kernel artifacts unavailable ({e:#}); using scalar fallback");
                Self::fallback()
            }
        }
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn shapes(&self) -> Shapes {
        self.shapes
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Route a key batch of any length to shards.
    ///
    /// `boundaries`/`chunk_to_shard` describe the real chunk table
    /// (length ≤ `route_c`); `num_shards` bounds the histogram.
    pub fn route(
        &self,
        node: &[u32],
        ts: &[u32],
        boundaries: &[u32],
        chunk_to_shard: &[i32],
        num_shards: usize,
    ) -> Result<RouteOutput> {
        anyhow::ensure!(node.len() == ts.len(), "key column length mismatch");
        anyhow::ensure!(
            boundaries.len() == chunk_to_shard.len(),
            "chunk table column mismatch"
        );
        anyhow::ensure!(
            boundaries.len() <= self.shapes.route_c,
            "chunk table ({}) exceeds artifact capacity ({})",
            boundaries.len(),
            self.shapes.route_c
        );
        anyhow::ensure!(num_shards <= self.shapes.route_s, "too many shards");
        self.metrics.counter("kernels.route.calls").inc();
        self.metrics.counter("kernels.route.keys").add(node.len() as u64);

        if self.backend == Backend::Fallback {
            let (shard_of, counts, hashes) =
                fallback::route_batch(node, ts, boundaries, chunk_to_shard, num_shards);
            return Ok(RouteOutput { shard_of, counts, hashes });
        }

        // Pad the chunk table to artifact capacity.
        let c = self.shapes.route_c;
        let mut bounds = boundaries.to_vec();
        bounds.resize(c, u32::MAX);
        let mut c2s = chunk_to_shard.to_vec();
        let last = *chunk_to_shard.last().unwrap_or(&0);
        c2s.resize(c, last);

        let b = self.shapes.route_b;
        let mut shard_of = Vec::with_capacity(node.len());
        let mut hashes = Vec::with_capacity(node.len());
        let mut counts = vec![0i32; num_shards];
        for start in (0..node.len()).step_by(b) {
            let end = (start + b).min(node.len());
            let n_real = end - start;
            let mut nn = node[start..end].to_vec();
            let mut tt = ts[start..end].to_vec();
            // Pad with key (0, 0); its histogram contribution is
            // subtracted below.
            nn.resize(b, 0);
            tt.resize(b, 0);
            let (s, c_hist, h) = self.call_route(nn, tt, bounds.clone(), c2s.clone())?;
            shard_of.extend_from_slice(&s[..n_real]);
            hashes.extend_from_slice(&h[..n_real]);
            let pad = (b - n_real) as i32;
            if pad > 0 {
                let pad_shard =
                    chunk_to_shard[fallback::chunk_of_hash(fnv1a_shard_key(0, 0), boundaries)];
                for (i, v) in c_hist.iter().enumerate().take(num_shards) {
                    let adj = if i as i32 == pad_shard { v - pad } else { *v };
                    counts[i] += adj;
                }
            } else {
                for (i, v) in c_hist.iter().enumerate().take(num_shards) {
                    counts[i] += v;
                }
            }
        }
        Ok(RouteOutput { shard_of, counts, hashes })
    }

    /// Evaluate the conditional-find predicate over columns of any length.
    pub fn filter(
        &self,
        ts: &[u32],
        node: &[u32],
        ts_lo: u32,
        ts_hi: u32,
        bitmap: &[u32],
    ) -> Result<FilterOutput> {
        anyhow::ensure!(ts.len() == node.len(), "column length mismatch");
        anyhow::ensure!(
            bitmap.len() <= self.shapes.filter_w,
            "bitmap ({}) exceeds artifact capacity ({})",
            bitmap.len(),
            self.shapes.filter_w
        );
        self.metrics.counter("kernels.filter.calls").inc();
        self.metrics.counter("kernels.filter.docs").add(ts.len() as u64);

        if self.backend == Backend::Fallback {
            let (mask, count) = fallback::filter_batch(ts, node, ts_lo, ts_hi, bitmap);
            return Ok(FilterOutput { mask, count });
        }

        let w = self.shapes.filter_w;
        let mut bm = bitmap.to_vec();
        bm.resize(w, 0);

        let b = self.shapes.filter_b;
        let mut mask = Vec::with_capacity(ts.len());
        let mut count = 0i32;
        for start in (0..ts.len()).step_by(b) {
            let end = (start + b).min(ts.len());
            let n_real = end - start;
            let mut tt = ts[start..end].to_vec();
            let mut nn = node[start..end].to_vec();
            // Pad with node id 0; if node 0 is a member AND 0 is in the ts
            // range the pad rows would match, so pad ts with u32::MAX
            // which never satisfies ts < ts_hi (ts_hi <= u32::MAX).
            tt.resize(b, u32::MAX);
            nn.resize(b, 0);
            let (m, c) = self.call_filter(tt, nn, ts_lo, ts_hi, bm.clone())?;
            mask.extend_from_slice(&m[..n_real]);
            count += c;
        }
        Ok(FilterOutput { mask, count })
    }

    /// Column statistics over a `[B, M]` row-major metric batch.
    /// `b` may be any positive length; `m` must equal `stats_m`.
    pub fn stats(&self, metrics: &[f32], b: usize, m: usize) -> Result<StatsOutput> {
        anyhow::ensure!(b > 0, "empty batch");
        anyhow::ensure!(metrics.len() == b * m, "metrics shape mismatch");
        anyhow::ensure!(m == self.shapes.stats_m, "column count must be stats_m");
        self.metrics.counter("kernels.stats.calls").inc();

        if self.backend == Backend::Fallback {
            let (min, max, mean) = fallback::stats_batch(metrics, b, m);
            return Ok(StatsOutput { min, max, mean });
        }

        let sb = self.shapes.stats_b;
        // Merge per-chunk results; mean needs a weighted combine.
        let mut min = vec![f32::INFINITY; m];
        let mut max = vec![f32::NEG_INFINITY; m];
        let mut sum = vec![0f64; m];
        for start in (0..b).step_by(sb) {
            let end = (start + sb).min(b);
            let n_real = end - start;
            let mut chunk = metrics[start * m..end * m].to_vec();
            // Pad by repeating the first row (affects neither min nor max;
            // mean is re-weighted from the true row count below).
            let first_row: Vec<f32> = chunk[..m].to_vec();
            for _ in n_real..sb {
                chunk.extend_from_slice(&first_row);
            }
            let (mn, mx, mean_padded) = self.call_stats(chunk)?;
            let pad = (sb - n_real) as f64;
            for col in 0..m {
                min[col] = min[col].min(mn[col]);
                max[col] = max[col].max(mx[col]);
                // padded mean * sb = real sum + pad * first_row value
                let total = mean_padded[col] as f64 * sb as f64;
                sum[col] += total - pad * first_row[col] as f64;
            }
        }
        let mean = sum.iter().map(|s| (*s / b as f64) as f32).collect();
        Ok(StatsOutput { min, max, mean })
    }

    fn call_route(
        &self,
        node: Vec<u32>,
        ts: Vec<u32>,
        boundaries: Vec<u32>,
        chunk_to_shard: Vec<i32>,
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<u32>)> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Route { node, ts, boundaries, chunk_to_shard, reply })?;
        rx.recv().context("kernel service dropped route reply")?
    }

    fn call_filter(
        &self,
        ts: Vec<u32>,
        node: Vec<u32>,
        ts_lo: u32,
        ts_hi: u32,
        bitmap: Vec<u32>,
    ) -> Result<(Vec<i32>, i32)> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Filter { ts, node, ts_lo, ts_hi, bitmap, reply })?;
        rx.recv().context("kernel service dropped filter reply")?
    }

    fn call_stats(&self, metrics: Vec<f32>) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Stats { metrics, reply })?;
        rx.recv().context("kernel service dropped stats reply")?
    }

    fn send(&self, req: Request) -> Result<()> {
        self.service
            .as_ref()
            .expect("HLO backend without service")
            .tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("kernel service thread has exited"))
    }
}

/// Service thread main: compile all artifacts, then serve requests.
#[cfg(feature = "pjrt")]
fn service_main(
    dir: PathBuf,
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<_> {
        let engine = Engine::new(&dir)?;
        let route = engine.load(&manifest.route_artifact())?;
        let filter = engine.load(&manifest.filter_artifact())?;
        let stats = engine.load(&manifest.stats_artifact())?;
        Ok((engine, route, filter, stats))
    })();
    let (_engine, route_exe, filter_exe, stats_exe) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Route { node, ts, boundaries, chunk_to_shard, reply } => {
                let r = (|| -> Result<_> {
                    let outs = route_exe.run(&[
                        xla::Literal::vec1(&node),
                        xla::Literal::vec1(&ts),
                        xla::Literal::vec1(&boundaries),
                        xla::Literal::vec1(&chunk_to_shard),
                    ])?;
                    anyhow::ensure!(outs.len() == 3, "route artifact returned {}", outs.len());
                    Ok((
                        outs[0].to_vec::<i32>()?,
                        outs[1].to_vec::<i32>()?,
                        outs[2].to_vec::<u32>()?,
                    ))
                })();
                let _ = reply.send(r);
            }
            Request::Filter { ts, node, ts_lo, ts_hi, bitmap, reply } => {
                let r = (|| -> Result<_> {
                    let outs = filter_exe.run(&[
                        xla::Literal::vec1(&ts),
                        xla::Literal::vec1(&node),
                        xla::Literal::vec1(&[ts_lo]),
                        xla::Literal::vec1(&[ts_hi]),
                        xla::Literal::vec1(&bitmap),
                    ])?;
                    anyhow::ensure!(outs.len() == 2, "filter artifact returned {}", outs.len());
                    let mask = outs[0].to_vec::<i32>()?;
                    let count = outs[1].to_vec::<i32>()?;
                    Ok((mask, count[0]))
                })();
                let _ = reply.send(r);
            }
            Request::Stats { metrics, reply } => {
                let r = (|| -> Result<_> {
                    let b = metrics.len() / BUILT_SHAPES.stats_m;
                    let lit = xla::Literal::vec1(&metrics)
                        .reshape(&[b as i64, BUILT_SHAPES.stats_m as i64])?;
                    let outs = stats_exe.run(&[lit])?;
                    anyhow::ensure!(outs.len() == 3, "stats artifact returned {}", outs.len());
                    Ok((
                        outs[0].to_vec::<f32>()?,
                        outs[1].to_vec::<f32>()?,
                        outs[2].to_vec::<f32>()?,
                    ))
                })();
                let _ = reply.send(r);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_handle_routes() {
        let k = Kernels::fallback();
        assert_eq!(k.backend(), Backend::Fallback);
        let bounds = vec![u32::MAX];
        let c2s = vec![0i32];
        let out = k.route(&[1, 2, 3], &[4, 5, 6], &bounds, &c2s, 1).unwrap();
        assert_eq!(out.shard_of, vec![0, 0, 0]);
        assert_eq!(out.counts, vec![3]);
        assert_eq!(out.hashes.len(), 3);
        assert_eq!(k.metrics().counter("kernels.route.keys").get(), 3);
    }

    #[test]
    fn fallback_handle_filters() {
        let k = Kernels::fallback();
        let bm = fallback::build_bitmap([2u32], 4);
        let out = k.filter(&[10, 20, 30], &[2, 2, 3], 15, 25, &bm).unwrap();
        assert_eq!(out.mask, vec![0, 1, 0]);
        assert_eq!(out.count, 1);
    }

    #[test]
    fn fallback_handle_stats() {
        let k = Kernels::fallback();
        let m = BUILT_SHAPES.stats_m;
        let metrics: Vec<f32> = (0..2 * m).map(|i| i as f32).collect();
        let out = k.stats(&metrics, 2, m).unwrap();
        assert_eq!(out.min[0], 0.0);
        assert_eq!(out.max[0], m as f32);
        assert_eq!(out.mean[1], (1.0 + (m + 1) as f32) / 2.0);
    }

    #[test]
    fn route_rejects_mismatched_columns() {
        let k = Kernels::fallback();
        assert!(k.route(&[1], &[1, 2], &[u32::MAX], &[0], 1).is_err());
    }

    #[test]
    fn route_rejects_oversized_chunk_table() {
        let k = Kernels::fallback();
        let bounds = vec![u32::MAX; BUILT_SHAPES.route_c + 1];
        let c2s = vec![0i32; BUILT_SHAPES.route_c + 1];
        assert!(k.route(&[1], &[1], &bounds, &c2s, 1).is_err());
    }
}
