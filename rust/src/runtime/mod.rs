//! PJRT runtime: loads AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod fallback;
pub mod manifest;
pub mod service;

pub use service::{Backend, FilterOutput, Kernels, RouteOutput, StatsOutput};
