//! Randomized differential crash-recovery fuzzing.
//!
//! Each seed drives a deterministic op stream (insert / insert_many /
//! remove / update_many / delete_many / group commit / checkpoint)
//! against both a storage
//! [`Engine`] and a plain in-memory model, snapshotting the model after
//! every journal frame. The engine is then killed, the on-disk state is
//! optionally mutated the way a real mid-write kill would leave it —
//! the newest journal segment truncated at a random byte, a partial
//! checkpoint staging file left behind — and reopened. The recovered
//! store must equal the model at the last durable frame, or, when the
//! journal tail was truncated, at *some* frame between the newest
//! checkpoint and the last group commit (frames are atomic and applied
//! in order, so any other state is a recovery bug). A probe insert
//! disambiguates states that differ only in the rid allocator.
//!
//! The run then continues on the recovered store — more writes, a
//! checkpoint (which truncates the replayed journal tail, so the delta
//! must carry it), another kill — and verifies exactness again.
//!
//! Small thresholds make auto-compaction, delta chains, and rebases
//! fire constantly; every fifth seed starts from a legacy single-file
//! `journal.wal` so the migration path is fuzzed too.
//!
//! Knobs (documented in docs/EXPERIMENTS.md): `CRASH_FUZZ_SEEDS` is
//! either a seed count (`32` → seeds 0..32) or an explicit comma list
//! (`7,19,1000`); the default sweep is 24 seeds.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use hpcstore::mongo::bson::Document;
use hpcstore::mongo::storage::{Engine, EngineOptions, LocalDir, StorageDir};
use hpcstore::util::rng::Pcg32;

/// rid → ts: identifies every live record uniquely (ts values are never
/// reused within a run).
type Model = BTreeMap<u64, i64>;

fn doc(ts: i64) -> Document {
    Document::new()
        .set("ts", ts)
        .set("node_id", ts % 16)
        .set("m0", ts as f64 * 0.25)
}

fn seeds() -> Vec<u64> {
    match std::env::var("CRASH_FUZZ_SEEDS") {
        Ok(s) if s.contains(',') => s
            .split(',')
            .map(|t| t.trim().parse().expect("CRASH_FUZZ_SEEDS: bad seed"))
            .collect(),
        Ok(s) => {
            let n: u64 = s.trim().parse().expect("CRASH_FUZZ_SEEDS: bad count");
            (0..n).collect()
        }
        Err(_) => (0..24).collect(),
    }
}

/// Path of the newest (highest-seq) journal segment, if any — the only
/// file a real crash can tear.
fn newest_journal(root: &str) -> Option<PathBuf> {
    let mut best: Option<(u64, PathBuf)> = None;
    for e in std::fs::read_dir(root).unwrap() {
        let e = e.unwrap();
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(seq) = name
            .strip_prefix("journal-")
            .and_then(|s| s.strip_suffix(".wal"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            if best.as_ref().map_or(true, |(b, _)| seq > *b) {
                best = Some((seq, e.path()));
            }
        }
    }
    best.map(|(_, p)| p)
}

/// Newest checkpoint artifact (the newest delta, else the full
/// snapshot) — the file whose *staging copy* a kill mid-checkpoint
/// leaves partially written.
fn newest_checkpoint_artifact(root: &str) -> Option<PathBuf> {
    let mut best: Option<(u64, PathBuf)> = None;
    for e in std::fs::read_dir(root).unwrap() {
        let e = e.unwrap();
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(gen) = name
            .strip_prefix("delta-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            if best.as_ref().map_or(true, |(b, _)| gen > *b) {
                best = Some((gen, e.path()));
            }
        }
    }
    best.map(|(_, p)| p).or_else(|| {
        let p = Path::new(root).join("store.ckpt");
        p.exists().then_some(p)
    })
}

/// Model snapshots indexed by journal-frame count, plus the durability
/// watermarks the kill windows are judged against.
struct FuzzRun {
    /// `states[k]` = model after the first `k` frame ops.
    states: Vec<Model>,
    /// Rid-allocator position after the first `k` frame ops.
    next_rids: Vec<u64>,
    /// Highest frame index durable on disk (group commit or checkpoint).
    synced: usize,
    /// Highest frame index covered by the newest checkpoint — frames at
    /// or below it survive any journal truncation.
    checkpointed: usize,
}

impl FuzzRun {
    fn push(&mut self, model: &Model, next_rid: u64) {
        self.states.push(model.clone());
        self.next_rids.push(next_rid);
    }
}

fn run_seed(seed: u64) {
    let mut rng = Pcg32::seeded(seed);
    let opts = EngineOptions {
        journal: true,
        compress_checkpoints: seed % 2 == 0,
        checkpoint_bytes: 6 * 1024,
        journal_segments: 2,
        full_checkpoint_chain: 3,
        ..EngineOptions::default()
    };
    let root = {
        let dir = LocalDir::temp(&format!("fuzz-{seed}")).unwrap();
        dir.describe()
    };

    let mut model: Model = Model::new();
    let mut next_rid = 0u64;
    let mut next_ts = 0i64;

    // Every fifth seed starts from a legacy pre-rotation store so the
    // v1-layout migration runs under the same differential check.
    // lint: journal-op(OP_INSERT) — single-document frames, replayed and
    // differentially checked against the model after every simulated kill.
    // lint: journal-op(OP_REMOVE) — single-document removes interleave with
    // the inserts under the same kill/replay differential check.
    if seed % 5 == 0 {
        let mut eng = Engine::open_with(
            Box::new(LocalDir::new(&root).unwrap()),
            EngineOptions::default(),
        )
        .unwrap();
        eng.create_collection("metrics");
        for _ in 0..6 {
            let rid = eng.insert("metrics", &doc(next_ts)).unwrap();
            assert_eq!(rid, next_rid, "seed {seed}: priming rid diverged");
            model.insert(rid, next_ts);
            next_rid += 1;
            next_ts += 1;
        }
        eng.sync().unwrap();
        drop(eng);
        std::fs::rename(
            Path::new(&root).join("journal-000001.wal"),
            Path::new(&root).join("journal.wal"),
        )
        .unwrap();
    }

    let mut run = FuzzRun {
        states: vec![model.clone()],
        next_rids: vec![next_rid],
        synced: 0,
        checkpointed: 0,
    };

    let mut eng =
        Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
    eng.create_collection("metrics");
    assert_eq!(
        eng.stats("metrics").docs,
        model.len() as u64,
        "seed {seed}: primed store must replay its legacy journal"
    );

    let ops = 80 + rng.next_bounded(120) as usize;
    for _ in 0..ops {
        match rng.next_bounded(100) {
            0..=29 => {
                // One insert = one journal frame.
                let rid = eng.insert("metrics", &doc(next_ts)).unwrap();
                assert_eq!(rid, next_rid, "seed {seed}: rid allocation diverged");
                model.insert(rid, next_ts);
                next_rid += 1;
                next_ts += 1;
                run.push(&model, next_rid);
            }
            30..=49 => {
                // One batch = one multi-record frame (atomic on replay).
                let k = 1 + rng.next_bounded(24) as i64;
                let docs: Vec<Document> = (0..k).map(|i| doc(next_ts + i)).collect();
                let rids = eng.insert_many("metrics", &docs).unwrap();
                for (i, rid) in rids.iter().enumerate() {
                    assert_eq!(*rid, next_rid + i as u64, "seed {seed}: batch rid diverged");
                    model.insert(*rid, next_ts + i as i64);
                }
                next_rid += k as u64;
                next_ts += k;
                run.push(&model, next_rid);
            }
            50..=59 => {
                if model.is_empty() {
                    continue;
                }
                let idx = rng.next_bounded(model.len() as u32) as usize;
                let rid = *model.keys().nth(idx).expect("index bounded by len");
                let removed = eng.remove("metrics", rid).unwrap();
                assert_eq!(
                    removed.get_i64("ts"),
                    model.get(&rid).copied(),
                    "seed {seed}: removed the wrong document"
                );
                model.remove(&rid);
                run.push(&model, next_rid);
            }
            60..=69 => {
                // One update batch = one OP_UPDATE_MANY frame: each pair
                // kills the old rid and installs the replacement under
                // the next sequential rid. The model mirrors both sides,
                // so a replay that loses, doubles, or reorders a pair
                // diverges at the diff (or at the rid probe).
                if model.is_empty() {
                    continue;
                }
                let keys: Vec<u64> = model.keys().copied().collect();
                let mut targets = std::collections::BTreeSet::new();
                for _ in 0..1 + rng.next_bounded(8) {
                    targets.insert(keys[rng.next_bounded(keys.len() as u32) as usize]);
                }
                let mut updates = Vec::with_capacity(targets.len());
                for &old in &targets {
                    updates.push((old, doc(next_ts)));
                    next_ts += 1;
                }
                let fresh = eng.update_many("metrics", &updates).unwrap();
                assert_eq!(fresh.len(), updates.len());
                for (i, ((old, d), new)) in updates.iter().zip(fresh).enumerate() {
                    assert_eq!(
                        new,
                        next_rid + i as u64,
                        "seed {seed}: update rid allocation diverged"
                    );
                    model.remove(old);
                    model.insert(new, d.get_i64("ts").expect("fuzz docs carry ts"));
                }
                next_rid += updates.len() as u64;
                run.push(&model, next_rid);
            }
            70..=79 => {
                // One delete batch = one rid-only OP_DELETE_MANY frame.
                if model.is_empty() {
                    continue;
                }
                let keys: Vec<u64> = model.keys().copied().collect();
                let mut picked = std::collections::BTreeSet::new();
                for _ in 0..1 + rng.next_bounded(8) {
                    picked.insert(keys[rng.next_bounded(keys.len() as u32) as usize]);
                }
                let victims: Vec<u64> = picked.into_iter().collect();
                let removed = eng.delete_many("metrics", &victims).unwrap();
                assert_eq!(removed.len(), victims.len());
                for (rid, d) in victims.iter().zip(removed) {
                    assert_eq!(
                        d.get_i64("ts"),
                        model.get(rid).copied(),
                        "seed {seed}: deleted the wrong document"
                    );
                    model.remove(rid);
                }
                run.push(&model, next_rid);
            }
            80..=93 => {
                // Group commit + background compaction hook — exactly
                // the shard-server write pattern.
                eng.sync().unwrap();
                run.synced = run.states.len() - 1;
                if eng.maybe_checkpoint().unwrap().is_some() {
                    run.checkpointed = run.states.len() - 1;
                }
            }
            _ => {
                // Admin checkpoint: persists buffered-but-unsynced
                // frames too (they land in the delta, not the journal).
                eng.checkpoint().unwrap();
                run.checkpointed = run.states.len() - 1;
                run.synced = run.states.len() - 1;
            }
        }
    }
    if rng.next_bounded(2) == 0 {
        eng.sync().unwrap();
        run.synced = run.states.len() - 1;
    }
    drop(eng); // kill

    // Crash mutation: what a kill mid-write leaves on the filesystem.
    let mode = rng.next_bounded(4);
    let mut truncated = false;
    if mode == 1 || mode == 3 {
        if let Some(seg) = newest_journal(&root) {
            let len = std::fs::metadata(&seg).unwrap().len();
            if len > 0 {
                let keep = rng.next_bounded(len.min(u32::MAX as u64) as u32) as u64;
                let f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
                f.set_len(keep).unwrap();
                truncated = true;
            }
        }
    }
    if mode == 2 || mode == 3 {
        // A checkpoint write died before its atomic rename: a partial
        // staging file sits next to the published artifact. Recovery
        // must discard it and keep the published chain authoritative.
        if let Some(art) = newest_checkpoint_artifact(&root) {
            let bytes = std::fs::read(&art).unwrap();
            if !bytes.is_empty() {
                let keep = 1 + rng.next_bounded(bytes.len() as u32) as usize;
                let tmp = format!("{}.tmp", art.to_string_lossy());
                std::fs::write(tmp, &bytes[..keep.min(bytes.len())]).unwrap();
            }
        }
    }

    // Reopen and diff against the model. The probe insert reveals the
    // recovered rid allocator, which disambiguates snapshots that hold
    // the same documents (e.g. before and after an insert+remove pair).
    let mut eng =
        Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
    // The collection itself is only persistent once a frame or
    // checkpoint carried it; recreate it so the probe below always has
    // somewhere to land (idempotent when it survived).
    eng.create_collection("metrics");
    let got: Model = eng
        .scan("metrics")
        .map(|(rid, d)| (rid, d.get_i64("ts").expect("fuzz docs carry ts")))
        .collect();
    let probe_ts = next_ts + 1_000_000;
    let probe_rid = eng.insert("metrics", &doc(probe_ts)).unwrap();
    let k = (run.checkpointed..=run.synced)
        .find(|&k| run.states[k] == got && run.next_rids[k] == probe_rid)
        .unwrap_or_else(|| {
            panic!(
                "seed {seed}: recovered state (docs {}, next_rid {probe_rid}) matches no \
                 durable frame in window {}..={} (mode {mode})",
                got.len(),
                run.checkpointed,
                run.synced
            )
        });
    if !truncated {
        assert_eq!(
            k, run.synced,
            "seed {seed}: a kill without journal damage must recover the last group commit"
        );
    }

    // Continue on the recovered store: the replayed tail is dirty state
    // the next checkpoint must carry (it truncates the journal that
    // held it), and the rid allocator must march on without reuse.
    let mut model = run.states[k].clone();
    model.insert(probe_rid, probe_ts);
    let mut rid = probe_rid + 1;
    let mut ts = probe_ts + 1;
    for _ in 0..6 {
        let r = eng.insert("metrics", &doc(ts)).unwrap();
        assert_eq!(r, rid, "seed {seed}: post-recovery rid diverged");
        model.insert(r, ts);
        rid += 1;
        ts += 1;
    }
    eng.sync().unwrap();
    eng.checkpoint().unwrap();
    drop(eng); // kill again, immediately after the checkpoint

    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    let got: Model = eng
        .scan("metrics")
        .map(|(rid, d)| (rid, d.get_i64("ts").expect("fuzz docs carry ts")))
        .collect();
    assert_eq!(got, model, "seed {seed}: post-recovery continuation diverged");
    drop(eng);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn differential_crash_fuzz_over_seed_matrix() {
    let seeds = seeds();
    assert!(!seeds.is_empty(), "CRASH_FUZZ_SEEDS selected no seeds");
    for seed in seeds {
        run_seed(seed);
    }
}
