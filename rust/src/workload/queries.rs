//! Query driver — the paper's concurrent conditional-find workload.
//!
//! "The query test was done by doing a conditional find ... constructed
//! by reading user jobs metadata for time run, duration, and which
//! nodes were assigned." Worker threads issue
//! `find({node_id: {$in: job.nodes}, ts: {$gte: t0, $lt: t1}})`,
//! drain the cursor, and record end-to-end latency. When the corpus was
//! fully ingested, each query must return exactly
//! `job.nodes × job.duration` documents (§4) — the driver checks this.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::jobs::UserJob;
use crate::metrics::Histogram;
use crate::mongo::bson::Value;
use crate::mongo::client::MongoClient;
use crate::mongo::query::{CmpOp, Filter, FindOptions};

/// Outcome of a query run.
#[derive(Clone, Debug)]
pub struct QueryReport {
    pub queries: u64,
    pub docs_returned: u64,
    pub wall_ns: u64,
    pub latency: Histogram,
    pub concurrency: usize,
    pub count_mismatches: u64,
}

impl QueryReport {
    pub fn queries_per_sec(&self) -> f64 {
        self.queries as f64 * 1e9 / self.wall_ns.max(1) as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} finds ({} docs) in {:.2}s @ concurrency {} → {:.1} q/s, latency p50 {} p95 {} p99 {}",
            self.queries,
            self.docs_returned,
            self.wall_ns as f64 / 1e9,
            self.concurrency,
            self.queries_per_sec(),
            crate::util::fmt::human_duration_ns(self.latency.p50()),
            crate::util::fmt::human_duration_ns(self.latency.p95()),
            crate::util::fmt::human_duration_ns(self.latency.p99()),
        )
    }
}

/// Build the paper's conditional find for one user job.
pub fn job_filter(job: &UserJob) -> Filter {
    let (t0, t1) = job.window();
    Filter::And(vec![
        Filter::is_in(
            "node_id",
            job.nodes.iter().map(|&n| Value::Int(n as i64)).collect(),
        ),
        Filter::Cmp { field: "ts".into(), op: CmpOp::Gte, value: Value::Int(t0 as i64) },
        Filter::Cmp { field: "ts".into(), op: CmpOp::Lt, value: Value::Int(t1 as i64) },
    ])
}

/// Query driver.
pub struct QueryDriver {
    pub jobs: Vec<UserJob>,
    pub concurrency: usize,
    /// Verify result counts against `expected_docs` (requires the full
    /// corpus to have been ingested).
    pub verify_counts: bool,
}

impl QueryDriver {
    pub fn new(jobs: Vec<UserJob>, concurrency: usize) -> Self {
        Self { jobs, concurrency: concurrency.max(1), verify_counts: true }
    }

    /// Issue every job's find once, `concurrency` workers in parallel.
    pub fn run(&self, client: &MongoClient) -> Result<QueryReport> {
        let jobs = Arc::new(self.jobs.clone());
        let next = Arc::new(AtomicUsize::new(0));
        let verify = self.verify_counts;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for worker in 0..self.concurrency {
            let jobs = jobs.clone();
            let next = next.clone();
            let client = client.pinned(worker);
            handles.push(std::thread::spawn(move || -> Result<(u64, u64, u64, Histogram)> {
                let mut lat = Histogram::new();
                let mut queries = 0u64;
                let mut docs = 0u64;
                let mut mismatches = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let job = &jobs[i];
                    let t = Instant::now();
                    let got = client
                        .find(job_filter(job), FindOptions::default().batch_size(2_000))
                        .map_err(|e| anyhow::anyhow!("find: {e}"))?
                        .count() as u64;
                    lat.record(t.elapsed().as_nanos() as u64);
                    queries += 1;
                    docs += got;
                    if verify && got != job.expected_docs() {
                        mismatches += 1;
                        eprintln!(
                            "warn: job {} returned {got} docs, expected {}",
                            job.id,
                            job.expected_docs()
                        );
                    }
                }
                Ok((queries, docs, mismatches, lat))
            }));
        }
        let mut queries = 0;
        let mut docs = 0;
        let mut mismatches = 0;
        let mut lat = Histogram::new();
        for h in handles {
            let (q, d, m, l) = h.join().expect("query worker panicked")?;
            queries += q;
            docs += d;
            mismatches += m;
            lat.merge(&l);
        }
        Ok(QueryReport {
            queries,
            docs_returned: docs,
            wall_ns: t0.elapsed().as_nanos() as u64,
            latency: lat,
            concurrency: self.concurrency,
            count_mismatches: mismatches,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::metrics::Registry;
    use crate::mongo::cluster::{Cluster, ClusterSpec};
    use crate::mongo::storage::index::IndexSpec;
    use crate::mongo::storage::LocalDir;
    use crate::runtime::Kernels;
    use crate::workload::ingest::IngestDriver;
    use crate::workload::jobs::generate_jobs;
    use crate::workload::ovis::OvisGenerator;

    #[test]
    fn filter_shape_is_canonical() -> anyhow::Result<()> {
        let job = UserJob { id: 1, nodes: vec![2, 5], start_min: 100, duration_min: 10 };
        let f = job_filter(&job);
        // Must be the exact canonical shape the shard kernel path accepts.
        let Filter::And(parts) = &f else {
            anyhow::bail!("not a conjunction: {f:?}");
        };
        assert_eq!(parts.len(), 3);
        assert!(f.in_values("node_id").is_some());
        let (lo, hi) = f.index_range("ts").unwrap();
        assert_eq!(lo, Some(Value::Int(100)));
        assert_eq!(hi, Some(Value::Int(110)));
        Ok(())
    }

    #[test]
    fn end_to_end_counts_match_paper_formula() {
        let cluster = Cluster::start(
            ClusterSpec::small(3, 2),
            |sid| Ok(Box::new(LocalDir::temp(&format!("qd-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let cfg = WorkloadConfig {
            monitored_nodes: 12,
            metrics_per_doc: 4,
            days: 30.0 / 1440.0, // 30 minutes
            query_jobs: 10,
            ..Default::default()
        };
        let client = cluster.client();
        client.create_index(IndexSpec::single("ts")).unwrap();
        client.create_index(IndexSpec::single("node_id")).unwrap();
        let gen = OvisGenerator::new(cfg.clone());
        IngestDriver::new(gen, 64, 2).run(&client).unwrap();

        let jobs = generate_jobs(&cfg);
        let expected: u64 = jobs.iter().map(UserJob::expected_docs).sum();
        let report = QueryDriver::new(jobs, 3).run(&client).unwrap();
        assert_eq!(report.queries, 10);
        assert_eq!(report.count_mismatches, 0, "some finds returned wrong counts");
        assert_eq!(report.docs_returned, expected);
        assert!(report.latency.count() == 10);
        cluster.shutdown();
    }
}
