//! Storage I/O abstraction.
//!
//! The engine writes journals and checkpoints through [`StorageDir`] so
//! the same code runs on a plain local directory ([`LocalDir`]) or on
//! the Lustre simulator (`hpc::lustre::LustreDir`), which adds stripe
//! placement and OST bandwidth accounting on top of real backing files.

use std::fs;
use std::io::{Read, Seek, Write};
use std::path::PathBuf;

use anyhow::{Context, Result};

/// An append-only file handle (journal).
pub trait StorageFile: Send {
    fn append(&mut self, bytes: &[u8]) -> Result<()>;
    /// Durability barrier (group commit calls this once per batch).
    fn sync(&mut self) -> Result<()>;
    fn len(&self) -> u64;
}

/// A flat directory of named files.
pub trait StorageDir: Send + Sync {
    /// Open (create or truncate) an append-only file.
    fn create(&self, name: &str) -> Result<Box<dyn StorageFile>>;
    /// Open for appending, creating if missing.
    fn append_to(&self, name: &str) -> Result<Box<dyn StorageFile>>;
    /// Read a whole file.
    fn read(&self, name: &str) -> Result<Vec<u8>>;
    /// Atomically replace a file's contents (checkpoints): the
    /// implementation stages to `<name>.tmp` and renames, so readers see
    /// either the old bytes or the new bytes, never a prefix.
    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()>;
    /// Whether a file named `name` exists.
    fn exists(&self, name: &str) -> bool;
    /// Delete a file (journal-segment truncation).
    fn remove(&self, name: &str) -> Result<()>;
    /// Names of all files in the directory, sorted (the engine scans
    /// this for journal segments on recovery and truncation).
    fn list(&self) -> Result<Vec<String>>;
    /// Human-readable location (diagnostics).
    fn describe(&self) -> String;
}

/// Plain local-filesystem directory.
pub struct LocalDir {
    root: PathBuf,
}

impl LocalDir {
    pub fn new(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating storage dir {}", root.display()))?;
        Ok(Self { root })
    }

    /// A fresh unique temp-backed directory (tests).
    pub fn temp(label: &str) -> Result<Self> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "hpcstore-{label}-{}-{n}",
            std::process::id()
        ));
        Self::new(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }
}

struct LocalFile {
    /// Buffered so the group-commit write path pays one OS write per
    /// [`StorageFile::sync`] (journal frames are staged engine-side, but
    /// checkpoint-era callers may append in several pieces).
    file: std::io::BufWriter<fs::File>,
    len: u64,
}

impl StorageFile for LocalFile {
    fn append(&mut self, bytes: &[u8]) -> Result<()> {
        self.file.write_all(bytes)?;
        self.len += bytes.len() as u64;
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        // Flush to the OS; a real deployment would fsync, but on the test
        // box that dominates every measurement without changing any
        // scaling behaviour, so durability is OS-crash-level here.
        self.file.flush()?;
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len
    }
}

impl Drop for LocalFile {
    fn drop(&mut self) {
        // Best-effort flush so a graceful close never loses buffered
        // bytes; crash-loss semantics stay with unsynced data.
        let _ = self.file.flush();
    }
}

impl StorageDir for LocalDir {
    fn create(&self, name: &str) -> Result<Box<dyn StorageFile>> {
        let file = fs::File::create(self.path(name))?;
        Ok(Box::new(LocalFile { file: std::io::BufWriter::new(file), len: 0 }))
    }

    fn append_to(&self, name: &str) -> Result<Box<dyn StorageFile>> {
        let mut file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.path(name))?;
        let len = file.seek(std::io::SeekFrom::End(0))?;
        Ok(Box::new(LocalFile { file: std::io::BufWriter::new(file), len }))
    }

    fn read(&self, name: &str) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        fs::File::open(self.path(name))
            .with_context(|| format!("opening {}", self.path(name).display()))?
            .read_to_end(&mut buf)?;
        Ok(buf)
    }

    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()> {
        let tmp = self.path(&format!("{name}.tmp"));
        fs::write(&tmp, bytes)?;
        fs::rename(&tmp, self.path(name))?;
        Ok(())
    }

    fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    fn remove(&self, name: &str) -> Result<()> {
        fs::remove_file(self.path(name))?;
        Ok(())
    }

    fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in fs::read_dir(&self.root)
            .with_context(|| format!("listing {}", self.root.display()))?
        {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                names.push(entry.file_name().to_string_lossy().into_owned());
            }
        }
        names.sort();
        Ok(names)
    }

    fn describe(&self) -> String {
        self.root.display().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_back() {
        let d = LocalDir::temp("io").unwrap();
        let mut f = d.create("wal.log").unwrap();
        f.append(b"hello ").unwrap();
        f.append(b"world").unwrap();
        f.sync().unwrap();
        assert_eq!(f.len(), 11);
        assert_eq!(d.read("wal.log").unwrap(), b"hello world");
    }

    #[test]
    fn append_to_preserves_content() {
        let d = LocalDir::temp("io2").unwrap();
        {
            let mut f = d.create("a").unwrap();
            f.append(b"one").unwrap();
        }
        {
            let mut f = d.append_to("a").unwrap();
            assert_eq!(f.len(), 3);
            f.append(b"two").unwrap();
        }
        assert_eq!(d.read("a").unwrap(), b"onetwo");
    }

    #[test]
    fn atomic_write_and_remove() {
        let d = LocalDir::temp("io3").unwrap();
        d.write_atomic("ck", b"v1").unwrap();
        d.write_atomic("ck", b"v2").unwrap();
        assert_eq!(d.read("ck").unwrap(), b"v2");
        assert!(d.exists("ck"));
        d.remove("ck").unwrap();
        assert!(!d.exists("ck"));
    }

    #[test]
    fn list_returns_sorted_file_names() {
        let d = LocalDir::temp("io5").unwrap();
        assert!(d.list().unwrap().is_empty());
        d.create("b.wal").unwrap().append(b"x").unwrap();
        d.create("a.wal").unwrap().append(b"y").unwrap();
        d.write_atomic("ck", b"v").unwrap();
        assert_eq!(d.list().unwrap(), vec!["a.wal", "b.wal", "ck"]);
    }

    #[test]
    fn missing_file_errors() {
        let d = LocalDir::temp("io4").unwrap();
        assert!(d.read("nope").is_err());
    }
}
