//! Shard server (`mongod`): owns a storage engine on its assigned
//! filesystem directory, serves inserts/finds for the chunks it owns,
//! triggers chunk splits, and participates in migrations.
//!
//! Query planning per shard (decision tree in docs/ARCHITECTURE.md §7):
//! 1. `$in` on node_id + the `(node_id, ts)` **compound index** → one
//!    bounded range scan per node value; candidates ≈ matches (exactly
//!    equal for the paper's canonical shape, whose `$lt` upper bound is
//!    known exclusive).
//! 2. `$in` on a single-field node_id index → point lookups; a ts range
//!    with its own index intersects, building the probe set from the
//!    smaller side.
//! 3. range on an indexed field → index range scan.
//! 4. otherwise → full collection scan.
//!
//! Candidates are **raw-matched** against the encoded record bytes
//! ([`RawDoc`]) — a rejected candidate never materializes a
//! [`Document`]; the canonical shape instead runs its (ts, node_id)
//! columns through the AOT **filter kernel**, extracted raw. Matching
//! records decode exactly once, when served (counted in
//! `shard.find_decodes`). Cursors stream from a resumable scan position
//! (index key or record id) instead of a fully materialized rid vector,
//! so sorted-limit queries cut the scan off early.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc;
use std::time::Instant;

use crate::mongo::bson::{Document, RawDoc, Value};
use crate::mongo::query::{Filter, FindOptions, SortDir};
use crate::mongo::sharding::chunk::ChunkMap;
use crate::mongo::sharding::migration::STAGING_COLLECTION;
use crate::mongo::storage::index::{encode_key, EncodedRange, Index};
use crate::mongo::storage::{Engine, EngineOptions, RecordId, StorageDir};
use crate::mongo::wire::{
    rpc, ConfigRequest, DeleteChunkReply, FindReply, InsertReply, MigrateBatchReply,
    ShardRequest, ShardStatsReply, StagedMigration, WireError,
};
use crate::metrics::{names, Registry};
use crate::runtime::Kernels;
use crate::util::ids::ShardId;

/// The sharded collection name (one sharded namespace, like the paper's
/// single OVIS metrics collection).
pub const COLLECTION: &str = "metrics";

/// Index names the planner recognizes.
const COMPOUND_INDEX: &str = "node_id_1_ts_1";
const TS_INDEX: &str = "ts_1";
const NODE_INDEX: &str = "node_id_1";

/// Keys/rids pulled into a streaming cursor per refill step — bounds
/// the work between mailbox turns without per-key round trips.
const SCAN_RUN: usize = 256;

/// One access path chosen by the planner.
enum ScanPlan {
    /// Materialized candidate rids (the index-intersection fallback and
    /// point-lookup plans); the residual matcher still runs.
    Rids(Vec<RecordId>),
    /// Resumable scan over `index`: encoded `[lo, hi)` ranges walked in
    /// order, yielding rids in index-key order. `rev` walks each range
    /// descending (the builder orders `ranges` to match the overall
    /// direction; every `rev` plan today is single-range).
    Index { index: String, ranges: Vec<EncodedRange>, rev: bool },
    /// Resumable full-collection scan in record-id order.
    Table,
}

/// A streaming scan position: plan + residual filter + resume state.
/// The position is a *key* (or record id), not an iterator, so the
/// store may mutate between getMores (concurrent ingest) and the scan
/// resumes correctly after it.
struct ScanCursor {
    plan: ScanPlan,
    /// Residual filter, evaluated raw per candidate.
    filter: Filter,
    /// Current range within an `Index` plan.
    range_idx: usize,
    /// Last fully consumed key (`Index` plans) — the resume point.
    after_key: Option<Vec<u8>>,
    /// Last consumed record id (`Table` plans).
    after_rid: Option<RecordId>,
    /// Consumed prefix of a `Rids` plan.
    pos: usize,
    /// Candidates pulled from the plan, awaiting the matcher.
    pending: VecDeque<RecordId>,
    /// The underlying scan is exhausted (pending may still hold rids).
    done: bool,
    /// Candidates examined / matched since the last metrics flush —
    /// batched locally so the hot loop takes no registry locks.
    seen: u64,
    matched: u64,
}

impl ScanCursor {
    fn new(plan: ScanPlan, filter: Filter) -> Self {
        Self {
            plan,
            filter,
            range_idx: 0,
            after_key: None,
            after_rid: None,
            pos: 0,
            pending: VecDeque::new(),
            done: false,
            seen: 0,
            matched: 0,
        }
    }
}

/// Where an open cursor's documents come from.
enum CursorSource {
    /// Matched rids known up front (the kernel fast path).
    Rids { rids: Vec<RecordId>, pos: usize },
    /// Documents materialized at plan time (non-indexed sort fallback:
    /// decoded once, sorted, projected, served from memory).
    Docs { buf: VecDeque<Document> },
    /// Streaming: candidates pulled lazily from a resumable scan,
    /// raw-matched, decoded only when served.
    Scan(ScanCursor),
}

struct CursorState {
    src: CursorSource,
    projection: Option<Vec<String>>,
    batch: usize,
    remaining: Option<usize>,
}

/// Decode one raw record for the reply — the read path's only full
/// materialization (projections decode just the projected fields). The
/// caller counts it into `shard.find_decodes`. A record that fails to
/// decode surfaces as a server error instead of killing the shard
/// thread: the engine's bytes are validated on every write and replay,
/// so reaching the error arm means on-disk or in-memory corruption the
/// client deserves to hear about.
fn materialize(raw: &[u8], projection: Option<&[String]>) -> Result<Document, WireError> {
    let rd = RawDoc::new(raw);
    match projection {
        Some(fields) => Ok(rd.project(fields)),
        None => rd
            .decode()
            .map_err(|e| WireError::Server(format!("corrupt record: {e}"))),
    }
}

fn cursor_exhausted(cur: &CursorState) -> bool {
    match &cur.src {
        CursorSource::Rids { rids, pos } => *pos >= rids.len(),
        CursorSource::Docs { buf } => buf.is_empty(),
        CursorSource::Scan(scan) => scan.done && scan.pending.is_empty(),
    }
}

/// Shard server state + event loop.
pub struct ShardServer {
    id: ShardId,
    engine: Engine,
    map: ChunkMap,
    config: mpsc::Sender<ConfigRequest>,
    kernels: Kernels,
    metrics: Registry,
    cursors: HashMap<u64, CursorState>,
    next_cursor: u64,
    /// Split a chunk when its (position-histogram) doc count exceeds this.
    split_threshold: u64,
    /// Position histogram: key position → docs at that position. Range
    /// sums give per-chunk counts; medians give split points.
    positions: std::collections::BTreeMap<u64, u32>,
    default_batch: usize,
    /// Migration staging on this destination — `(range, donor,
    /// committed)`, mirroring the durable `__migration` collection
    /// (rebuilt from it after a restart).
    staging: Option<((u64, u64), ShardId, bool)>,
    /// Staged data documents (meta records excluded).
    staged_docs: u64,
}

impl ShardServer {
    /// Open the shard's engine on `dir` (recovering any persisted
    /// state) and build the server. `engine_opts` carries the storage
    /// lifecycle: journaling, checkpoint compression, and the
    /// auto-compaction threshold this server enforces after every group
    /// commit.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: ShardId,
        dir: Box<dyn StorageDir>,
        map: ChunkMap,
        config: mpsc::Sender<ConfigRequest>,
        kernels: Kernels,
        metrics: Registry,
        engine_opts: EngineOptions,
        split_threshold: u64,
        default_batch: usize,
    ) -> anyhow::Result<Self> {
        let mut engine = Engine::open_with(dir, engine_opts)?;
        engine.create_collection(COLLECTION);
        let mut s = Self {
            id,
            engine,
            map,
            config,
            kernels,
            metrics,
            cursors: HashMap::new(),
            next_cursor: 1,
            split_threshold,
            positions: Default::default(),
            default_batch,
            staging: None,
            staged_docs: 0,
        };
        // Rebuild the position histogram from recovered records (second
        // job re-attaching to persisted Lustre data) — raw key-field
        // probes, no per-record decode. Staged migration documents are
        // not live and never enter the histogram.
        let recovered: Vec<u64> = s
            .engine
            .scan_raw_from(COLLECTION, None)
            .filter_map(|(_, raw)| s.position_of_raw(&RawDoc::new(raw)))
            .collect();
        for pos in recovered {
            *s.positions.entry(pos).or_insert(0) += 1;
        }
        // Rebuild migration staging state: a killed migration leaves its
        // staging collection behind, and the cluster's reconciliation
        // pass (`sharding::migration::recover`) needs its identity.
        if s.engine.stats(STAGING_COLLECTION).docs > 0 {
            let mut range = (0u64, 0u64);
            let mut from = id;
            let mut committed = false;
            let mut meta_seen = false;
            for (_, d) in s.engine.scan(STAGING_COLLECTION) {
                if d.get_i64("__migmeta").is_some() {
                    meta_seen = true;
                    // Positions are u64; stored as bit-cast i64 (exact
                    // round trip).
                    range = (
                        d.get_i64("lo").unwrap_or(0) as u64,
                        d.get_i64("hi").unwrap_or(0) as u64,
                    );
                    from = ShardId(d.get_i64("from").unwrap_or(0) as u32);
                } else if d.get_i64("__migcommit").is_some() {
                    committed = true;
                } else {
                    s.staged_docs += 1;
                }
            }
            // A meta-less staging is torn pre-commit garbage: surface it
            // uncommitted so reconciliation rolls it back.
            s.staging = Some((range, from, committed && meta_seen));
        }
        Ok(s)
    }

    /// Spawn the event loop thread; returns its mailbox and join handle.
    pub fn spawn(self) -> (mpsc::Sender<ShardRequest>, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let join = self.spawn_with(rx);
        (tx, join)
    }

    /// Spawn on a pre-created channel (the cluster wires mailboxes before
    /// any thread starts).
    pub fn spawn_with(mut self, rx: mpsc::Receiver<ShardRequest>) -> std::thread::JoinHandle<()> {
        let name = format!("{}", self.id);
        std::thread::Builder::new()
            .name(name)
            .spawn(move || self.run(rx))
            // lint: allow(panic, thread spawn fails only on OS resource
            // exhaustion at cluster startup, before any data is live)
            .expect("spawn shard thread")
    }

    fn run(&mut self, rx: mpsc::Receiver<ShardRequest>) {
        while let Ok(req) = rx.recv() {
            match req {
                ShardRequest::Shutdown => break,
                ShardRequest::SetMap { map } => {
                    self.map = map;
                }
                ShardRequest::InsertBatch { version, docs, reply } => {
                    let t = Instant::now();
                    let r = self.handle_insert_many(version, docs);
                    self.metrics
                        .observe(names::SHARD_INSERT_BATCH_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::Find { filter, opts, reply } => {
                    let t = Instant::now();
                    let r = self.handle_find(&filter, &opts);
                    self.metrics
                        .observe(names::SHARD_FIND_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::GetMore { cursor, reply } => {
                    let _ = reply.send(self.handle_get_more(cursor));
                }
                ShardRequest::Count { filter, reply } => {
                    let t = Instant::now();
                    let r = self.handle_count(&filter);
                    self.metrics
                        .observe(names::SHARD_COUNT_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::CreateIndex { spec, reply } => {
                    let r = self
                        .engine
                        .create_index(COLLECTION, spec)
                        .map_err(|e| WireError::Server(e.to_string()));
                    let _ = reply.send(r);
                }
                ShardRequest::MigrateBatch { range, after, limit, reply } => {
                    let t = Instant::now();
                    let r = self.handle_migrate_batch(range, after, limit);
                    self.metrics
                        .observe(names::SHARD_MIGRATE_BATCH_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::StageChunk { range, from, docs, reply } => {
                    let r = self.handle_stage_chunk(range, from, docs);
                    let _ = reply.send(r);
                }
                ShardRequest::CommitStaged { reply } => {
                    let _ = reply.send(self.handle_commit_staged());
                }
                ShardRequest::PublishStaged { reply } => {
                    let _ = reply.send(self.handle_publish_staged());
                }
                ShardRequest::AbortStaged { reply } => {
                    let _ = reply.send(self.handle_abort_staged());
                }
                ShardRequest::DeleteChunk { range, compact, reply } => {
                    let r = self.delete_range(range, compact);
                    let _ = reply.send(r);
                }
                ShardRequest::StagedState { reply } => {
                    let _ = reply.send(self.staged_state());
                }
                ShardRequest::Stats { reply } => {
                    let _ = reply.send(self.stats());
                }
                ShardRequest::Checkpoint { reply } => {
                    let r = self
                        .engine
                        .checkpoint()
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        // Admin-command trigger — one of the three
                        // distinct `shard.checkpoints` sites (see the
                        // constant's docs in `metrics::names`).
                        self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
                    }
                    let _ = reply.send(r);
                }
            }
        }
    }

    /// Background compaction hook, run after every group commit: once
    /// the engine has journaled past its configured threshold, write a
    /// checkpoint and rotate/truncate the journal so the shard's
    /// on-disk footprint on the shared filesystem stays bounded.
    ///
    /// A compaction failure must not fail the triggering write — the
    /// batch is already durable in the journal — so errors are counted
    /// and logged, and the next group commit retries (the byte counter
    /// keeps growing until a checkpoint succeeds).
    fn maybe_compact(&mut self) {
        match self.engine.maybe_checkpoint() {
            Ok(Some(ck)) => {
                // Threshold trigger — one of the three distinct
                // `shard.checkpoints` sites (see `metrics::names`).
                self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
                if ck.full {
                    // Generation 1 or a chain rebase: the one compaction
                    // whose cost scales with the live set.
                    self.metrics.counter(names::SHARD_REBASES).inc();
                }
                self.metrics.counter(names::SHARD_DELTA_BYTES).add(ck.delta_bytes);
                self.metrics
                    .counter(names::SHARD_SEGMENTS_TRUNCATED)
                    .add(ck.segments_truncated);
                self.metrics
                    .counter(names::SHARD_JOURNAL_BYTES_TRUNCATED)
                    .add(ck.journal_bytes_truncated);
            }
            Ok(None) => {}
            Err(e) => {
                self.metrics.counter(names::SHARD_CHECKPOINT_ERRORS).inc();
                eprintln!("warn: {}: background checkpoint failed: {e:#}", self.id);
            }
        }
    }

    /// Shard-key position of a document (`None` if key fields missing).
    fn position_of(&self, doc: &Document) -> Option<u64> {
        let node = doc.get_i64("node_id")? as u32;
        let ts = doc.get_i64("ts")? as u32;
        Some(self.map.key.position(node, ts))
    }

    /// [`Self::position_of`] read straight from encoded record bytes —
    /// the scans that only need positions (histogram rebuild, range
    /// deletes, migration batching) never decode whole documents.
    fn position_of_raw(&self, doc: &RawDoc) -> Option<u64> {
        let node = doc.get_i64("node_id")? as u32;
        let ts = doc.get_i64("ts")? as u32;
        Some(self.map.key.position(node, ts))
    }

    /// Bulk-ingest leg on the shard: version handshake, owner filtering,
    /// then the owned run is indexed and journaled as a whole batch with
    /// a single group commit.
    fn handle_insert_many(
        &mut self,
        version: u64,
        docs: Vec<Document>,
    ) -> Result<InsertReply, WireError> {
        // Version handshake: if the router is ahead, catch up from the
        // config server; if the router is behind, tell it to refresh.
        if version > self.map.version {
            if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                self.map = map;
            }
        }
        if version != self.map.version {
            self.metrics.counter(names::SHARD_STALE_VERSION).inc();
            return Err(WireError::StaleVersion { current: self.map.version });
        }

        // Split the batch into owned documents and wrong-owner rejects,
        // then index + journal the owned run as ONE multi-record frame.
        let mut wrong_owner = Vec::new();
        let mut touched_chunks: Vec<usize> = Vec::new();
        let mut owned_docs: Vec<Document> = Vec::with_capacity(docs.len());
        let mut owned_pos: Vec<u64> = Vec::with_capacity(docs.len());
        for (i, doc) in docs.into_iter().enumerate() {
            let Some(pos) = self.position_of(&doc) else {
                wrong_owner.push(i);
                continue;
            };
            let chunk = self.map.chunk_of(pos);
            if self.map.owners[chunk] != self.id {
                wrong_owner.push(i);
                continue;
            }
            if !touched_chunks.contains(&chunk) {
                touched_chunks.push(chunk);
            }
            owned_docs.push(doc);
            owned_pos.push(pos);
        }
        let inserted = owned_docs.len();
        self.engine
            .insert_many(COLLECTION, &owned_docs)
            .map_err(|e| WireError::Server(e.to_string()))?;
        for pos in owned_pos {
            *self.positions.entry(pos).or_insert(0) += 1;
        }
        // Group commit once per batch: one journal frame, one sync.
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
        self.metrics.counter(names::SHARD_DOCS_INSERTED).add(inserted as u64);
        self.maybe_compact();

        // Split any chunk that crossed the threshold.
        for chunk in touched_chunks {
            self.maybe_split(chunk);
        }
        Ok(InsertReply { inserted, wrong_owner })
    }

    fn chunk_doc_count(&self, chunk: usize) -> u64 {
        let (lo, hi) = self.map.chunk_range(chunk);
        self.positions.range(lo..=hi).map(|(_, c)| *c as u64).sum()
    }

    /// Median position within a chunk (split point).
    fn chunk_median(&self, chunk: usize) -> Option<u64> {
        let (lo, hi) = self.map.chunk_range(chunk);
        let total: u64 = self.chunk_doc_count(chunk);
        if total < 2 {
            return None;
        }
        let mut seen = 0u64;
        for (&pos, &c) in self.positions.range(lo..=hi) {
            seen += c as u64;
            if seen >= total / 2 {
                // Split point must be < hi and >= lo.
                if pos >= hi {
                    return None;
                }
                return Some(pos);
            }
        }
        None
    }

    fn maybe_split(&mut self, chunk: usize) {
        if self.chunk_doc_count(chunk) <= self.split_threshold {
            return;
        }
        let Some(at) = self.chunk_median(chunk) else { return };
        let seen = self.map.version;
        if let Ok(Ok(check)) = rpc(&self.config, |reply| ConfigRequest::ReportSplit {
            seen_version: seen,
            chunk,
            at,
            reply,
        }) {
            use crate::mongo::sharding::config_server::VersionCheck;
            match check {
                VersionCheck::Ok => {
                    self.metrics.counter(names::SHARD_SPLITS).inc();
                    // Config pushes SetMap to everyone (including us); we
                    // may process it on the next loop turn. Update our
                    // local copy eagerly to keep counting accurate.
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.map = map;
                    }
                }
                VersionCheck::Stale { .. } => {
                    self.metrics.counter(names::SHARD_SPLIT_STALE).inc();
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.map = map;
                    }
                }
            }
        }
    }

    /// The paper's canonical query shape, *exactly*: a conjunction of
    /// `ts >= lo` (`$gte`), `ts < hi` (`$lt`) and `node_id $in [ints]`
    /// and nothing else — the only shape the filter kernel's predicate
    /// `lo <= ts < hi && node in set` evaluates completely. Any other
    /// filter takes the scalar matcher path.
    fn canonical_shape(filter: &Filter) -> Option<(u32, u32, Vec<u32>)> {
        use crate::mongo::query::CmpOp;
        let conjuncts = match filter {
            Filter::And(fs) => fs.as_slice(),
            f @ Filter::In { .. } => std::slice::from_ref(f),
            _ => return None,
        };
        let mut lo: Option<u32> = None;
        let mut hi: Option<u32> = None;
        let mut nodes: Option<Vec<u32>> = None;
        for c in conjuncts {
            match c {
                Filter::Cmp { field, op: CmpOp::Gte, value }
                    if field == "ts" && lo.is_none() =>
                {
                    let v = value.as_i64()?;
                    if !(0..=u32::MAX as i64).contains(&v) {
                        return None;
                    }
                    lo = Some(v as u32);
                }
                Filter::Cmp { field, op: CmpOp::Lt, value }
                    if field == "ts" && hi.is_none() =>
                {
                    let v = value.as_i64()?;
                    if !(0..=u32::MAX as i64).contains(&v) {
                        return None;
                    }
                    hi = Some(v as u32);
                }
                Filter::In { field, values } if field == "node_id" && nodes.is_none() => {
                    let mut ids = Vec::with_capacity(values.len());
                    for v in values {
                        let n = v.as_i64()?;
                        if !(0..=u32::MAX as i64).contains(&n) {
                            return None;
                        }
                        ids.push(n as u32);
                    }
                    nodes = Some(ids);
                }
                _ => return None, // anything else → matcher path
            }
        }
        Some((lo.unwrap_or(0), hi.unwrap_or(u32::MAX), nodes?))
    }

    fn handle_find(
        &mut self,
        filter: &Filter,
        opts: &FindOptions,
    ) -> Result<FindReply, WireError> {
        let src = self.plan_source(filter, opts)?;
        let batch = opts.batch_size.unwrap_or(self.default_batch);
        let mut cur = CursorState {
            src,
            projection: opts.projection.clone(),
            batch,
            remaining: opts.limit,
        };
        let reply = self.serve_batch(&mut cur)?;
        if reply.cursor.is_some() {
            let id = self.next_cursor;
            self.next_cursor += 1;
            self.cursors.insert(id, cur);
            Ok(FindReply { docs: reply.docs, cursor: Some(id) })
        } else {
            Ok(reply)
        }
    }

    /// Build the cursor source for a find: the index-ordered sort path,
    /// the kernel fast path, or a streaming scan with the raw matcher.
    fn plan_source(
        &self,
        filter: &Filter,
        opts: &FindOptions,
    ) -> Result<CursorSource, WireError> {
        if let Some((field, dir)) = &opts.sort {
            // Index-ordered sort: a single-field index on the sort field
            // serves rids in key order (reverse scan for Desc) — the
            // limit cuts the scan off early instead of materializing,
            // decoding, and sorting every match. Worth it when the
            // index walk is bounded by the *filter* — it ranges the
            // sort field, or matches everything. A selective filter on
            // a different field (even with a limit: scarce matches
            // would walk the whole sort index before filling it) is
            // better served by its own plan + decode-once sort (below).
            let sort_index = format!("{field}_1");
            let bounded =
                filter.index_range(field).is_some() || matches!(filter, Filter::True);
            if bounded && self.engine.index(COLLECTION, &sort_index).is_some() {
                self.metrics.counter(names::SHARD_PLAN_INDEX_SORT).inc();
                let (lo, hi) = filter.index_range(field).unwrap_or((None, None));
                let ranges =
                    vec![Index::superset_bounds(&[], lo.as_ref(), hi.as_ref())];
                return Ok(CursorSource::Scan(ScanCursor::new(
                    ScanPlan::Index {
                        index: sort_index,
                        ranges,
                        rev: *dir == SortDir::Desc,
                    },
                    filter.clone(),
                )));
            }
            // Sort field not indexed: drain the unsorted plan, decoding
            // each match exactly once, sort in memory, serve from there.
            return self.sorted_fallback(filter, opts, field, *dir);
        }
        // Kernel fast path for the canonical shape over planned
        // candidates — columns extracted raw, no document materialized.
        if let Some((lo, hi, nodes)) = Self::canonical_shape(filter) {
            let words = self.kernels.shapes().filter_w;
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                self.metrics.counter(names::SHARD_FIND_KERNEL_PATH).inc();
                let candidates = self.drain_plan(self.plan_scan(filter));
                self.metrics
                    .counter(names::SHARD_FIND_CANDIDATES)
                    .add(candidates.len() as u64);
                let rids = self.kernel_filter(&candidates, lo, hi, &nodes)?;
                self.metrics.counter(names::SHARD_FIND_MATCHES).add(rids.len() as u64);
                return Ok(CursorSource::Rids { rids, pos: 0 });
            }
        }
        // General path: stream the planned scan through the raw matcher.
        self.metrics.counter(names::SHARD_FIND_MATCHER_PATH).inc();
        Ok(CursorSource::Scan(ScanCursor::new(self.plan_scan(filter), filter.clone())))
    }

    /// Choose an access path for `filter` — the planner decision tree
    /// (module docs). Streaming plans yield candidates lazily; the
    /// `Rids` plan is the materialized intersection/point fallback.
    fn plan_scan(&self, filter: &Filter) -> ScanPlan {
        // 1. `$in` on node_id.
        if let Some(values) = filter.in_values("node_id") {
            let ts_range = filter.index_range("ts");
            // 1a. Compound (node_id, ts): one bounded range scan per
            // node. For the canonical shape the `$lt` upper bound is
            // known exclusive, so the bounds are *exact* — candidates
            // == matches; any other operator mix gets an inclusive
            // superset and the residual filter.
            if self.engine.index(COLLECTION, COMPOUND_INDEX).is_some() {
                self.metrics.counter(names::SHARD_PLAN_COMPOUND).inc();
                // Exact bounds demand that the filter really pins BOTH
                // ts sides ($gte lo and $lt hi): a canonical_shape
                // default (0 / u32::MAX) encoded as an exact Int bound
                // would wrongly exclude documents whose ts is missing
                // or non-Int — keys of another type rank that a
                // ts-unconstrained filter still matches. Partial or
                // absent ts bounds take the inclusive superset and the
                // residual filter.
                let both_ts_bounds = matches!(&ts_range, Some((Some(_), Some(_))));
                let ranges: Vec<EncodedRange> = match Self::canonical_shape(filter) {
                    Some((lo, hi, nodes)) if both_ts_bounds => nodes
                        .iter()
                        .map(|&n| {
                            let node = Value::Int(n as i64);
                            (
                                encode_key(&[&node, &Value::Int(lo as i64)]),
                                encode_key(&[&node, &Value::Int(hi as i64)]),
                            )
                        })
                        .collect(),
                    _ => {
                        let (lo, hi) = match &ts_range {
                            Some((lo, hi)) => (lo.as_ref(), hi.as_ref()),
                            None => (None, None),
                        };
                        values
                            .iter()
                            .map(|v| Index::superset_bounds(&[v], lo, hi))
                            .collect()
                    }
                };
                return ScanPlan::Index {
                    index: COMPOUND_INDEX.to_string(),
                    ranges,
                    rev: false,
                };
            }
            // 1b. Single node_id index: point lookups; with a ts index
            // and range, intersect — the probe set is built from the
            // smaller side and the larger side streams through it.
            if let Some(idx) = self.engine.index(COLLECTION, NODE_INDEX) {
                let in_len: usize = values.iter().map(|v| idx.point_len(&[v])).sum();
                if let Some((lo, hi)) = &ts_range {
                    if let Some(ts_idx) = self.engine.index(COLLECTION, TS_INDEX) {
                        self.metrics.counter(names::SHARD_PLAN_INTERSECT).inc();
                        let ts_len =
                            ts_idx.range_superset_len(lo.as_ref(), hi.as_ref());
                        let rids: Vec<RecordId> = if in_len <= ts_len {
                            let probe: HashSet<RecordId> = values
                                .iter()
                                .flat_map(|v| idx.point_iter(&[v]))
                                .collect();
                            ts_idx
                                .range_superset(lo.as_ref(), hi.as_ref())
                                .filter(|r| probe.contains(r))
                                .collect()
                        } else {
                            let probe: HashSet<RecordId> = ts_idx
                                .range_superset(lo.as_ref(), hi.as_ref())
                                .collect();
                            values
                                .iter()
                                .flat_map(|v| idx.point_iter(&[v]))
                                .filter(|r| probe.contains(r))
                                .collect()
                        };
                        return ScanPlan::Rids(rids);
                    }
                }
                self.metrics.counter(names::SHARD_PLAN_IN_POINTS).inc();
                let mut rids = Vec::with_capacity(in_len);
                for v in values {
                    rids.extend(idx.point_iter(&[v]));
                }
                return ScanPlan::Rids(rids);
            }
        }
        // 2. Range on indexed ts (inclusive superset; the residual
        // filter restores exact operator semantics).
        if let Some((lo, hi)) = filter.index_range("ts") {
            if self.engine.index(COLLECTION, TS_INDEX).is_some() {
                self.metrics.counter(names::SHARD_PLAN_TS_RANGE).inc();
                return ScanPlan::Index {
                    index: TS_INDEX.to_string(),
                    ranges: vec![Index::superset_bounds(&[], lo.as_ref(), hi.as_ref())],
                    rev: false,
                };
            }
        }
        // 2b. Range/eq on node_id: its own index, or the compound
        // prefix (a (node_id, ts) scan bounded on node_id alone).
        if let Some((lo, hi)) = filter.index_range("node_id") {
            for index in [NODE_INDEX, COMPOUND_INDEX] {
                if self.engine.index(COLLECTION, index).is_some() {
                    self.metrics.counter(names::SHARD_PLAN_NODE_RANGE).inc();
                    return ScanPlan::Index {
                        index: index.to_string(),
                        ranges: vec![Index::superset_bounds(
                            &[],
                            lo.as_ref(),
                            hi.as_ref(),
                        )],
                        rev: false,
                    };
                }
            }
        }
        // 3. Full scan.
        self.metrics.counter(names::SHARD_PLAN_FULL_SCAN).inc();
        ScanPlan::Table
    }

    /// Drain a plan into a candidate rid vector (the kernel path wants
    /// whole columns).
    fn drain_plan(&self, plan: ScanPlan) -> Vec<RecordId> {
        let mut scan = match plan {
            ScanPlan::Rids(rids) => return rids,
            plan => ScanCursor::new(plan, Filter::True),
        };
        let mut out = Vec::new();
        loop {
            out.extend(scan.pending.drain(..));
            if !self.refill_scan(&mut scan) {
                break;
            }
        }
        out
    }

    /// Run the AOT filter kernel over the candidates' (ts, node_id)
    /// columns — extracted from the raw record bytes, no per-candidate
    /// document decode — and return the matching rids in order.
    fn kernel_filter(
        &self,
        candidates: &[RecordId],
        lo: u32,
        hi: u32,
        nodes: &[u32],
    ) -> Result<Vec<RecordId>, WireError> {
        let words = self.kernels.shapes().filter_w;
        let mut ts_col = Vec::with_capacity(candidates.len());
        let mut node_col = Vec::with_capacity(candidates.len());
        let mut rids = Vec::with_capacity(candidates.len());
        for &rid in candidates {
            if let Some(raw) = self.engine.fetch_raw(COLLECTION, rid) {
                let d = RawDoc::new(raw);
                ts_col.push(d.get_i64("ts").unwrap_or(-1).max(0) as u32);
                node_col.push(d.get_i64("node_id").unwrap_or(0).max(0) as u32);
                rids.push(rid);
            }
        }
        let bitmap = crate::runtime::fallback::build_bitmap(nodes.iter().copied(), words);
        let out = self
            .kernels
            .filter(&ts_col, &node_col, lo, hi, &bitmap)
            .map_err(|e| WireError::Server(e.to_string()))?;
        Ok(rids
            .iter()
            .zip(&out.mask)
            .filter(|(_, &m)| m == 1)
            .map(|(&rid, _)| rid)
            .collect())
    }

    /// Non-indexed sort field: drain the unsorted plan, decoding each
    /// match exactly once, sort the decoded documents, and serve the
    /// cursor from memory. (The old path decoded every candidate to
    /// match, every match again to sort, and every served doc a third
    /// time.)
    fn sorted_fallback(
        &self,
        filter: &Filter,
        opts: &FindOptions,
        field: &str,
        dir: SortDir,
    ) -> Result<CursorSource, WireError> {
        let mut scan = ScanCursor::new(self.plan_scan(filter), filter.clone());
        let mut docs: Vec<Document> = Vec::new();
        while let Some((_, raw)) = self.next_scan_match(&mut scan) {
            docs.push(
                RawDoc::new(raw)
                    .decode()
                    .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?,
            );
        }
        self.metrics.counter(names::SHARD_FIND_DECODES).add(docs.len() as u64);
        self.flush_scan_metrics(&mut scan);
        docs.sort_by(|a, b| {
            let o = a
                .get(field)
                .unwrap_or(&Value::Null)
                .cmp_total(b.get(field).unwrap_or(&Value::Null));
            match dir {
                SortDir::Asc => o,
                SortDir::Desc => o.reverse(),
            }
        });
        // The cursor can only ever serve `limit` documents — don't keep
        // (or project) the sorted tail beyond it.
        if let Some(limit) = opts.limit {
            docs.truncate(limit);
        }
        let buf = docs
            .into_iter()
            .map(|d| match &opts.projection {
                Some(fields) => d.project(fields),
                None => d,
            })
            .collect();
        Ok(CursorSource::Docs { buf })
    }

    /// Advance a streaming scan to its next match: pull candidates from
    /// the resumable plan, raw-match each against the encoded bytes,
    /// and return the matching record id *with* its bytes (one record
    /// lookup serves both the match and the materialization).
    /// Candidate/match tallies accumulate on the cursor (flushed to the
    /// registry per served batch).
    fn next_scan_match<'e>(
        &'e self,
        scan: &mut ScanCursor,
    ) -> Option<(RecordId, &'e [u8])> {
        loop {
            while let Some(rid) = scan.pending.pop_front() {
                scan.seen += 1;
                let Some(raw) = self.engine.fetch_raw(COLLECTION, rid) else {
                    continue;
                };
                if scan.filter.matches_raw(&RawDoc::new(raw)) {
                    scan.matched += 1;
                    return Some((rid, raw));
                }
            }
            if scan.done || !self.refill_scan(scan) {
                scan.done = true;
                return None;
            }
        }
    }

    /// Pull the next key run (index plans) or record-id run (table
    /// scans) into `pending`. Returns false when the scan is exhausted.
    fn refill_scan(&self, scan: &mut ScanCursor) -> bool {
        match &scan.plan {
            ScanPlan::Rids(rids) => {
                if scan.pos >= rids.len() {
                    return false;
                }
                let end = (scan.pos + SCAN_RUN).min(rids.len());
                scan.pending.extend(rids[scan.pos..end].iter().copied());
                scan.pos = end;
                true
            }
            ScanPlan::Index { index, ranges, rev } => {
                let Some(idx) = self.engine.index(COLLECTION, index) else {
                    return false;
                };
                while scan.range_idx < ranges.len() {
                    let range = &ranges[scan.range_idx];
                    if let Some(key) = idx.pull_range(
                        range,
                        scan.after_key.as_deref(),
                        *rev,
                        SCAN_RUN,
                        &mut scan.pending,
                    ) {
                        scan.after_key = Some(key);
                        return true;
                    }
                    scan.range_idx += 1;
                    scan.after_key = None;
                }
                false
            }
            ScanPlan::Table => {
                let before = scan.pending.len();
                for (rid, _) in self
                    .engine
                    .scan_raw_from(COLLECTION, scan.after_rid)
                    .take(SCAN_RUN)
                {
                    scan.after_rid = Some(rid);
                    scan.pending.push_back(rid);
                }
                scan.pending.len() > before
            }
        }
    }

    /// Publish (and reset) a scan's candidate/match tallies — batched
    /// so the per-candidate hot loop takes no registry locks.
    fn flush_scan_metrics(&self, scan: &mut ScanCursor) {
        if scan.seen > 0 {
            self.metrics.counter(names::SHARD_FIND_CANDIDATES).add(scan.seen);
            scan.seen = 0;
        }
        if scan.matched > 0 {
            self.metrics.counter(names::SHARD_FIND_MATCHES).add(scan.matched);
            scan.matched = 0;
        }
    }

    fn serve_batch(&self, cur: &mut CursorState) -> Result<FindReply, WireError> {
        let mut docs = Vec::with_capacity(cur.batch.min(64));
        let mut decoded = 0u64;
        while docs.len() < cur.batch && cur.remaining != Some(0) {
            let doc = match &mut cur.src {
                CursorSource::Rids { rids, pos } => {
                    let mut out = None;
                    while out.is_none() && *pos < rids.len() {
                        let rid = rids[*pos];
                        *pos += 1;
                        if let Some(raw) = self.engine.fetch_raw(COLLECTION, rid) {
                            decoded += 1;
                            out = Some(materialize(raw, cur.projection.as_deref())?);
                        }
                    }
                    out
                }
                // Sorted-fallback documents were decoded (and projected)
                // when the cursor was built.
                CursorSource::Docs { buf } => buf.pop_front(),
                CursorSource::Scan(scan) => match self.next_scan_match(scan) {
                    Some((_, raw)) => {
                        decoded += 1;
                        Some(materialize(raw, cur.projection.as_deref())?)
                    }
                    None => None,
                },
            };
            let Some(doc) = doc else { break };
            docs.push(doc);
            if let Some(r) = cur.remaining.as_mut() {
                *r -= 1;
            }
        }
        if decoded > 0 {
            self.metrics.counter(names::SHARD_FIND_DECODES).add(decoded);
        }
        if let CursorSource::Scan(scan) = &mut cur.src {
            self.flush_scan_metrics(scan);
        }
        let more = !cursor_exhausted(cur) && cur.remaining != Some(0);
        Ok(FindReply { docs, cursor: more.then_some(0) })
    }

    /// Count without materializing documents for the client. The
    /// canonical shape runs the kernel over raw-extracted columns; any
    /// other filter streams the plan through the raw matcher — counting
    /// decodes nothing at all.
    fn handle_count(&mut self, filter: &Filter) -> Result<u64, WireError> {
        // Counts examine candidates exactly like finds do, so both
        // branches publish the candidate/match tallies — the ratio the
        // planner regressions read covers finds and counts alike.
        if let Some((lo, hi, nodes)) = Self::canonical_shape(filter) {
            let words = self.kernels.shapes().filter_w;
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                let candidates = self.drain_plan(self.plan_scan(filter));
                self.metrics
                    .counter(names::SHARD_FIND_CANDIDATES)
                    .add(candidates.len() as u64);
                let n = self.kernel_filter(&candidates, lo, hi, &nodes)?.len() as u64;
                self.metrics.counter(names::SHARD_FIND_MATCHES).add(n);
                return Ok(n);
            }
        }
        let mut scan = ScanCursor::new(self.plan_scan(filter), filter.clone());
        let mut n = 0u64;
        while self.next_scan_match(&mut scan).is_some() {
            n += 1;
        }
        self.flush_scan_metrics(&mut scan);
        Ok(n)
    }

    fn handle_get_more(&mut self, cursor: u64) -> Result<FindReply, WireError> {
        let mut cur = self
            .cursors
            .remove(&cursor)
            .ok_or(WireError::UnknownCursor(cursor))?;
        let mut reply = self.serve_batch(&mut cur)?;
        if reply.cursor.is_some() {
            self.cursors.insert(cursor, cur);
            reply.cursor = Some(cursor);
        }
        Ok(reply)
    }

    /// Migration source: one bounded batch of the range, resuming from
    /// the record-id cursor `after`. The scan itself is capped (not
    /// only the match count), so even a sparse range never holds the
    /// event loop for more than a bounded walk — invariant IM2.
    fn handle_migrate_batch(
        &self,
        range: (u64, u64),
        after: Option<u64>,
        limit: usize,
    ) -> Result<MigrateBatchReply, WireError> {
        let limit = limit.max(1);
        let scan_cap = limit.saturating_mul(8).max(4096);
        let mut docs = Vec::new();
        let mut last = None;
        let mut scanned = 0usize;
        let mut done = true;
        // Raw walk: only records actually inside the migrating range
        // decode; the (typically much larger) out-of-range remainder is
        // probed for its key fields and skipped.
        for (rid, raw) in self.engine.scan_raw_from(COLLECTION, after) {
            scanned += 1;
            last = Some(rid);
            let rd = RawDoc::new(raw);
            if let Some(pos) = self.position_of_raw(&rd) {
                if range.0 <= pos && pos <= range.1 {
                    docs.push(
                        rd.decode()
                            .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?,
                    );
                }
            }
            if docs.len() >= limit || scanned >= scan_cap {
                done = false;
                break;
            }
        }
        Ok(MigrateBatchReply { docs, last, done })
    }

    /// Migration destination: stage one copied batch in the
    /// `__migration` collection — durable via the same group-committed
    /// `insert_many` path as ingest, but invisible to queries until
    /// published. The first batch pins the migration identity (range +
    /// donor) in a meta record, journaled ahead of any data.
    fn handle_stage_chunk(
        &mut self,
        range: (u64, u64),
        from: ShardId,
        docs: Vec<Document>,
    ) -> Result<usize, WireError> {
        self.engine.create_collection(STAGING_COLLECTION);
        match self.staging {
            Some((_, _, true)) => {
                return Err(WireError::Server(
                    "a committed migration awaits publish".into(),
                ));
            }
            Some((r, f, false)) if r != range || f != from => {
                return Err(WireError::Server("another migration is staged".into()));
            }
            Some(_) => {}
            None => {
                let meta = Document::new()
                    .set("__migmeta", 1i64)
                    .set("lo", range.0 as i64)
                    .set("hi", range.1 as i64)
                    .set("from", from.0 as i64);
                self.engine
                    .insert_many(STAGING_COLLECTION, &[meta])
                    .map_err(|e| WireError::Server(e.to_string()))?;
                self.staging = Some((range, from, false));
            }
        }
        let n = docs.len();
        self.engine
            .insert_many(STAGING_COLLECTION, &docs)
            .map_err(|e| WireError::Server(e.to_string()))?;
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        self.staged_docs += n as u64;
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_IN).add(n as u64);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: durably write the commit marker — one
    /// journal frame plus a sync. From the moment this replies, the
    /// migration can only roll forward (M3). Idempotent.
    fn handle_commit_staged(&mut self) -> Result<u64, WireError> {
        let Some((range, from, committed)) = self.staging else {
            return Err(WireError::Server("nothing staged".into()));
        };
        if !committed {
            let marker = Document::new().set("__migcommit", 1i64);
            self.engine
                .insert_many(STAGING_COLLECTION, &[marker])
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
            self.staging = Some((range, from, true));
        }
        Ok(self.staged_docs)
    }

    /// Migration destination: publish the staged documents into the
    /// live collection as **one atomic move frame** (replay never sees
    /// them in both collections or in neither), then drop the meta
    /// records. Idempotent: an empty or marker-only staging publishes
    /// nothing and just cleans up.
    fn handle_publish_staged(&mut self) -> Result<u64, WireError> {
        if self.staging.is_none() && self.engine.stats(STAGING_COLLECTION).docs == 0 {
            return Ok(0);
        }
        // Raw pass: the publish needs rids and key positions only —
        // staged documents move as encoded bytes, never decoding here.
        let mut data: Vec<(RecordId, Option<u64>)> = Vec::new();
        let mut meta: Vec<RecordId> = Vec::new();
        for (rid, raw) in self.engine.scan_raw_from(STAGING_COLLECTION, None) {
            let rd = RawDoc::new(raw);
            if rd.get_i64("__migmeta").is_some() || rd.get_i64("__migcommit").is_some() {
                meta.push(rid);
            } else {
                data.push((rid, self.position_of_raw(&rd)));
            }
        }
        let rids: Vec<RecordId> = data.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        self.engine
            .move_many(STAGING_COLLECTION, COLLECTION, &rids)
            .map_err(|e| WireError::Server(e.to_string()))?;
        if !meta.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &meta)
                .map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        for (_, pos) in &data {
            if let Some(pos) = pos {
                *self.positions.entry(*pos).or_insert(0) += 1;
            }
        }
        self.staging = None;
        self.staged_docs = 0;
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_PUBLISHED).add(n);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: drop an *uncommitted* staged range — the
    /// awaited abort path that used to orphan these documents. Refuses
    /// to drop a committed staging (that one must roll forward).
    fn handle_abort_staged(&mut self) -> Result<u64, WireError> {
        if let Some((_, _, true)) = self.staging {
            return Err(WireError::Server(
                "staged migration is committed; cannot abort".into(),
            ));
        }
        let rids = self.engine.record_ids(STAGING_COLLECTION);
        let dropped = self.staged_docs;
        if !rids.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.staging = None;
        self.staged_docs = 0;
        self.metrics.counter(names::SHARD_MIGRATION_ABORTS).inc();
        self.maybe_compact();
        Ok(dropped)
    }

    fn staged_state(&self) -> Option<StagedMigration> {
        self.staging.map(|(range, from, committed)| StagedMigration {
            range,
            from,
            committed,
            docs: self.staged_docs,
        })
    }

    /// Migration source: delete the committed-away range as **one**
    /// atomic `remove_many` frame (a kill can never half-delete the
    /// chunk), then — when `compact` — checkpoint immediately so the
    /// moved-away documents leave this shard's journal and delta chain
    /// instead of occupying the shared filesystem until the next
    /// threshold crossing.
    fn delete_range(
        &mut self,
        range: (u64, u64),
        compact: bool,
    ) -> Result<DeleteChunkReply, WireError> {
        let doomed: Vec<(RecordId, u64)> = self
            .engine
            .scan_raw_from(COLLECTION, None)
            .filter_map(|(rid, raw)| {
                let pos = self.position_of_raw(&RawDoc::new(raw))?;
                (range.0 <= pos && pos <= range.1).then_some((rid, pos))
            })
            .collect();
        let rids: Vec<RecordId> = doomed.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        if !rids.is_empty() {
            self.engine
                .remove_many(COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            for (_, pos) in doomed {
                if let Some(c) = self.positions.get_mut(&pos) {
                    *c -= 1;
                    if *c == 0 {
                        self.positions.remove(&pos);
                    }
                }
            }
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_OUT).add(n);
        let compacted = if compact && n > 0 {
            let ck = self
                .engine
                .checkpoint()
                .map_err(|e| WireError::Server(e.to_string()))?;
            // Post-migration source compaction — one of the three
            // distinct `shard.checkpoints` sites (see `metrics::names`).
            self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
            self.metrics
                .counter(names::SHARD_JOURNAL_BYTES_TRUNCATED)
                .add(ck.journal_bytes_truncated);
            Some(ck)
        } else {
            self.maybe_compact();
            None
        };
        Ok(DeleteChunkReply { removed: n, compacted })
    }

    fn stats(&self) -> ShardStatsReply {
        let chunks_owned = self
            .map
            .owners
            .iter()
            .filter(|o| **o == self.id)
            .count() as u32;
        ShardStatsReply {
            collection: self.engine.stats(COLLECTION),
            chunks_owned,
            map_version: self.map.version,
            journal_bytes: self.engine.pending_journal_bytes() as u64,
            journal_disk_bytes: self.engine.journal_disk_bytes(),
            checkpoint_generation: self.engine.generation(),
            checkpoint_chain_len: self.engine.chain_len(),
            delta_disk_bytes: self.engine.chain_disk_bytes(),
            staged_docs: self.staged_docs,
        }
    }
}
