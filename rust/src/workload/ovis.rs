//! Synthetic OVIS metric corpus.
//!
//! The paper ingests "time series metric data of Blue Waters compute
//! nodes collected by OVIS ... sample[d] each node independently once
//! every minute ... about 75 distinct metrics (e.g. memory use, cpu
//! activity, network activity)". This generator reproduces that shape
//! deterministically: one document per (node, minute) with
//! `metrics_per_doc` numeric fields, generated from per-(node, metric)
//! seeded streams so any slice of the corpus can be produced
//! independently (clients generate disjoint slices in parallel).
//!
//! Metric models: a third of the metrics behave like gauges around a
//! node-specific level (memory), a third like rates with diurnal
//! modulation (cpu), a third like bursty counters (network).

use crate::config::WorkloadConfig;
use crate::mongo::bson::Document;
use crate::util::rng::Pcg32;

/// Metric field names are `m00 .. mNN` plus the two indexed fields
/// `ts` (epoch minutes) and `node_id`.
pub fn metric_name(i: u32) -> String {
    format!("m{i:02}")
}

/// Deterministic corpus generator.
#[derive(Clone)]
pub struct OvisGenerator {
    cfg: WorkloadConfig,
}

impl OvisGenerator {
    pub fn new(cfg: WorkloadConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &WorkloadConfig {
        &self.cfg
    }

    /// Total documents in the corpus.
    pub fn total_docs(&self) -> u64 {
        self.cfg.total_docs()
    }

    /// The sample value of metric `m` on `node` at minute-offset `t`.
    ///
    /// Pure function of (seed, node, m, t) — no cross-sample state.
    pub fn metric_value(&self, node: u32, m: u32, t: u32) -> f64 {
        let mut rng = Pcg32::new(
            self.cfg.seed ^ ((node as u64) << 32 | m as u64),
            (m as u64) << 32 | node as u64,
        );
        let level = 10.0 + 90.0 * rng.next_f64(); // node-specific base level
        let noise_seed = rng.next_u64();
        let mut noise_rng = Pcg32::new(noise_seed ^ t as u64, t as u64 | 1);
        let noise = noise_rng.next_gaussian();
        match m % 3 {
            // Gauge (memory-like): level + slow wander + noise.
            0 => level + (t as f64 / 360.0).sin() * 5.0 + noise,
            // Rate (cpu-like): diurnal modulation, clipped at 0.
            1 => {
                let diurnal = 0.5 + 0.5 * ((t as f64) * std::f64::consts::TAU / 1440.0).sin();
                (level * diurnal * 0.01 * (1.0 + 0.3 * noise)).max(0.0)
            }
            // Counter delta (network-like): bursty.
            _ => {
                if noise_rng.next_f64() < 0.1 {
                    level * 10.0 * noise_rng.next_f64()
                } else {
                    level * 0.1 * noise_rng.next_f64()
                }
            }
        }
    }

    /// The document for (node, minute-offset `t`).
    pub fn doc(&self, node: u32, t: u32) -> Document {
        let mut d = Document::new()
            .set("ts", (self.cfg.start_epoch_min + t) as i64)
            .set("node_id", node as i64);
        for m in 0..self.cfg.metrics_per_doc {
            d.put(&metric_name(m), self.metric_value(node, m, t));
        }
        d
    }

    /// CSV row for (node, t) — the flat-file corpus form.
    pub fn csv_row(&self, node: u32, t: u32) -> String {
        let mut row = format!("{},{}", self.cfg.start_epoch_min + t, node);
        for m in 0..self.cfg.metrics_per_doc {
            row.push_str(&format!(",{:.4}", self.metric_value(node, m, t)));
        }
        row
    }

    /// CSV header.
    pub fn csv_header(&self) -> String {
        let mut h = "ts,node_id".to_string();
        for m in 0..self.cfg.metrics_per_doc {
            h.push(',');
            h.push_str(&metric_name(m));
        }
        h
    }

    /// Documents for one minute across all nodes (an ingest wave).
    pub fn minute_docs(&self, t: u32) -> Vec<Document> {
        (0..self.cfg.monitored_nodes).map(|n| self.doc(n, t)).collect()
    }

    /// The `i`-th document of the corpus in (minute, node) order —
    /// clients slice the corpus by document index ranges.
    pub fn doc_at(&self, i: u64) -> Document {
        let nodes = self.cfg.monitored_nodes as u64;
        let t = (i / nodes) as u32;
        let node = (i % nodes) as u32;
        self.doc(node, t)
    }

    /// Approximate bytes of one encoded document (sizing reports; the
    /// paper's 200 TB / 70 G rows ≈ 2.9 KB per CSV row).
    pub fn approx_doc_bytes(&self) -> u64 {
        self.doc(0, 0).encoded_len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> OvisGenerator {
        OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 16,
            metrics_per_doc: 75,
            days: 0.01,
            ..Default::default()
        })
    }

    #[test]
    fn doc_shape_matches_paper() {
        let g = gen();
        let d = g.doc(3, 100);
        assert_eq!(d.len(), 2 + 75); // ts, node_id, 75 metrics
        assert_eq!(d.get_i64("node_id"), Some(3));
        assert_eq!(d.get_i64("ts"), Some(g.config().start_epoch_min as i64 + 100));
        assert!(d.get_f64("m00").is_some());
        assert!(d.get_f64("m74").is_some());
    }

    #[test]
    fn generation_is_deterministic() {
        let a = gen().doc(5, 42);
        let b = gen().doc(5, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn different_nodes_and_times_differ() {
        let g = gen();
        assert_ne!(g.doc(1, 10), g.doc(2, 10));
        assert_ne!(g.doc(1, 10), g.doc(1, 11));
    }

    #[test]
    fn doc_at_covers_corpus_in_order() {
        let g = gen();
        let nodes = g.config().monitored_nodes as u64;
        let d0 = g.doc_at(0);
        let dn = g.doc_at(nodes); // first doc of minute 1
        assert_eq!(d0.get_i64("node_id"), Some(0));
        assert_eq!(
            dn.get_i64("ts").unwrap(),
            d0.get_i64("ts").unwrap() + 1
        );
        assert_eq!(g.doc_at(nodes + 3), g.doc(3, 1));
    }

    #[test]
    fn csv_row_parses_back() {
        let g = gen();
        let header = g.csv_header();
        assert!(header.starts_with("ts,node_id,m00"));
        let row = g.csv_row(2, 7);
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), 77);
        assert_eq!(cols[1], "2");
        for c in &cols[2..] {
            c.parse::<f64>().unwrap();
        }
    }

    #[test]
    fn rates_are_nonnegative() {
        let g = gen();
        for t in 0..200 {
            assert!(g.metric_value(1, 1, t) >= 0.0);
            assert!(g.metric_value(1, 4, t) >= 0.0);
        }
    }

    #[test]
    fn doc_bytes_in_expected_range() {
        // 77 numeric fields ≈ 77 * ~14 bytes → roughly 1 KiB.
        let b = gen().approx_doc_bytes();
        assert!(b > 500 && b < 2500, "doc bytes {b}");
    }
}
