//! Artifact manifest: shapes and file names written by `compile/aot.py`.
//!
//! The Rust side validates at startup that the artifacts on disk were
//! lowered with the shapes this binary was built to feed them.

use std::path::Path;

use anyhow::{Context, Result};

use crate::json;

/// Fixed AOT shapes (must match `python/compile/model.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shapes {
    pub route_b: usize,
    pub route_c: usize,
    pub route_s: usize,
    pub filter_b: usize,
    pub filter_w: usize,
    pub stats_b: usize,
    pub stats_m: usize,
}

/// The shapes compiled into this binary. `aot.py` writes the same values
/// into `manifest.json`; [`Manifest::load`] cross-checks them.
pub const BUILT_SHAPES: Shapes = Shapes {
    route_b: 4096,
    route_c: 512,
    route_s: 64,
    filter_b: 4096,
    filter_w: 1024,
    stats_b: 4096,
    stats_m: 16,
};

/// Loaded manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub shapes: Shapes,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and verify it matches [`BUILT_SHAPES`].
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let v = json::from_file(&artifact_dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts`?)")?;
        let s = v
            .get("shapes")
            .ok_or_else(|| anyhow::anyhow!("manifest missing `shapes`"))?;
        let shapes = Shapes {
            route_b: s.require_u64("route_b")? as usize,
            route_c: s.require_u64("route_c")? as usize,
            route_s: s.require_u64("route_s")? as usize,
            filter_b: s.require_u64("filter_b")? as usize,
            filter_w: s.require_u64("filter_w")? as usize,
            stats_b: s.require_u64("stats_b")? as usize,
            stats_m: s.require_u64("stats_m")? as usize,
        };
        if shapes != BUILT_SHAPES {
            anyhow::bail!(
                "artifact shapes {shapes:?} do not match built-in {BUILT_SHAPES:?}; \
                 re-run `make artifacts` after changing python/compile/model.py"
            );
        }
        Ok(Self { shapes })
    }

    pub fn route_artifact(&self) -> String {
        format!(
            "route_b{}_c{}_s{}",
            self.shapes.route_b, self.shapes.route_c, self.shapes.route_s
        )
    }

    pub fn filter_artifact(&self) -> String {
        format!("filter_b{}_w{}", self.shapes.filter_b, self.shapes.filter_w)
    }

    pub fn stats_artifact(&self) -> String {
        format!("stats_b{}_m{}", self.shapes.stats_b, self.shapes.stats_m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_match_aot_convention() {
        let m = Manifest { shapes: BUILT_SHAPES };
        assert_eq!(m.route_artifact(), "route_b4096_c512_s64");
        assert_eq!(m.filter_artifact(), "filter_b4096_w1024");
        assert_eq!(m.stats_artifact(), "stats_b4096_m16");
    }

    #[test]
    fn load_rejects_mismatched_shapes() {
        let dir = std::env::temp_dir().join(format!("hpcstore-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"shapes": {"route_b": 8, "route_c": 512, "route_s": 64,
                 "filter_b": 4096, "filter_w": 1024, "stats_b": 4096, "stats_m": 16}}"#,
        )
        .unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("do not match"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must load.
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.shapes, BUILT_SHAPES);
        }
    }
}
