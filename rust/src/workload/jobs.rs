//! Synthetic user-job metadata for the query workload.
//!
//! "The query is constructed by reading user jobs metadata for time run,
//! duration, and which nodes were assigned." We synthesize a jobs table
//! with realistic shapes: node counts log-distributed, durations from
//! tens of minutes to hours, start times across the ingested window.
//! "The total number of documents returned by a query is number of user
//! job nodes times duration of user job in minutes" — [`UserJob::
//! expected_docs`] is exactly that, and the query driver asserts it.

use crate::config::WorkloadConfig;
use crate::util::rng::Pcg32;

/// One user job record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UserJob {
    pub id: u32,
    /// Monitored-node ids the job ran on.
    pub nodes: Vec<u32>,
    /// Start, as epoch minutes.
    pub start_min: u32,
    /// Duration in minutes.
    pub duration_min: u32,
}

impl UserJob {
    /// Half-open sample window `[start, start + duration)`.
    pub fn window(&self) -> (u32, u32) {
        (self.start_min, self.start_min + self.duration_min)
    }

    /// Documents a conditional find for this job returns (paper §4).
    pub fn expected_docs(&self) -> u64 {
        self.nodes.len() as u64 * self.duration_min as u64
    }
}

/// Generate `cfg.query_jobs` jobs whose windows lie inside the ingested
/// corpus ("candidate user jobs were selected from a time period
/// starting January 1, 2018 until the number of days described in
/// Table 1").
pub fn generate_jobs(cfg: &WorkloadConfig) -> Vec<UserJob> {
    let mut rng = Pcg32::seeded(cfg.seed ^ 0x4a0b5);
    let total_minutes = cfg.minutes().max(2);
    let mut jobs = Vec::with_capacity(cfg.query_jobs as usize);
    for id in 0..cfg.query_jobs {
        // Log-ish node-count distribution: mostly small jobs, a few big.
        let max_nodes = cfg.monitored_nodes.max(2);
        let exp = rng.next_f64() * (max_nodes as f64).log2() * 0.75;
        let n_nodes = (2f64.powf(exp).round() as u32).clamp(1, max_nodes);
        let nodes: Vec<u32> = rng
            .sample_indices(cfg.monitored_nodes as usize, n_nodes as usize)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        // Duration: real user jobs run minutes-to-hours regardless of
        // how much archive is ingested — 10 min .. 12 h, clipped to the
        // corpus window.
        let max_dur = 720.min(total_minutes / 2).max(1);
        let min_dur = 10.min(max_dur);
        let duration_min = rng.range_u32(min_dur, max_dur + 1).min(total_minutes - 1).max(1);
        let start_off = rng.next_bounded(total_minutes - duration_min);
        jobs.push(UserJob {
            id,
            nodes,
            start_min: cfg.start_epoch_min + start_off,
            duration_min,
        });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> WorkloadConfig {
        WorkloadConfig {
            monitored_nodes: 64,
            days: 0.5,
            query_jobs: 50,
            ..Default::default()
        }
    }

    #[test]
    fn jobs_fit_inside_corpus_window() {
        let cfg = cfg();
        let end = cfg.start_epoch_min + cfg.minutes();
        for job in generate_jobs(&cfg) {
            assert!(job.start_min >= cfg.start_epoch_min);
            assert!(job.window().1 <= end, "{job:?} beyond {end}");
            assert!(!job.nodes.is_empty());
            assert!(job.nodes.iter().all(|&n| n < cfg.monitored_nodes));
            // Distinct nodes.
            let set: std::collections::BTreeSet<_> = job.nodes.iter().collect();
            assert_eq!(set.len(), job.nodes.len());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_jobs(&cfg());
        let b = generate_jobs(&cfg());
        assert_eq!(a, b);
        let mut other = cfg();
        other.seed ^= 1;
        assert_ne!(a, generate_jobs(&other));
    }

    #[test]
    fn expected_docs_formula() {
        let j = UserJob { id: 0, nodes: vec![1, 2, 3], start_min: 100, duration_min: 40 };
        assert_eq!(j.expected_docs(), 120);
        assert_eq!(j.window(), (100, 140));
    }

    #[test]
    fn job_sizes_are_diverse() {
        let jobs = generate_jobs(&cfg());
        let sizes: std::collections::BTreeSet<usize> =
            jobs.iter().map(|j| j.nodes.len()).collect();
        assert!(sizes.len() > 3, "node-count distribution degenerate: {sizes:?}");
    }
}
