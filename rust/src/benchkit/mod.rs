//! Criterion-like benchmark harness (criterion is not in the vendored
//! dependency set).
//!
//! `rust/benches/*.rs` are `harness = false` binaries that drive this
//! module. It provides warmup, repeated timed runs, robust statistics
//! (mean/σ/percentiles via sorted samples), throughput units, and
//! markdown table emission so each bench prints the same rows as the
//! paper's tables/figures.

use std::time::Instant;

use crate::util::fmt::{human_count, human_duration_ns, markdown_table};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sampled {
    pub name: String,
    /// Nanoseconds per iteration, one entry per measured sample.
    pub samples_ns: Vec<f64>,
    /// Items processed per iteration (for throughput).
    pub items_per_iter: f64,
}

impl Sampled {
    pub fn mean_ns(&self) -> f64 {
        mean(&self.samples_ns)
    }

    pub fn stddev_ns(&self) -> f64 {
        stddev(&self.samples_ns)
    }

    pub fn percentile_ns(&self, p: f64) -> f64 {
        percentile(&self.samples_ns, p)
    }

    /// Items per second at the mean sample time.
    pub fn throughput(&self) -> f64 {
        if self.mean_ns() == 0.0 {
            0.0
        } else {
            self.items_per_iter * 1e9 / self.mean_ns()
        }
    }

    pub fn summary_row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            human_duration_ns(self.mean_ns() as u64),
            format!("±{:.1}%", 100.0 * self.stddev_ns() / self.mean_ns().max(1e-12)),
            human_duration_ns(self.percentile_ns(0.5) as u64),
            human_duration_ns(self.percentile_ns(0.95) as u64),
            format!("{}/s", human_count(self.throughput() as u64)),
        ]
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup_iters: u32,
    pub samples: u32,
    /// Minimum inner iterations per sample (amortizes timer overhead for
    /// sub-microsecond operations).
    pub min_inner: u32,
    /// Target nanoseconds per sample used for auto inner-scaling.
    pub target_sample_ns: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            samples: 12,
            min_inner: 1,
            target_sample_ns: 20_000_000.0, // 20 ms
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            samples: 5,
            min_inner: 1,
            target_sample_ns: 5_000_000.0,
        }
    }

    /// Time `f` (whole-operation granularity): each sample runs `inner`
    /// iterations where `inner` is scaled so a sample lasts about
    /// `target_sample_ns`.
    pub fn run<F: FnMut()>(&self, name: &str, items_per_iter: f64, mut f: F) -> Sampled {
        // Warmup + calibration.
        let mut one_iter_ns = f64::MAX;
        for _ in 0..self.warmup_iters.max(1) {
            let t = Instant::now();
            f();
            one_iter_ns = one_iter_ns.min(t.elapsed().as_nanos() as f64);
        }
        let inner = ((self.target_sample_ns / one_iter_ns.max(1.0)).ceil() as u32)
            .clamp(self.min_inner.max(1), 1_000_000);

        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..inner {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / inner as f64);
        }
        Sampled {
            name: name.to_string(),
            samples_ns: samples,
            items_per_iter,
        }
    }
}

/// Collects cases and prints one markdown table at the end.
#[derive(Default)]
pub struct Report {
    title: String,
    cases: Vec<Sampled>,
    extra_rows: Vec<Vec<String>>,
    extra_headers: Option<Vec<String>>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, s: Sampled) {
        println!(
            "  {:<40} mean={} thrpt={}/s",
            s.name,
            human_duration_ns(s.mean_ns() as u64),
            human_count(s.throughput() as u64)
        );
        self.cases.push(s);
    }

    /// For benches whose output is not time-per-iteration (e.g. DES
    /// sweeps): set custom headers and add raw rows.
    pub fn set_custom(&mut self, headers: Vec<String>) {
        self.extra_headers = Some(headers);
    }

    pub fn add_row(&mut self, row: Vec<String>) {
        self.extra_rows.push(row);
    }

    pub fn print(&self) {
        println!("\n## {}\n", self.title);
        if !self.cases.is_empty() {
            let rows: Vec<Vec<String>> = self.cases.iter().map(|c| c.summary_row()).collect();
            print!(
                "{}",
                markdown_table(
                    &["case", "mean", "σ", "p50", "p95", "throughput"],
                    &rows
                )
            );
        }
        if let Some(headers) = &self.extra_headers {
            let hdrs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            print!("{}", markdown_table(&hdrs, &self.extra_rows));
        }
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// True when `--quick` was passed (CI/sanity runs).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert!((stddev(&xs) - 1.5811).abs() < 1e-3);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
    }

    #[test]
    fn run_measures_something() {
        let b = Bench {
            warmup_iters: 1,
            samples: 3,
            min_inner: 1,
            target_sample_ns: 100_000.0,
        };
        let mut x = 0u64;
        let s = b.run("spin", 1000.0, || {
            for i in 0..1000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(s.samples_ns.len(), 3);
        assert!(s.mean_ns() > 0.0);
        assert!(s.throughput() > 0.0);
        std::hint::black_box(x);
    }

    #[test]
    fn report_renders_table() {
        let mut r = Report::new("t");
        r.set_custom(vec!["a".into(), "b".into()]);
        r.add_row(vec!["1".into(), "2".into()]);
        r.print(); // must not panic
    }
}
