"""Pallas kernel: shard-key hashing + chunk lookup for insertMany routing.

This is the ``mongos`` hot spot: for a batch of B documents keyed by
``(node_id, timestamp_minute)``, compute the 32-bit FNV-1a hash of the
shard key and locate the owning chunk on the hash ring.

TPU adaptation (DESIGN.md §Hardware-Adaptation): a CPU/GPU router would
binary-search each key against the chunk boundaries — a divergent,
branchy loop. Here the chunk index is computed as a *data-parallel
compare-and-count* ``sum(boundaries < hash)`` over a ``[block_b, C]``
tile, which maps onto the VPU as dense elementwise work, with the
boundary vector resident in VMEM for every grid step (its BlockSpec index
map is constant). VMEM per grid step at the default shapes
(block_b=1024, C=512): ~1024*4*4 B of keys/outputs + 512*4*2 B of tables
+ the 1024x512 compare tile — well under the ~16 MiB VMEM budget.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import FNV_OFFSET, FNV_PRIME


def _fnv1a(node, ts):
    # FNV-1a over the 8 little-endian bytes of (node_id, ts). Unrolled;
    # uint32 arithmetic wraps mod 2^32.
    h = jnp.full(node.shape, FNV_OFFSET, dtype=jnp.uint32)
    for word in (node, ts):
        for shift in (0, 8, 16, 24):
            byte = (word >> shift) & 0xFF
            h = (h ^ byte) * np.uint32(FNV_PRIME)
    return h


def _route_kernel_compare_count(node_ref, ts_ref, bounds_ref, c2s_ref, shard_ref, hash_ref):
    """TPU-style: chunk index as a dense compare-and-count over a
    [block_b, C] tile (VPU-friendly, no divergent control flow)."""
    h = _fnv1a(node_ref[...], ts_ref[...])
    bounds = bounds_ref[...]
    chunk = jnp.sum(
        (bounds[None, :] < h[:, None]).astype(jnp.int32), axis=1, dtype=jnp.int32
    )
    shard_ref[...] = jnp.take(c2s_ref[...].astype(jnp.int32), chunk)
    hash_ref[...] = h


def _route_kernel_searchsorted(node_ref, ts_ref, bounds_ref, c2s_ref, shard_ref, hash_ref):
    """CPU-optimal: vectorized binary search (identical semantics:
    `searchsorted(bounds, h, side='left')` == count of bounds < h)."""
    h = _fnv1a(node_ref[...], ts_ref[...])
    chunk = jnp.searchsorted(bounds_ref[...], h, side="left").astype(jnp.int32)
    shard_ref[...] = jnp.take(c2s_ref[...].astype(jnp.int32), chunk)
    hash_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_b", "variant"))
def shard_route(
    node_id, ts_min, boundaries, chunk_to_shard, *, block_b=1024, variant="searchsorted"
):
    """Route a key batch to shards.

    Args:
      node_id:        u32[B] shard-key node ids.
      ts_min:         u32[B] shard-key epoch-minutes.
      boundaries:     u32[C] sorted inclusive upper bounds per chunk on
                      the hash ring; tail padded with 0xFFFFFFFF.
      chunk_to_shard: i32[C] owning shard per chunk; tail padded with the
                      last real shard id.
      block_b:        batch tile size (must divide B).
      variant:        "searchsorted" (CPU-optimal; what the AOT artifact
                      ships for the CPU PJRT runtime) or "compare_count"
                      (the TPU formulation; see DESIGN.md
                      §Hardware-Adaptation). Bit-identical outputs —
                      pytest asserts both against ref.py.

    Returns:
      (shard_of i32[B], hashes u32[B]).
    """
    b = node_id.shape[0]
    c = boundaries.shape[0]
    if b % block_b:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    kernels = {
        "searchsorted": _route_kernel_searchsorted,
        "compare_count": _route_kernel_compare_count,
    }
    grid = (b // block_b,)
    return pl.pallas_call(
        kernels[variant],
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            # Tables are VMEM-resident for every grid step.
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(node_id, ts_min, boundaries, chunk_to_shard)
