//! Generic discrete-event queue: a time-ordered heap with stable FIFO
//! tie-breaking.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Event queue over payload type `E`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(u64, u64)>>,
    payloads: Vec<Option<E>>,
    now_ns: u64,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            payloads: Vec::new(),
            now_ns: 0,
            seq: 0,
            processed: 0,
        }
    }

    /// Current virtual time (last popped event's time).
    pub fn now(&self) -> u64 {
        self.now_ns
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute time `at_ns` (clamped to now).
    pub fn push(&mut self, at_ns: u64, event: E) {
        let at = at_ns.max(self.now_ns);
        let id = self.seq;
        self.seq += 1;
        self.payloads.push(Some(event));
        self.heap.push(Reverse((at, id)));
    }

    /// Pop the earliest event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(u64, E)> {
        let Reverse((t, id)) = self.heap.pop()?;
        self.now_ns = t;
        self.processed += 1;
        let e = self.payloads[id as usize].take().expect("event already taken");
        // Compact the payload store opportunistically when fully drained.
        if self.heap.is_empty() {
            self.payloads.clear();
            self.seq = 0;
        }
        Some((t, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 30);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(5, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.push(100, "x");
        q.pop();
        q.push(50, "late"); // in the past → runs at now
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn storage_reclaimed_after_drain() {
        let mut q = EventQueue::new();
        for round in 0..3 {
            for i in 0..1000 {
                q.push(round * 1000 + i, i);
            }
            while q.pop().is_some() {}
            assert!(q.is_empty());
        }
        assert_eq!(q.processed(), 3000);
    }
}
