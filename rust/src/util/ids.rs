//! Strongly-typed identifiers used across the cluster.
//!
//! Using newtypes (not bare `u32`s) prevents the classic
//! shard-id-passed-as-node-id bug at compile time; they are `Copy`,
//! ordered, and format as their role name (`shard-3`, `host-17`).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
        pub struct $name(pub u32);

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "-{}"), self.0)
            }
        }

        impl From<u32> for $name {
            fn from(v: u32) -> Self {
                Self(v)
            }
        }

        impl $name {
            pub fn index(self) -> usize {
                self.0 as usize
            }
        }
    };
}

id_type!(
    /// A shard server (`mongod` with data).
    ShardId, "shard"
);
id_type!(
    /// A query router (`mongos`).
    RouterId, "router"
);
id_type!(
    /// A physical host (compute node) in the HPC allocation.
    HostId, "host"
);
id_type!(
    /// A client processing element running the ingest/query script.
    ClientId, "client"
);
id_type!(
    /// A Lustre object storage target.
    OstId, "ost"
);
id_type!(
    /// A batch job in the scheduler queue.
    JobId, "job"
);

/// Monotonic request-id generator (unique within a process).
#[derive(Default)]
pub struct RequestIdGen {
    next: AtomicU64,
}

impl RequestIdGen {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_uses_role_prefix() {
        assert_eq!(ShardId(3).to_string(), "shard-3");
        assert_eq!(HostId(17).to_string(), "host-17");
        assert_eq!(OstId(0).to_string(), "ost-0");
    }

    #[test]
    fn ids_are_distinct_types() {
        // (compile-time property; just exercise conversions)
        let s: ShardId = 5u32.into();
        assert_eq!(s.index(), 5);
    }

    #[test]
    fn request_ids_unique() {
        let g = RequestIdGen::new();
        let a = g.next();
        let b = g.next();
        assert_ne!(a, b);
    }
}
