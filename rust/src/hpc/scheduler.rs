//! Moab/Torque-like batch scheduler: job queue, node pool, FCFS with
//! EASY backfill, walltime enforcement.
//!
//! "Many HPC architectures process user requests by job queue scheduler"
//! (paper §1) — the run-script deployment lives inside one of these
//! jobs. The scheduler is virtual-time driven: tests and the DES drive
//! it with explicit times; the live examples use it to admit the
//! deploy-job before running the run-script body in-process.

use std::collections::{BTreeSet, VecDeque};

use anyhow::{bail, Result};

use crate::util::ids::JobId;

/// A submitted batch job.
#[derive(Clone, Debug)]
pub struct Job {
    pub name: String,
    pub nodes: u32,
    /// Requested walltime (seconds) — the kill limit.
    pub walltime_s: u64,
    /// Simulated actual runtime. `None` = interactive (the caller calls
    /// [`Scheduler::complete`] itself).
    pub runtime_s: Option<u64>,
}

impl Job {
    pub fn new(name: &str, nodes: u32, walltime_s: u64) -> Self {
        Self { name: name.to_string(), nodes, walltime_s, runtime_s: None }
    }

    pub fn with_runtime(mut self, runtime_s: u64) -> Self {
        self.runtime_s = Some(runtime_s);
        self
    }
}

/// Lifecycle state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running { start_s: u64, hosts: Vec<u32> },
    Completed { start_s: u64, end_s: u64 },
    /// Hit the walltime limit and was killed.
    TimedOut { start_s: u64, end_s: u64 },
}

struct JobRecord {
    job: Job,
    state: JobState,
}

/// The scheduler.
pub struct Scheduler {
    total_nodes: u32,
    free: BTreeSet<u32>,
    jobs: Vec<JobRecord>,
    queue: VecDeque<JobId>,
    now_s: u64,
    pub backfill_enabled: bool,
    /// (job, start, end) log for utilization reports.
    pub history: Vec<(JobId, u64, u64)>,
}

impl Scheduler {
    pub fn new(total_nodes: u32) -> Self {
        Self {
            total_nodes,
            free: (0..total_nodes).collect(),
            jobs: Vec::new(),
            queue: VecDeque::new(),
            now_s: 0,
            backfill_enabled: true,
            history: Vec::new(),
        }
    }

    pub fn now(&self) -> u64 {
        self.now_s
    }

    pub fn free_nodes(&self) -> u32 {
        self.free.len() as u32
    }

    /// `qsub`: enqueue a job.
    pub fn submit(&mut self, job: Job) -> Result<JobId> {
        if job.nodes == 0 {
            bail!("job requests zero nodes");
        }
        if job.nodes > self.total_nodes {
            bail!(
                "job requests {} nodes but the machine has {}",
                job.nodes,
                self.total_nodes
            );
        }
        let id = JobId(self.jobs.len() as u32);
        self.jobs.push(JobRecord { job, state: JobState::Queued });
        self.queue.push_back(id);
        self.try_schedule();
        Ok(id)
    }

    /// `qstat`: job state.
    pub fn state(&self, id: JobId) -> &JobState {
        &self.jobs[id.index()].state
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.index()].job
    }

    /// Hosts allocated to a running job.
    pub fn hosts_of(&self, id: JobId) -> Option<&[u32]> {
        match &self.jobs[id.index()].state {
            JobState::Running { hosts, .. } => Some(hosts),
            _ => None,
        }
    }

    fn allocate(&mut self, n: u32) -> Vec<u32> {
        let hosts: Vec<u32> = self.free.iter().take(n as usize).copied().collect();
        for h in &hosts {
            self.free.remove(h);
        }
        hosts
    }

    /// Estimated end time of a running job (walltime-based, as EASY
    /// backfill uses).
    fn estimated_end(&self, id: JobId) -> u64 {
        match &self.jobs[id.index()].state {
            JobState::Running { start_s, .. } => start_s + self.jobs[id.index()].job.walltime_s,
            _ => u64::MAX,
        }
    }

    /// FCFS + EASY backfill pass; returns jobs started at `now`.
    pub fn try_schedule(&mut self) -> Vec<JobId> {
        let mut started = Vec::new();
        // FCFS: start queue head(s) while they fit.
        while let Some(&head) = self.queue.front() {
            let need = self.jobs[head.index()].job.nodes;
            if need <= self.free_nodes() {
                let hosts = self.allocate(need);
                self.jobs[head.index()].state =
                    JobState::Running { start_s: self.now_s, hosts };
                self.queue.pop_front();
                started.push(head);
            } else {
                break;
            }
        }
        if !self.backfill_enabled {
            return started;
        }
        // EASY backfill around the (single) blocked head.
        let Some(&head) = self.queue.front() else { return started };
        let head_need = self.jobs[head.index()].job.nodes as i64;
        // Shadow time: when enough running jobs will have ended for the
        // head to start (by walltime estimates).
        let mut ends: Vec<(u64, u32)> = self
            .jobs
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match &r.state {
                JobState::Running { .. } => {
                    Some((self.estimated_end(JobId(i as u32)), r.job.nodes))
                }
                _ => None,
            })
            .collect();
        ends.sort_unstable();
        let mut avail = self.free_nodes() as i64;
        let mut shadow = u64::MAX;
        let mut extra = 0i64; // nodes free at shadow beyond the head's need
        for (end, n) in ends {
            avail += n as i64;
            if avail >= head_need {
                shadow = end;
                extra = avail - head_need;
                break;
            }
        }
        // Backfill candidates after the head, FCFS order.
        let candidates: Vec<JobId> = self.queue.iter().skip(1).copied().collect();
        for cand in candidates {
            let need = self.jobs[cand.index()].job.nodes;
            if need > self.free_nodes() {
                continue;
            }
            let fits_time = self.now_s + self.jobs[cand.index()].job.walltime_s <= shadow;
            let fits_extra = (need as i64) <= extra;
            if fits_time || fits_extra {
                let hosts = self.allocate(need);
                self.jobs[cand.index()].state =
                    JobState::Running { start_s: self.now_s, hosts };
                self.queue.retain(|j| *j != cand);
                if !fits_time {
                    extra -= need as i64;
                }
                started.push(cand);
            }
        }
        started
    }

    /// Complete a running job (interactive jobs; sim jobs complete via
    /// [`Self::advance_to`]).
    pub fn complete(&mut self, id: JobId) -> Result<()> {
        let rec = &mut self.jobs[id.index()];
        let JobState::Running { start_s, hosts } = rec.state.clone() else {
            bail!("job {id} is not running");
        };
        rec.state = JobState::Completed { start_s, end_s: self.now_s };
        for h in hosts {
            self.free.insert(h);
        }
        self.history.push((id, start_s, self.now_s));
        self.try_schedule();
        Ok(())
    }

    /// Next event time (sim-job completion or walltime kill).
    pub fn next_event(&self) -> Option<u64> {
        self.jobs
            .iter()
            .filter_map(|r| match &r.state {
                JobState::Running { start_s, .. } => {
                    let runtime = r.job.runtime_s.unwrap_or(u64::MAX);
                    Some((start_s + runtime.min(r.job.walltime_s)).max(self.now_s))
                }
                _ => None,
            })
            .min()
    }

    /// Advance virtual time, completing/killing sim jobs on the way.
    pub fn advance_to(&mut self, t: u64) {
        loop {
            let Some(evt) = self.next_event() else { break };
            if evt > t {
                break;
            }
            self.now_s = evt;
            // Complete or kill everything due at `evt`.
            for i in 0..self.jobs.len() {
                let id = JobId(i as u32);
                let (due, timed_out) = match &self.jobs[i].state {
                    JobState::Running { start_s, .. } => {
                        let runtime = self.jobs[i].job.runtime_s.unwrap_or(u64::MAX);
                        let wall = self.jobs[i].job.walltime_s;
                        let end = start_s + runtime.min(wall);
                        (end <= evt, runtime > wall)
                    }
                    _ => (false, false),
                };
                if due {
                    let JobState::Running { start_s, hosts } = self.jobs[i].state.clone() else {
                        continue;
                    };
                    self.jobs[i].state = if timed_out {
                        JobState::TimedOut { start_s, end_s: evt }
                    } else {
                        JobState::Completed { start_s, end_s: evt }
                    };
                    for h in hosts {
                        self.free.insert(h);
                    }
                    self.history.push((id, start_s, evt));
                }
            }
            self.try_schedule();
        }
        self.now_s = self.now_s.max(t);
    }

    /// Run until no sim jobs remain queued or running.
    pub fn drain(&mut self) {
        while let Some(evt) = self.next_event() {
            self.advance_to(evt);
        }
    }

    /// Node-seconds utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon_s: u64) -> f64 {
        let used: u64 = self
            .history
            .iter()
            .map(|(id, s, e)| (e.min(&horizon_s) - s) * self.jobs[id.index()].job.nodes as u64)
            .sum();
        used as f64 / (self.total_nodes as u64 * horizon_s.max(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_starts_in_order() {
        let mut s = Scheduler::new(10);
        let a = s.submit(Job::new("a", 4, 100).with_runtime(50)).unwrap();
        let b = s.submit(Job::new("b", 4, 100).with_runtime(50)).unwrap();
        let c = s.submit(Job::new("c", 4, 100).with_runtime(50)).unwrap();
        assert!(matches!(s.state(a), JobState::Running { .. }));
        assert!(matches!(s.state(b), JobState::Running { .. }));
        assert!(matches!(s.state(c), JobState::Queued)); // only 2 nodes left
        s.drain();
        assert!(matches!(s.state(c), JobState::Completed { start_s: 50, .. }));
    }

    #[test]
    fn backfill_lets_small_short_job_jump() {
        let mut s = Scheduler::new(10);
        let _big1 = s.submit(Job::new("big1", 8, 100).with_runtime(100)).unwrap();
        let blocked = s.submit(Job::new("blocked", 10, 100).with_runtime(10)).unwrap();
        // Small job fits the 2 free nodes and ends before big1's walltime.
        let small = s.submit(Job::new("small", 2, 50).with_runtime(50)).unwrap();
        assert!(matches!(s.state(blocked), JobState::Queued));
        assert!(
            matches!(s.state(small), JobState::Running { .. }),
            "small job should backfill"
        );
        s.drain();
        // Head eventually runs.
        assert!(matches!(s.state(blocked), JobState::Completed { .. }));
    }

    #[test]
    fn backfill_never_delays_head_reservation() -> anyhow::Result<()> {
        let mut s = Scheduler::new(10);
        let _big = s.submit(Job::new("big", 8, 100).with_runtime(100))?;
        let head = s.submit(Job::new("head", 10, 100).with_runtime(10))?;
        // This job fits the 2 free nodes but runs past the shadow time
        // (100) and would steal nodes the head needs → must NOT backfill.
        let long = s.submit(Job::new("long", 2, 500).with_runtime(500))?;
        assert!(matches!(s.state(long), JobState::Queued));
        s.drain();
        let JobState::Completed { start_s, .. } = s.state(head) else {
            anyhow::bail!("head not completed: {:?}", s.state(head));
        };
        assert_eq!(*start_s, 100, "head must start exactly at the shadow time");
        Ok(())
    }

    #[test]
    fn backfill_disabled_is_strict_fcfs() {
        let mut s = Scheduler::new(10);
        s.backfill_enabled = false;
        let _big = s.submit(Job::new("big", 8, 100).with_runtime(100)).unwrap();
        let _head = s.submit(Job::new("head", 10, 100).with_runtime(10)).unwrap();
        let small = s.submit(Job::new("small", 1, 5).with_runtime(5)).unwrap();
        assert!(matches!(s.state(small), JobState::Queued));
    }

    #[test]
    fn walltime_kill() {
        let mut s = Scheduler::new(4);
        let j = s.submit(Job::new("runaway", 4, 10).with_runtime(1000)).unwrap();
        s.drain();
        assert!(matches!(s.state(j), JobState::TimedOut { end_s: 10, .. }));
        assert_eq!(s.free_nodes(), 4);
    }

    #[test]
    fn interactive_job_completion() {
        let mut s = Scheduler::new(4);
        let j = s.submit(Job::new("deploy", 4, 3600)).unwrap();
        let hosts = s.hosts_of(j).unwrap().to_vec();
        assert_eq!(hosts.len(), 4);
        s.complete(j).unwrap();
        assert!(matches!(s.state(j), JobState::Completed { .. }));
        assert!(s.complete(j).is_err());
    }

    #[test]
    fn rejects_oversized_and_empty_jobs() {
        let mut s = Scheduler::new(4);
        assert!(s.submit(Job::new("too-big", 5, 10)).is_err());
        assert!(s.submit(Job::new("empty", 0, 10)).is_err());
    }

    #[test]
    fn utilization_accounting() {
        let mut s = Scheduler::new(10);
        s.submit(Job::new("a", 10, 100).with_runtime(100)).unwrap();
        s.drain();
        let u = s.utilization(100);
        assert!((u - 1.0).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn queue_wait_then_start() {
        let mut s = Scheduler::new(4);
        let a = s.submit(Job::new("a", 4, 50).with_runtime(30)).unwrap();
        let b = s.submit(Job::new("b", 4, 50).with_runtime(30)).unwrap();
        assert!(matches!(s.state(b), JobState::Queued));
        s.advance_to(30);
        assert!(matches!(s.state(a), JobState::Completed { .. }));
        assert!(matches!(s.state(b), JobState::Running { start_s: 30, .. }));
    }
}
