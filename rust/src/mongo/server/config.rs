//! Config server process: hosts [`ConfigState`] behind the wire layer
//! and pushes chunk-map updates to every shard and router after each
//! metadata mutation.
//!
//! The config thread never blocks on a shard RPC (migration data
//! movement is executed by the cluster coordinator), so the
//! shard→config RPCs (`GetMap`, `ReportSplit`) can be synchronous
//! without deadlock.

use std::sync::mpsc;

use crate::mongo::sharding::chunk::{ChunkMap, ShardKey};
use crate::mongo::sharding::config_server::ConfigState;
use crate::mongo::wire::{ConfigRequest, ConfigStatsReply, ShardRequest, WireError};
use crate::metrics::{names, Registry};

/// Config server process.
pub struct ConfigServer {
    state: ConfigState,
    /// Mailboxes to push `SetMap` to after each mutation.
    shards: Vec<mpsc::Sender<ShardRequest>>,
    metrics: Registry,
    migrations_done: u64,
    migrations_aborted: u64,
}

impl ConfigServer {
    /// Build a config server with a hashed pre-split chunk table.
    pub fn new(
        key: ShardKey,
        num_shards: u32,
        chunks_per_shard: u32,
        replicas: u32,
        metrics: Registry,
    ) -> Self {
        Self {
            state: ConfigState::new(key, num_shards, chunks_per_shard, replicas),
            shards: Vec::new(),
            metrics,
            migrations_done: 0,
            migrations_aborted: 0,
        }
    }

    /// Initial chunk map (routers/shards bootstrap from this before the
    /// thread starts).
    pub fn initial_map(&self) -> ChunkMap {
        self.state.map().clone()
    }

    /// Register the shard mailboxes (after shards spawn).
    pub fn set_shards(&mut self, shards: Vec<mpsc::Sender<ShardRequest>>) {
        self.shards = shards;
    }

    pub fn spawn(self) -> (mpsc::Sender<ConfigRequest>, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let join = self.spawn_with(rx);
        (tx, join)
    }

    /// Spawn on a pre-created channel.
    pub fn spawn_with(mut self, rx: mpsc::Receiver<ConfigRequest>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name("config-server".into())
            .spawn(move || self.run(rx))
            // lint: allow(panic, thread spawn fails only on OS resource
            // exhaustion at cluster startup, before any data is live)
            .expect("spawn config thread")
    }

    fn push_map(&self) {
        for s in &self.shards {
            let _ = s.send(ShardRequest::SetMap { map: self.state.map().clone() });
        }
    }

    fn run(&mut self, rx: mpsc::Receiver<ConfigRequest>) {
        while let Ok(req) = rx.recv() {
            match req {
                ConfigRequest::Shutdown => break,
                ConfigRequest::GetMap { reply } => {
                    self.metrics.counter(names::CONFIG_GET_MAP).inc();
                    let _ = reply.send(self.state.map().clone());
                }
                ConfigRequest::ReportSplit { seen_version, chunk, at, reply } => {
                    self.metrics.counter(names::CONFIG_REPORT_SPLIT).inc();
                    let r = self
                        .state
                        .split_chunk(seen_version, chunk, at)
                        .map_err(|e| WireError::Server(e.to_string()));
                    if matches!(
                        r,
                        Ok(crate::mongo::sharding::config_server::VersionCheck::Ok)
                    ) {
                        self.metrics.counter(names::CONFIG_SPLITS).inc();
                        self.push_map();
                    }
                    let _ = reply.send(r);
                }
                ConfigRequest::BeginMigration { chunk, to, reply } => {
                    // Begin records the handoff (version bump): push the
                    // map before replying so every shard knows the range
                    // has copies in motion before any data streams.
                    let r = self
                        .state
                        .begin_migration(chunk, to)
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        self.push_map();
                    }
                    let _ = reply.send(r);
                }
                ConfigRequest::CommitMigration { reply } => {
                    // The flip (M2): ownership moves, every shard gets
                    // the new map *before* the reply — the coordinator's
                    // catch-up batches therefore observe a donor that
                    // already rejects new writes in the range.
                    let r = self
                        .state
                        .commit_migration()
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        self.metrics.counter(names::CONFIG_MIGRATION_FLIPS).inc();
                        self.push_map();
                    }
                    let _ = reply.send(r);
                }
                ConfigRequest::AdvanceMigration { state, reply } => {
                    let r = self
                        .state
                        .advance_migration(state)
                        .map_err(|e| WireError::Server(e.to_string()));
                    let _ = reply.send(r);
                }
                ConfigRequest::PublishMigration { reply } => {
                    // The orphan instant: from this version on the
                    // donor's copies of the range are duplicates. Push
                    // before replying — the coordinator's source delete
                    // is therefore ordered after SetMap in the donor's
                    // mailbox, so the donor filters before it deletes.
                    let r = self
                        .state
                        .publish_migration()
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        self.metrics.counter(names::CONFIG_MIGRATION_PUBLISHES).inc();
                        self.push_map();
                    }
                    let _ = reply.send(r);
                }
                ConfigRequest::FinishMigration { reply } => {
                    let before = self.state.version();
                    let r = self
                        .state
                        .finish_migration()
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        self.migrations_done += 1;
                        self.metrics.counter(names::CONFIG_MIGRATIONS).inc();
                        if self.state.version() != before {
                            // Finishing dropped the handoff: re-push.
                            self.push_map();
                        }
                    }
                    let _ = reply.send(r);
                }
                ConfigRequest::AbortMigration { reply } => {
                    let before = self.state.version();
                    let aborted = self.state.abort_migration();
                    if aborted.is_some() {
                        self.migrations_aborted += 1;
                        self.metrics.counter(names::CONFIG_MIGRATION_ABORTS).inc();
                        if self.state.version() != before {
                            // The abort rolled a flip back: re-push.
                            self.push_map();
                        }
                    }
                    let _ = reply.send(aborted);
                }
                ConfigRequest::Stats { reply } => {
                    let _ = reply.send(ConfigStatsReply {
                        version: self.state.version(),
                        chunks: self.state.map().num_chunks(),
                        oplog_len: self.state.oplog_len,
                        migrations_done: self.migrations_done,
                        migrations_aborted: self.migrations_aborted,
                        migration_state: self.state.migration().map(|m| m.state),
                    });
                }
            }
        }
    }
}
