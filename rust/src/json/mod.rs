//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! The vendored crate set has no `serde`/`serde_json`; configs, artifact
//! manifests, cost models, and report files all go through this module.
//! It supports the full JSON grammar (RFC 8259) with the usual practical
//! limits: numbers are `f64` or `i64`, object keys are strings, no
//! comments.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::{to_string, to_string_pretty};

/// Parse a JSON file.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Write a value to a file, pretty-printed.
pub fn to_file(path: &std::path::Path, value: &Value) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_string_pretty(value))?;
    Ok(())
}
