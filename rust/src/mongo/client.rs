//! Client API — the pymongo analogue the run-script workloads use.
//!
//! A [`MongoClient`] holds the router mailboxes published by the
//! run-script host file ("the run script makes available ... a list of
//! host names of the MongoDB cluster's router servers", paper §3.2) and
//! round-robins requests across them, exactly like the paper's client
//! PEs spreading `insertMany` calls over the routers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::mongo::aggregate::AggPipeline;
use crate::mongo::bson::Document;
use crate::mongo::query::{Filter, FindOptions};
use crate::mongo::server::router::{InsertManyReply, RouterMailbox, RouterRequest};
use crate::mongo::storage::index::IndexSpec;
use crate::mongo::wire::{rpc, DeleteReply, UpdateReply, WireError};

/// Thread-safe, cloneable client handle.
#[derive(Clone)]
pub struct MongoClient {
    routers: Arc<Vec<RouterMailbox>>,
    next: Arc<AtomicUsize>,
}

impl MongoClient {
    /// Build a client over the given router mailboxes (at least one).
    pub fn new(routers: Vec<RouterMailbox>) -> Self {
        assert!(!routers.is_empty(), "client needs at least one router");
        Self { routers: Arc::new(routers), next: Arc::new(AtomicUsize::new(0)) }
    }

    fn pick(&self) -> &RouterMailbox {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        &self.routers[i % self.routers.len()]
    }

    /// A client pinned to one router (a PE talks to "its" router in the
    /// paper's layout: PE index mod router count).
    pub fn pinned(&self, pe: usize) -> MongoClient {
        let router = self.routers[pe % self.routers.len()].clone();
        MongoClient { routers: Arc::new(vec![router]), next: Arc::new(AtomicUsize::new(0)) }
    }

    /// Routers this client round-robins over.
    pub fn num_routers(&self) -> usize {
        self.routers.len()
    }

    /// `insertMany(ordered=False)`.
    pub fn insert_many(&self, docs: Vec<Document>) -> Result<InsertManyReply, WireError> {
        rpc(self.pick(), |reply| RouterRequest::InsertMany { docs, reply })?
    }

    /// `insertMany` through the router's ingest buffer: the router
    /// coalesces batches from every client talking to it and flushes to
    /// the shards on size/deadline — group commit across clients. Blocks
    /// until the flush containing this batch completes.
    pub fn insert_buffered(&self, docs: Vec<Document>) -> Result<InsertManyReply, WireError> {
        rpc(self.pick(), |reply| RouterRequest::InsertBuffered { docs, reply })?
    }

    /// A client-side bulk writer that buffers documents locally and
    /// flushes an `insertMany` once `batch_size` documents accumulate or
    /// `flush_interval` has elapsed since the first buffered document.
    pub fn bulk_writer(&self, batch_size: usize, flush_interval: Duration) -> BulkWriter {
        BulkWriter {
            client: self.clone(),
            buf: Vec::with_capacity(batch_size.max(1)),
            batch_size: batch_size.max(1),
            flush_interval,
            since: None,
            inserted: 0,
            rerouted: 0,
            flushes: 0,
        }
    }

    /// `find(filter)` returning a pull cursor.
    pub fn find(&self, filter: Filter, opts: FindOptions) -> Result<ClientCursor, WireError> {
        let router = self.pick().clone();
        let first = rpc(&router, |reply| RouterRequest::Find { filter, opts, reply })??;
        Ok(ClientCursor {
            router,
            buffered: first.docs.into(),
            cursor: first.cursor,
            err: None,
        })
    }

    /// `countDocuments`: scatter-count on the shards — no result set
    /// crosses the wire.
    pub fn count_documents(&self, filter: Filter) -> Result<usize, WireError> {
        let n = rpc(self.pick(), |reply| RouterRequest::Count { filter, reply })??;
        Ok(n as usize)
    }

    /// `aggregate(pipeline)`: `$match`/`$project`/`$group`/`$sort`/
    /// `$limit`, executed shard-side. With aggregation push-down on
    /// (`--agg-partial`, the default), only per-group partial
    /// accumulator rows cross the wire — not matching documents.
    pub fn aggregate(&self, pipeline: AggPipeline) -> Result<Vec<Document>, WireError> {
        rpc(self.pick(), |reply| RouterRequest::Aggregate { pipeline, reply })?
    }

    /// `updateMany(filter, {$set: set})`: top-level field merge on every
    /// matching document, cluster-wide. Shard-key fields (`node_id`,
    /// `ts`) are immutable — updates naming them are rejected.
    pub fn update_many(&self, filter: Filter, set: Document) -> Result<UpdateReply, WireError> {
        rpc(self.pick(), |reply| RouterRequest::Update { filter, set, reply })?
    }

    /// `deleteMany(filter)`: remove every matching document, cluster-wide.
    pub fn delete_many(&self, filter: Filter) -> Result<DeleteReply, WireError> {
        rpc(self.pick(), |reply| RouterRequest::Delete { filter, reply })?
    }

    /// `createIndex` on every shard (idempotent).
    pub fn create_index(&self, spec: IndexSpec) -> Result<(), WireError> {
        rpc(self.pick(), |reply| RouterRequest::CreateIndex { spec, reply })?
    }
}

/// Buffers documents client-side and flushes `insertMany` batches on
/// size or deadline — the client leg of the bulk-ingest pipeline. Call
/// [`BulkWriter::finish`] to flush the tail and read the totals.
pub struct BulkWriter {
    client: MongoClient,
    buf: Vec<Document>,
    batch_size: usize,
    flush_interval: Duration,
    since: Option<Instant>,
    inserted: usize,
    rerouted: usize,
    flushes: u64,
}

impl BulkWriter {
    /// Buffer one document, flushing if the batch is full or the flush
    /// deadline has passed.
    pub fn push(&mut self, doc: Document) -> Result<(), WireError> {
        if self.buf.is_empty() {
            self.since = Some(Instant::now());
        }
        self.buf.push(doc);
        let deadline_hit = self
            .since
            .map(|t| t.elapsed() >= self.flush_interval)
            .unwrap_or(false);
        if self.buf.len() >= self.batch_size || deadline_hit {
            self.flush()?;
        }
        Ok(())
    }

    /// Flush any buffered documents now.
    pub fn flush(&mut self) -> Result<(), WireError> {
        if self.buf.is_empty() {
            self.since = None;
            return Ok(());
        }
        let docs = std::mem::take(&mut self.buf);
        self.since = None;
        let rep = self.client.insert_many(docs)?;
        self.inserted += rep.inserted;
        self.rerouted += rep.rerouted;
        self.flushes += 1;
        Ok(())
    }

    /// Documents currently buffered client-side.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// `insertMany` calls issued so far.
    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    /// Flush the tail and return the aggregate reply.
    pub fn finish(mut self) -> Result<InsertManyReply, WireError> {
        self.flush()?;
        Ok(InsertManyReply { inserted: self.inserted, rerouted: self.rerouted })
    }
}

impl Drop for BulkWriter {
    /// Dropping a part-full writer flushes the tail instead of silently
    /// losing it — a run script that returns early (or unwinds) must
    /// not leave its last sub-batch-size of documents client-side.
    /// Best-effort: a flush failure here is reported on stderr, never a
    /// panic (drop can run during unwinding, where a second panic
    /// aborts). [`BulkWriter::finish`] remains the right way to end a
    /// writer — it surfaces the error and the totals.
    fn drop(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let tail = self.buf.len();
        match self.flush() {
            Ok(()) => eprintln!(
                "BulkWriter dropped with {tail} buffered document(s); flushed implicitly \
                 (use finish() to observe totals)"
            ),
            Err(e) => eprintln!(
                "BulkWriter dropped with {tail} buffered document(s) and the implicit \
                 flush failed: {e}"
            ),
        }
    }
}

/// Iterates result documents, pulling `getMore` batches on demand.
///
/// A `getMore` failure ends the iteration; [`ClientCursor::error`]
/// distinguishes a clean exhaustion (`None`) from a mid-drain error:
///
/// * [`WireError::SnapshotExpired`] — the cursor's pinned snapshot
///   fell behind the retention window; reissue the `find`.
/// * [`WireError::NotPrimary`] — the member serving the cursor was
///   deposed mid-drain; reissue the `find` (it will route freshly).
/// * [`WireError::ShardUnavailable`] — the member serving the cursor
///   died; its cursor state died with it. Reissue the `find`.
///
/// All three are *retryable for a read* ([`ClientCursor::retryable`]):
/// re-running the `find` from scratch re-reads a consistent snapshot
/// and cannot double-apply anything. Callers that treat iterator end
/// as "all results seen" must check [`ClientCursor::error`] first —
/// a dead shard mid-drain is **not** exhaustion.
pub struct ClientCursor {
    router: RouterMailbox,
    buffered: VecDeque<Document>,
    cursor: Option<u64>,
    err: Option<WireError>,
}

impl ClientCursor {
    /// The error that terminated iteration, if any. `None` after a
    /// complete drain.
    pub fn error(&self) -> Option<&WireError> {
        self.err.as_ref()
    }

    /// True when iteration ended on an error a fresh `find` cleanly
    /// recovers from. Wider than [`WireError::retryable`]: a dead
    /// shard ([`WireError::ShardUnavailable`]) is ambiguous for a
    /// *write*, but a re-read is always safe.
    pub fn retryable(&self) -> bool {
        match &self.err {
            Some(WireError::ShardUnavailable { .. }) => true,
            Some(e) => e.retryable(),
            None => false,
        }
    }
}

impl Iterator for ClientCursor {
    type Item = Document;

    fn next(&mut self) -> Option<Document> {
        loop {
            if let Some(doc) = self.buffered.pop_front() {
                return Some(doc);
            }
            let cursor = self.cursor.take()?;
            match rpc(&self.router, |reply| RouterRequest::GetMore { cursor, reply }) {
                Ok(Ok(rep)) => {
                    self.buffered = rep.docs.into();
                    self.cursor = rep.cursor;
                    if self.buffered.is_empty() && self.cursor.is_none() {
                        return None;
                    }
                }
                Ok(Err(e)) => {
                    self.err = Some(e);
                    return None;
                }
                Err(e) => {
                    self.err = Some(e);
                    return None;
                }
            }
        }
    }
}
