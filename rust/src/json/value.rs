//! JSON value model with ergonomic accessors.

use std::collections::BTreeMap;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integers that fit i64 are kept exact.
    Int(i64),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn object() -> Value {
        Value::Object(BTreeMap::new())
    }

    /// Insert into an object, returning `self` for builder chaining.
    /// On a non-object receiver this is a no-op; use [`Value::try_set`]
    /// when the caller needs to detect that case.
    pub fn set(&mut self, key: &str, value: impl Into<Value>) -> &mut Self {
        let _ = self.try_set(key, value);
        self
    }

    /// Fallible insert: `None` (and no mutation) if `self` is not an
    /// object, `Some(self)` after inserting otherwise.
    pub fn try_set(&mut self, key: &str, value: impl Into<Value>) -> Option<&mut Self> {
        match self {
            Value::Object(m) => {
                m.insert(key.to_string(), value.into());
                Some(self)
            }
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `v.at(&["a", "b"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Num(f) if f.fract() == 0.0 && f.abs() < 2f64.powi(53) => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Typed fetch with a descriptive error (config loading).
    pub fn require_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field `{key}`"))
    }

    pub fn require_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field `{key}`"))
    }

    pub fn require_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field `{key}`"))
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::Int(v as i64)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        // Saturate to i64 (JSON ints are i64 here); values beyond are
        // stored as floats.
        i64::try_from(v).map(Value::Int).unwrap_or(Value::Num(v as f64))
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        (v as u64).into()
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let mut v = Value::object();
        v.set("n", 3u32).set("s", "hi").set("f", 1.5).set("b", true);
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn path_lookup() {
        let mut inner = Value::object();
        inner.set("x", 1i64);
        let mut outer = Value::object();
        outer.set("inner", inner);
        assert_eq!(outer.at(&["inner", "x"]).unwrap().as_i64(), Some(1));
        assert!(outer.at(&["inner", "y"]).is_none());
    }

    #[test]
    fn set_on_non_object_is_detectable_no_op() {
        let mut v = Value::Int(3);
        assert!(v.try_set("k", 1i64).is_none());
        v.set("k", 1i64); // must not panic, must not mutate
        assert_eq!(v, Value::Int(3));
        let mut o = Value::object();
        assert!(o.try_set("k", 1i64).is_some());
        assert_eq!(o.get("k").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn u64_saturation() {
        let v: Value = u64::MAX.into();
        assert!(matches!(v, Value::Num(_)));
        let v: Value = 42u64.into();
        assert_eq!(v.as_i64(), Some(42));
    }

    #[test]
    fn require_errors_are_descriptive() {
        let v = Value::object();
        let err = v.require_u64("count").unwrap_err().to_string();
        assert!(err.contains("count"));
    }
}
