//! Hashing: the shard-key FNV-1a (bit-exact with the Pallas kernel and
//! `python/compile/kernels/ref.py`) plus a general-purpose 64-bit FNV
//! used for non-routing purposes (bucketing, checksums).

/// FNV-1a 32-bit parameters — keep in lockstep with `ref.py`.
pub const FNV_OFFSET_32: u32 = 2_166_136_261;
pub const FNV_PRIME_32: u32 = 16_777_619;

/// Shard-key hash: FNV-1a over the 8 little-endian bytes of
/// `(node_id, ts_min)`. This is the hash the routing artifact computes;
/// the Rust fallback and all chunk-split logic must use this function.
#[inline]
pub fn fnv1a_shard_key(node_id: u32, ts_min: u32) -> u32 {
    let mut h = FNV_OFFSET_32;
    for word in [node_id, ts_min] {
        for shift in [0u32, 8, 16, 24] {
            let byte = (word >> shift) & 0xFF;
            h = (h ^ byte).wrapping_mul(FNV_PRIME_32);
        }
    }
    h
}

/// FNV-1a 64-bit over arbitrary bytes (checksums, non-routing buckets).
#[inline]
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same vectors as `python/tests/test_route.py::test_fnv1a_known_vectors`.
    #[test]
    fn shard_key_known_vectors() {
        fn scalar(node: u32, ts: u32) -> u32 {
            let mut h: u64 = 2_166_136_261;
            for w in [node, ts] {
                for s in [0, 8, 16, 24] {
                    h = ((h ^ ((w as u64 >> s) & 0xFF)) * 16_777_619) % (1 << 32);
                }
            }
            h as u32
        }
        for (n, t) in [(0, 0), (1, 0), (0, 1), (12_345, 67_890), (u32::MAX, u32::MAX)] {
            assert_eq!(fnv1a_shard_key(n, t), scalar(n, t), "({n},{t})");
        }
    }

    #[test]
    fn shard_key_spreads() {
        // Sequential keys should not collide in low bits (routing quality).
        let mut buckets = [0u32; 64];
        for node in 0..1000u32 {
            for ts in 0..10u32 {
                buckets[(fnv1a_shard_key(node, ts) % 64) as usize] += 1;
            }
        }
        let min = *buckets.iter().min().unwrap();
        let max = *buckets.iter().max().unwrap();
        // 10_000 keys over 64 buckets ≈ 156 each; allow wide slack.
        assert!(min > 100 && max < 220, "min={min} max={max}");
    }

    #[test]
    fn fnv64_known_vector() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x8594_4171_f739_67e8);
    }
}
