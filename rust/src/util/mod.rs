//! Foundation utilities shared by every subsystem.
//!
//! The vendored dependency set has no `rand`, `serde`, or `chrono`; the
//! small, deterministic building blocks those would normally provide live
//! here instead: seedable PRNGs, the FNV-1a shard-key hash (bit-exact
//! with the Pallas kernel and `ref.py`), a wall/virtual clock abstraction,
//! and id/formatting helpers.

pub mod backoff;
pub mod clock;
pub mod compress;
pub mod fmt;
pub mod hash;
pub mod ids;
pub mod rng;

pub use backoff::Backoff;
pub use clock::{Clock, ManualClock, SystemClock};
pub use hash::fnv1a_shard_key;
pub use rng::{Pcg32, SplitMix64};
