//! Shard server (`mongod`): owns a storage engine on its assigned
//! filesystem directory, serves inserts/finds for the chunks it owns,
//! triggers chunk splits, and participates in migrations.
//!
//! Query planning per shard:
//! 1. `$in` on an indexed field → point lookups per value, residual
//!    matcher on fetched docs.
//! 2. range on an indexed field → index range scan; when the query is
//!    the paper's canonical shape (ts range + node-id set) the candidate
//!    columns are run through the AOT **filter kernel** instead of the
//!    scalar matcher.
//! 3. otherwise → full collection scan + matcher.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

use crate::mongo::bson::{Document, Value};
use crate::mongo::query::{Filter, FindOptions};
use crate::mongo::sharding::chunk::ChunkMap;
use crate::mongo::sharding::migration::STAGING_COLLECTION;
use crate::mongo::storage::{Engine, EngineOptions, RecordId, StorageDir};
use crate::mongo::wire::{
    rpc, ConfigRequest, DeleteChunkReply, FindReply, InsertReply, MigrateBatchReply,
    ShardRequest, ShardStatsReply, StagedMigration, WireError,
};
use crate::metrics::Registry;
use crate::runtime::Kernels;
use crate::util::ids::ShardId;

/// The sharded collection name (one sharded namespace, like the paper's
/// single OVIS metrics collection).
pub const COLLECTION: &str = "metrics";

struct CursorState {
    rids: Vec<RecordId>,
    pos: usize,
    projection: Option<Vec<String>>,
    batch: usize,
    remaining: Option<usize>,
}

/// Shard server state + event loop.
pub struct ShardServer {
    id: ShardId,
    engine: Engine,
    map: ChunkMap,
    config: mpsc::Sender<ConfigRequest>,
    kernels: Kernels,
    metrics: Registry,
    cursors: HashMap<u64, CursorState>,
    next_cursor: u64,
    /// Split a chunk when its (position-histogram) doc count exceeds this.
    split_threshold: u64,
    /// Position histogram: key position → docs at that position. Range
    /// sums give per-chunk counts; medians give split points.
    positions: std::collections::BTreeMap<u64, u32>,
    default_batch: usize,
    /// Migration staging on this destination — `(range, donor,
    /// committed)`, mirroring the durable `__migration` collection
    /// (rebuilt from it after a restart).
    staging: Option<((u64, u64), ShardId, bool)>,
    /// Staged data documents (meta records excluded).
    staged_docs: u64,
}

impl ShardServer {
    /// Open the shard's engine on `dir` (recovering any persisted
    /// state) and build the server. `engine_opts` carries the storage
    /// lifecycle: journaling, checkpoint compression, and the
    /// auto-compaction threshold this server enforces after every group
    /// commit.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: ShardId,
        dir: Box<dyn StorageDir>,
        map: ChunkMap,
        config: mpsc::Sender<ConfigRequest>,
        kernels: Kernels,
        metrics: Registry,
        engine_opts: EngineOptions,
        split_threshold: u64,
        default_batch: usize,
    ) -> anyhow::Result<Self> {
        let mut engine = Engine::open_with(dir, engine_opts)?;
        engine.create_collection(COLLECTION);
        let mut s = Self {
            id,
            engine,
            map,
            config,
            kernels,
            metrics,
            cursors: HashMap::new(),
            next_cursor: 1,
            split_threshold,
            positions: Default::default(),
            default_batch,
            staging: None,
            staged_docs: 0,
        };
        // Rebuild the position histogram from recovered records (second
        // job re-attaching to persisted Lustre data). Staged migration
        // documents are not live and never enter the histogram.
        let recovered: Vec<Document> =
            s.engine.scan(COLLECTION).map(|(_, d)| d).collect();
        for doc in &recovered {
            if let Some(pos) = s.position_of(doc) {
                *s.positions.entry(pos).or_insert(0) += 1;
            }
        }
        // Rebuild migration staging state: a killed migration leaves its
        // staging collection behind, and the cluster's reconciliation
        // pass (`sharding::migration::recover`) needs its identity.
        if s.engine.stats(STAGING_COLLECTION).docs > 0 {
            let mut range = (0u64, 0u64);
            let mut from = id;
            let mut committed = false;
            let mut meta_seen = false;
            for (_, d) in s.engine.scan(STAGING_COLLECTION) {
                if d.get_i64("__migmeta").is_some() {
                    meta_seen = true;
                    // Positions are u64; stored as bit-cast i64 (exact
                    // round trip).
                    range = (
                        d.get_i64("lo").unwrap_or(0) as u64,
                        d.get_i64("hi").unwrap_or(0) as u64,
                    );
                    from = ShardId(d.get_i64("from").unwrap_or(0) as u32);
                } else if d.get_i64("__migcommit").is_some() {
                    committed = true;
                } else {
                    s.staged_docs += 1;
                }
            }
            // A meta-less staging is torn pre-commit garbage: surface it
            // uncommitted so reconciliation rolls it back.
            s.staging = Some((range, from, committed && meta_seen));
        }
        Ok(s)
    }

    /// Spawn the event loop thread; returns its mailbox and join handle.
    pub fn spawn(self) -> (mpsc::Sender<ShardRequest>, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let join = self.spawn_with(rx);
        (tx, join)
    }

    /// Spawn on a pre-created channel (the cluster wires mailboxes before
    /// any thread starts).
    pub fn spawn_with(mut self, rx: mpsc::Receiver<ShardRequest>) -> std::thread::JoinHandle<()> {
        let name = format!("{}", self.id);
        std::thread::Builder::new()
            .name(name)
            .spawn(move || self.run(rx))
            .expect("spawn shard thread")
    }

    fn run(&mut self, rx: mpsc::Receiver<ShardRequest>) {
        while let Ok(req) = rx.recv() {
            match req {
                ShardRequest::Shutdown => break,
                ShardRequest::SetMap { map } => {
                    self.map = map;
                }
                ShardRequest::InsertBatch { version, docs, reply } => {
                    let t = Instant::now();
                    let r = self.handle_insert_many(version, docs);
                    self.metrics
                        .observe("shard.insert_batch_ns", t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::Find { filter, opts, reply } => {
                    let t = Instant::now();
                    let r = self.handle_find(&filter, &opts);
                    self.metrics.observe("shard.find_ns", t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::GetMore { cursor, reply } => {
                    let _ = reply.send(self.handle_get_more(cursor));
                }
                ShardRequest::Count { filter, reply } => {
                    let t = Instant::now();
                    let r = self.handle_count(&filter);
                    self.metrics.observe("shard.count_ns", t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                ShardRequest::CreateIndex { spec, reply } => {
                    let r = self
                        .engine
                        .create_index(COLLECTION, spec)
                        .map_err(|e| WireError::Server(e.to_string()));
                    let _ = reply.send(r);
                }
                ShardRequest::MigrateBatch { range, after, limit, reply } => {
                    let t = Instant::now();
                    let r = self.handle_migrate_batch(range, after, limit);
                    self.metrics
                        .observe("shard.migrate_batch_ns", t.elapsed().as_nanos() as u64);
                    let _ = reply.send(Ok(r));
                }
                ShardRequest::StageChunk { range, from, docs, reply } => {
                    let r = self.handle_stage_chunk(range, from, docs);
                    let _ = reply.send(r);
                }
                ShardRequest::CommitStaged { reply } => {
                    let _ = reply.send(self.handle_commit_staged());
                }
                ShardRequest::PublishStaged { reply } => {
                    let _ = reply.send(self.handle_publish_staged());
                }
                ShardRequest::AbortStaged { reply } => {
                    let _ = reply.send(self.handle_abort_staged());
                }
                ShardRequest::DeleteChunk { range, compact, reply } => {
                    let r = self.delete_range(range, compact);
                    let _ = reply.send(r);
                }
                ShardRequest::StagedState { reply } => {
                    let _ = reply.send(self.staged_state());
                }
                ShardRequest::Stats { reply } => {
                    let _ = reply.send(self.stats());
                }
                ShardRequest::Checkpoint { reply } => {
                    let r = self
                        .engine
                        .checkpoint()
                        .map_err(|e| WireError::Server(e.to_string()));
                    if r.is_ok() {
                        self.metrics.counter("shard.checkpoints").inc();
                    }
                    let _ = reply.send(r);
                }
            }
        }
    }

    /// Background compaction hook, run after every group commit: once
    /// the engine has journaled past its configured threshold, write a
    /// checkpoint and rotate/truncate the journal so the shard's
    /// on-disk footprint on the shared filesystem stays bounded.
    ///
    /// A compaction failure must not fail the triggering write — the
    /// batch is already durable in the journal — so errors are counted
    /// and logged, and the next group commit retries (the byte counter
    /// keeps growing until a checkpoint succeeds).
    fn maybe_compact(&mut self) {
        match self.engine.maybe_checkpoint() {
            Ok(Some(ck)) => {
                self.metrics.counter("shard.checkpoints").inc();
                if ck.full {
                    // Generation 1 or a chain rebase: the one compaction
                    // whose cost scales with the live set.
                    self.metrics.counter("shard.rebases").inc();
                }
                self.metrics.counter("shard.delta_bytes").add(ck.delta_bytes);
                self.metrics
                    .counter("shard.segments_truncated")
                    .add(ck.segments_truncated);
                self.metrics
                    .counter("shard.journal_bytes_truncated")
                    .add(ck.journal_bytes_truncated);
            }
            Ok(None) => {}
            Err(e) => {
                self.metrics.counter("shard.checkpoint_errors").inc();
                eprintln!("warn: {}: background checkpoint failed: {e:#}", self.id);
            }
        }
    }

    /// Shard-key position of a document (`None` if key fields missing).
    fn position_of(&self, doc: &Document) -> Option<u64> {
        let node = doc.get_i64("node_id")? as u32;
        let ts = doc.get_i64("ts")? as u32;
        Some(self.map.key.position(node, ts))
    }

    /// Bulk-ingest leg on the shard: version handshake, owner filtering,
    /// then the owned run is indexed and journaled as a whole batch with
    /// a single group commit.
    fn handle_insert_many(
        &mut self,
        version: u64,
        docs: Vec<Document>,
    ) -> Result<InsertReply, WireError> {
        // Version handshake: if the router is ahead, catch up from the
        // config server; if the router is behind, tell it to refresh.
        if version > self.map.version {
            if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                self.map = map;
            }
        }
        if version != self.map.version {
            self.metrics.counter("shard.stale_version").inc();
            return Err(WireError::StaleVersion { current: self.map.version });
        }

        // Split the batch into owned documents and wrong-owner rejects,
        // then index + journal the owned run as ONE multi-record frame.
        let mut wrong_owner = Vec::new();
        let mut touched_chunks: Vec<usize> = Vec::new();
        let mut owned_docs: Vec<Document> = Vec::with_capacity(docs.len());
        let mut owned_pos: Vec<u64> = Vec::with_capacity(docs.len());
        for (i, doc) in docs.into_iter().enumerate() {
            let Some(pos) = self.position_of(&doc) else {
                wrong_owner.push(i);
                continue;
            };
            let chunk = self.map.chunk_of(pos);
            if self.map.owners[chunk] != self.id {
                wrong_owner.push(i);
                continue;
            }
            if !touched_chunks.contains(&chunk) {
                touched_chunks.push(chunk);
            }
            owned_docs.push(doc);
            owned_pos.push(pos);
        }
        let inserted = owned_docs.len();
        self.engine
            .insert_many(COLLECTION, &owned_docs)
            .map_err(|e| WireError::Server(e.to_string()))?;
        for pos in owned_pos {
            *self.positions.entry(pos).or_insert(0) += 1;
        }
        // Group commit once per batch: one journal frame, one sync.
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        self.metrics.counter("shard.group_commits").inc();
        self.metrics.counter("shard.docs_inserted").add(inserted as u64);
        self.maybe_compact();

        // Split any chunk that crossed the threshold.
        for chunk in touched_chunks {
            self.maybe_split(chunk);
        }
        Ok(InsertReply { inserted, wrong_owner })
    }

    fn chunk_doc_count(&self, chunk: usize) -> u64 {
        let (lo, hi) = self.map.chunk_range(chunk);
        self.positions.range(lo..=hi).map(|(_, c)| *c as u64).sum()
    }

    /// Median position within a chunk (split point).
    fn chunk_median(&self, chunk: usize) -> Option<u64> {
        let (lo, hi) = self.map.chunk_range(chunk);
        let total: u64 = self.chunk_doc_count(chunk);
        if total < 2 {
            return None;
        }
        let mut seen = 0u64;
        for (&pos, &c) in self.positions.range(lo..=hi) {
            seen += c as u64;
            if seen >= total / 2 {
                // Split point must be < hi and >= lo.
                if pos >= hi {
                    return None;
                }
                return Some(pos);
            }
        }
        None
    }

    fn maybe_split(&mut self, chunk: usize) {
        if self.chunk_doc_count(chunk) <= self.split_threshold {
            return;
        }
        let Some(at) = self.chunk_median(chunk) else { return };
        let seen = self.map.version;
        if let Ok(Ok(check)) = rpc(&self.config, |reply| ConfigRequest::ReportSplit {
            seen_version: seen,
            chunk,
            at,
            reply,
        }) {
            use crate::mongo::sharding::config_server::VersionCheck;
            match check {
                VersionCheck::Ok => {
                    self.metrics.counter("shard.splits").inc();
                    // Config pushes SetMap to everyone (including us); we
                    // may process it on the next loop turn. Update our
                    // local copy eagerly to keep counting accurate.
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.map = map;
                    }
                }
                VersionCheck::Stale { .. } => {
                    self.metrics.counter("shard.split_stale").inc();
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.map = map;
                    }
                }
            }
        }
    }

    /// The paper's canonical query shape, *exactly*: a conjunction of
    /// `ts >= lo` (`$gte`), `ts < hi` (`$lt`) and `node_id $in [ints]`
    /// and nothing else — the only shape the filter kernel's predicate
    /// `lo <= ts < hi && node in set` evaluates completely. Any other
    /// filter takes the scalar matcher path.
    fn canonical_shape(filter: &Filter) -> Option<(u32, u32, Vec<u32>)> {
        use crate::mongo::query::CmpOp;
        let conjuncts = match filter {
            Filter::And(fs) => fs.as_slice(),
            f @ Filter::In { .. } => std::slice::from_ref(f),
            _ => return None,
        };
        let mut lo: Option<u32> = None;
        let mut hi: Option<u32> = None;
        let mut nodes: Option<Vec<u32>> = None;
        for c in conjuncts {
            match c {
                Filter::Cmp { field, op: CmpOp::Gte, value }
                    if field == "ts" && lo.is_none() =>
                {
                    let v = value.as_i64()?;
                    if !(0..=u32::MAX as i64).contains(&v) {
                        return None;
                    }
                    lo = Some(v as u32);
                }
                Filter::Cmp { field, op: CmpOp::Lt, value }
                    if field == "ts" && hi.is_none() =>
                {
                    let v = value.as_i64()?;
                    if !(0..=u32::MAX as i64).contains(&v) {
                        return None;
                    }
                    hi = Some(v as u32);
                }
                Filter::In { field, values } if field == "node_id" && nodes.is_none() => {
                    let mut ids = Vec::with_capacity(values.len());
                    for v in values {
                        let n = v.as_i64()?;
                        if !(0..=u32::MAX as i64).contains(&n) {
                            return None;
                        }
                        ids.push(n as u32);
                    }
                    nodes = Some(ids);
                }
                _ => return None, // anything else → matcher path
            }
        }
        Some((lo.unwrap_or(0), hi.unwrap_or(u32::MAX), nodes?))
    }

    fn handle_find(
        &mut self,
        filter: &Filter,
        opts: &FindOptions,
    ) -> Result<FindReply, WireError> {
        let candidates: Vec<RecordId> = self.plan_candidates(filter);
        self.metrics
            .counter("shard.find_candidates")
            .add(candidates.len() as u64);

        // Kernel fast path for the canonical shape over index candidates.
        let rids: Vec<RecordId> = if let Some((lo, hi, nodes)) = Self::canonical_shape(filter) {
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            let words = self.kernels.shapes().filter_w;
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                self.metrics.counter("shard.find_kernel_path").inc();
                let mut ts_col = Vec::with_capacity(candidates.len());
                let mut node_col = Vec::with_capacity(candidates.len());
                let mut docs: Vec<(RecordId, Document)> = Vec::with_capacity(candidates.len());
                for &rid in &candidates {
                    if let Some(d) = self.engine.fetch(COLLECTION, rid) {
                        ts_col.push(d.get_i64("ts").unwrap_or(-1).max(0) as u32);
                        node_col.push(d.get_i64("node_id").unwrap_or(0).max(0) as u32);
                        docs.push((rid, d));
                    }
                }
                let bitmap = crate::runtime::fallback::build_bitmap(nodes, words);
                let out = self
                    .kernels
                    .filter(&ts_col, &node_col, lo, hi, &bitmap)
                    .map_err(|e| WireError::Server(e.to_string()))?;
                docs.iter()
                    .zip(&out.mask)
                    .filter(|(_, &m)| m == 1)
                    .map(|((rid, _), _)| *rid)
                    .collect()
            } else {
                self.matcher_path(&candidates, filter)
            }
        } else {
            self.matcher_path(&candidates, filter)
        };

        self.metrics.counter("shard.find_matches").add(rids.len() as u64);
        let batch = opts.batch_size.unwrap_or(self.default_batch);
        let mut cur = CursorState {
            rids,
            pos: 0,
            projection: opts.projection.clone(),
            batch,
            remaining: opts.limit,
        };
        // Sort: materialize + order by field before serving (only sane
        // with a limit; workload queries don't sort).
        if let Some((field, dir)) = &opts.sort {
            let mut docs: Vec<(RecordId, Document)> = cur
                .rids
                .iter()
                .filter_map(|&r| self.engine.fetch(COLLECTION, r).map(|d| (r, d)))
                .collect();
            docs.sort_by(|(_, a), (_, b)| {
                let o = a
                    .get(field)
                    .unwrap_or(&Value::Null)
                    .cmp_total(b.get(field).unwrap_or(&Value::Null));
                match dir {
                    crate::mongo::query::SortDir::Asc => o,
                    crate::mongo::query::SortDir::Desc => o.reverse(),
                }
            });
            cur.rids = docs.into_iter().map(|(r, _)| r).collect();
        }
        let reply = self.serve_batch(&mut cur);
        if reply.cursor.is_some() {
            let id = self.next_cursor;
            self.next_cursor += 1;
            self.cursors.insert(id, cur);
            Ok(FindReply { docs: reply.docs, cursor: Some(id) })
        } else {
            Ok(reply)
        }
    }

    /// Choose an access path and produce candidate record ids.
    fn plan_candidates(&self, filter: &Filter) -> Vec<RecordId> {
        // 1. $in on indexed node_id → point lookups; when a ts range is
        // also present and indexed, intersect the two rid sets (index
        // intersection) so candidates ≈ matches instead of each node's
        // full history.
        if let Some(values) = filter.in_values("node_id") {
            if let Some(idx) = self.engine.index(COLLECTION, "node_id_1") {
                let mut rids = Vec::new();
                for v in values {
                    rids.extend(idx.point(&[v]));
                }
                if let Some((lo, hi)) = filter.index_range("ts") {
                    if let Some(ts_idx) = self.engine.index(COLLECTION, "ts_1") {
                        self.metrics.counter("shard.plan_intersect").inc();
                        let ts_rids = ts_idx.range_superset(lo.as_ref(), hi.as_ref());
                        let in_ts: std::collections::HashSet<RecordId> =
                            ts_rids.into_iter().collect();
                        rids.retain(|r| in_ts.contains(r));
                        return rids;
                    }
                }
                self.metrics.counter("shard.plan_in_points").inc();
                return rids;
            }
        }
        // 2. Range on indexed ts (inclusive superset; residual filter
        // downstream restores exact operator semantics).
        if let Some((lo, hi)) = filter.index_range("ts") {
            if let Some(idx) = self.engine.index(COLLECTION, "ts_1") {
                self.metrics.counter("shard.plan_ts_range").inc();
                return idx.range_superset(lo.as_ref(), hi.as_ref());
            }
        }
        // 2b. Range/eq on indexed node_id.
        if let Some((lo, hi)) = filter.index_range("node_id") {
            if let Some(idx) = self.engine.index(COLLECTION, "node_id_1") {
                self.metrics.counter("shard.plan_node_range").inc();
                return idx.range_superset(lo.as_ref(), hi.as_ref());
            }
        }
        // 3. Full scan.
        self.metrics.counter("shard.plan_full_scan").inc();
        self.engine.record_ids(COLLECTION)
    }

    fn matcher_path(&self, candidates: &[RecordId], filter: &Filter) -> Vec<RecordId> {
        self.metrics.counter("shard.find_matcher_path").inc();
        candidates
            .iter()
            .filter_map(|&rid| {
                let d = self.engine.fetch(COLLECTION, rid)?;
                filter.matches(&d).then_some(rid)
            })
            .collect()
    }

    fn serve_batch(&self, cur: &mut CursorState) -> FindReply {
        let mut docs = Vec::with_capacity(cur.batch.min(cur.rids.len() - cur.pos));
        while cur.pos < cur.rids.len() && docs.len() < cur.batch {
            if let Some(limit) = cur.remaining {
                if limit == 0 {
                    cur.pos = cur.rids.len();
                    break;
                }
            }
            let rid = cur.rids[cur.pos];
            cur.pos += 1;
            if let Some(doc) = self.engine.fetch(COLLECTION, rid) {
                let doc = match &cur.projection {
                    Some(fields) => doc.project(fields),
                    None => doc,
                };
                docs.push(doc);
                if let Some(r) = cur.remaining.as_mut() {
                    *r -= 1;
                }
            }
        }
        let more = cur.pos < cur.rids.len() && cur.remaining != Some(0);
        FindReply { docs, cursor: more.then_some(0) }
    }

    /// Count without materializing documents for the client. Uses the
    /// same planner; the kernel path only needs the match count.
    fn handle_count(&mut self, filter: &Filter) -> Result<u64, WireError> {
        let candidates = self.plan_candidates(filter);
        if let Some((lo, hi, nodes)) = Self::canonical_shape(filter) {
            let words = self.kernels.shapes().filter_w;
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                let mut ts_col = Vec::with_capacity(candidates.len());
                let mut node_col = Vec::with_capacity(candidates.len());
                for &rid in &candidates {
                    if let Some(d) = self.engine.fetch(COLLECTION, rid) {
                        ts_col.push(d.get_i64("ts").unwrap_or(-1).max(0) as u32);
                        node_col.push(d.get_i64("node_id").unwrap_or(0).max(0) as u32);
                    }
                }
                let bitmap = crate::runtime::fallback::build_bitmap(nodes, words);
                let out = self
                    .kernels
                    .filter(&ts_col, &node_col, lo, hi, &bitmap)
                    .map_err(|e| WireError::Server(e.to_string()))?;
                return Ok(out.count as u64);
            }
        }
        Ok(self.matcher_path(&candidates, filter).len() as u64)
    }

    fn handle_get_more(&mut self, cursor: u64) -> Result<FindReply, WireError> {
        let mut cur = self
            .cursors
            .remove(&cursor)
            .ok_or(WireError::UnknownCursor(cursor))?;
        let mut reply = self.serve_batch(&mut cur);
        if reply.cursor.is_some() {
            self.cursors.insert(cursor, cur);
            reply.cursor = Some(cursor);
        }
        Ok(reply)
    }

    /// Migration source: one bounded batch of the range, resuming from
    /// the record-id cursor `after`. The scan itself is capped (not
    /// only the match count), so even a sparse range never holds the
    /// event loop for more than a bounded walk — invariant IM2.
    fn handle_migrate_batch(
        &self,
        range: (u64, u64),
        after: Option<u64>,
        limit: usize,
    ) -> MigrateBatchReply {
        let limit = limit.max(1);
        let scan_cap = limit.saturating_mul(8).max(4096);
        let mut docs = Vec::new();
        let mut last = None;
        let mut scanned = 0usize;
        let mut done = true;
        for (rid, doc) in self.engine.scan_from(COLLECTION, after) {
            scanned += 1;
            last = Some(rid);
            if let Some(pos) = self.position_of(&doc) {
                if range.0 <= pos && pos <= range.1 {
                    docs.push(doc);
                }
            }
            if docs.len() >= limit || scanned >= scan_cap {
                done = false;
                break;
            }
        }
        MigrateBatchReply { docs, last, done }
    }

    /// Migration destination: stage one copied batch in the
    /// `__migration` collection — durable via the same group-committed
    /// `insert_many` path as ingest, but invisible to queries until
    /// published. The first batch pins the migration identity (range +
    /// donor) in a meta record, journaled ahead of any data.
    fn handle_stage_chunk(
        &mut self,
        range: (u64, u64),
        from: ShardId,
        docs: Vec<Document>,
    ) -> Result<usize, WireError> {
        self.engine.create_collection(STAGING_COLLECTION);
        match self.staging {
            Some((_, _, true)) => {
                return Err(WireError::Server(
                    "a committed migration awaits publish".into(),
                ));
            }
            Some((r, f, false)) if r != range || f != from => {
                return Err(WireError::Server("another migration is staged".into()));
            }
            Some(_) => {}
            None => {
                let meta = Document::new()
                    .set("__migmeta", 1i64)
                    .set("lo", range.0 as i64)
                    .set("hi", range.1 as i64)
                    .set("from", from.0 as i64);
                self.engine
                    .insert_many(STAGING_COLLECTION, &[meta])
                    .map_err(|e| WireError::Server(e.to_string()))?;
                self.staging = Some((range, from, false));
            }
        }
        let n = docs.len();
        self.engine
            .insert_many(STAGING_COLLECTION, &docs)
            .map_err(|e| WireError::Server(e.to_string()))?;
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        self.staged_docs += n as u64;
        self.metrics.counter("shard.migration_docs_in").add(n as u64);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: durably write the commit marker — one
    /// journal frame plus a sync. From the moment this replies, the
    /// migration can only roll forward (M3). Idempotent.
    fn handle_commit_staged(&mut self) -> Result<u64, WireError> {
        let Some((range, from, committed)) = self.staging else {
            return Err(WireError::Server("nothing staged".into()));
        };
        if !committed {
            let marker = Document::new().set("__migcommit", 1i64);
            self.engine
                .insert_many(STAGING_COLLECTION, &[marker])
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
            self.staging = Some((range, from, true));
        }
        Ok(self.staged_docs)
    }

    /// Migration destination: publish the staged documents into the
    /// live collection as **one atomic move frame** (replay never sees
    /// them in both collections or in neither), then drop the meta
    /// records. Idempotent: an empty or marker-only staging publishes
    /// nothing and just cleans up.
    fn handle_publish_staged(&mut self) -> Result<u64, WireError> {
        if self.staging.is_none() && self.engine.stats(STAGING_COLLECTION).docs == 0 {
            return Ok(0);
        }
        let mut data: Vec<(RecordId, Document)> = Vec::new();
        let mut meta: Vec<RecordId> = Vec::new();
        for (rid, doc) in self.engine.scan(STAGING_COLLECTION) {
            if doc.get_i64("__migmeta").is_some() || doc.get_i64("__migcommit").is_some() {
                meta.push(rid);
            } else {
                data.push((rid, doc));
            }
        }
        let rids: Vec<RecordId> = data.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        self.engine
            .move_many(STAGING_COLLECTION, COLLECTION, &rids)
            .map_err(|e| WireError::Server(e.to_string()))?;
        if !meta.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &meta)
                .map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        for (_, doc) in &data {
            if let Some(pos) = self.position_of(doc) {
                *self.positions.entry(pos).or_insert(0) += 1;
            }
        }
        self.staging = None;
        self.staged_docs = 0;
        self.metrics.counter("shard.migration_docs_published").add(n);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: drop an *uncommitted* staged range — the
    /// awaited abort path that used to orphan these documents. Refuses
    /// to drop a committed staging (that one must roll forward).
    fn handle_abort_staged(&mut self) -> Result<u64, WireError> {
        if let Some((_, _, true)) = self.staging {
            return Err(WireError::Server(
                "staged migration is committed; cannot abort".into(),
            ));
        }
        let rids = self.engine.record_ids(STAGING_COLLECTION);
        let dropped = self.staged_docs;
        if !rids.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.staging = None;
        self.staged_docs = 0;
        self.metrics.counter("shard.migration_aborts").inc();
        self.maybe_compact();
        Ok(dropped)
    }

    fn staged_state(&self) -> Option<StagedMigration> {
        self.staging.map(|(range, from, committed)| StagedMigration {
            range,
            from,
            committed,
            docs: self.staged_docs,
        })
    }

    /// Migration source: delete the committed-away range as **one**
    /// atomic `remove_many` frame (a kill can never half-delete the
    /// chunk), then — when `compact` — checkpoint immediately so the
    /// moved-away documents leave this shard's journal and delta chain
    /// instead of occupying the shared filesystem until the next
    /// threshold crossing.
    fn delete_range(
        &mut self,
        range: (u64, u64),
        compact: bool,
    ) -> Result<DeleteChunkReply, WireError> {
        let doomed: Vec<(RecordId, u64)> = self
            .engine
            .scan(COLLECTION)
            .filter_map(|(rid, d)| {
                let pos = self.position_of(&d)?;
                (range.0 <= pos && pos <= range.1).then_some((rid, pos))
            })
            .collect();
        let rids: Vec<RecordId> = doomed.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        if !rids.is_empty() {
            self.engine
                .remove_many(COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            for (_, pos) in doomed {
                if let Some(c) = self.positions.get_mut(&pos) {
                    *c -= 1;
                    if *c == 0 {
                        self.positions.remove(&pos);
                    }
                }
            }
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.metrics.counter("shard.migration_docs_out").add(n);
        let compacted = if compact && n > 0 {
            let ck = self
                .engine
                .checkpoint()
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.metrics.counter("shard.checkpoints").inc();
            self.metrics
                .counter("shard.journal_bytes_truncated")
                .add(ck.journal_bytes_truncated);
            Some(ck)
        } else {
            self.maybe_compact();
            None
        };
        Ok(DeleteChunkReply { removed: n, compacted })
    }

    fn stats(&self) -> ShardStatsReply {
        let chunks_owned = self
            .map
            .owners
            .iter()
            .filter(|o| **o == self.id)
            .count() as u32;
        ShardStatsReply {
            collection: self.engine.stats(COLLECTION),
            chunks_owned,
            map_version: self.map.version,
            journal_bytes: self.engine.pending_journal_bytes() as u64,
            journal_disk_bytes: self.engine.journal_disk_bytes(),
            checkpoint_generation: self.engine.generation(),
            checkpoint_chain_len: self.engine.chain_len(),
            delta_disk_bytes: self.engine.chain_disk_bytes(),
            staged_docs: self.staged_docs,
        }
    }
}
