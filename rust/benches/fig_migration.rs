//! FM — chunk migration: donor availability vs stream batch size, and
//! the byte/lifecycle hand-back at commit.
//!
//! The claim under test is the tentpole of the streaming migration
//! refactor: a migration must **coexist** with the live workload. The
//! pre-refactor protocol shipped a whole chunk as one mailbox message,
//! so the donor's event loop stalled for the full extract; the
//! streaming protocol bounds the donor's longest stall by one
//! `--migration-batch-docs` batch (invariant IM2). Rows sweep the batch
//! size on a live two-shard cluster with a deliberately skewed ranged
//! corpus, while a background client keeps inserting against the donor:
//! the `donor insert max` column is what that client actually
//! experienced during the balancer round. The one-shot row emulates the
//! old behaviour (batch ≈ chunk size).
//!
//! The second column group shows invariant IM4: the donor's on-disk
//! journal + delta footprint before and after the post-commit
//! compaction — moved-away data leaves the shared filesystem at
//! commit, instead of squatting until the next threshold crossing.
//!
//! The second table is the DES axis: the same sweep at paper scale
//! (`SimSpec::{migrations, migration_batch}`), where
//! `migration_stall_ns` is the donor's longest contiguous occupancy.
//!
//! Run: `cargo bench --bench fig_migration` (add `--quick` for a small
//! sweep). See `docs/EXPERIMENTS.md` for the recorded-results template.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::{ShardKeyKind, StoreConfig};
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_count;

fn doc(ts: i64) -> Document {
    // Single node id + increasing ts: under a ranged shard key every
    // document lands in one shard's chunks — the skew the balancer
    // must then stream away.
    Document::new()
        .set("ts", ts)
        .set("node_id", 5i64)
        .set("m0", ts as f64 * 0.5)
        .set("m1", (ts * 7) as f64)
}

fn main() {
    let (corpus, probe_batch): (i64, usize) = if quick_mode() { (6_000, 25) } else { (20_000, 50) };
    // batch = chunk size emulates the pre-refactor one-shot protocol.
    let batches: &[(usize, &str)] = &[
        (1 << 30, "one-shot (old)"),
        (4_096, "4096"),
        (512, "512"),
    ];

    let mut report = Report::new(
        "Migration — donor availability vs stream batch size (live 2-shard cluster)",
    );
    report.set_custom(
        [
            "batch docs",
            "chunks moved",
            "docs moved",
            "round ms",
            "donor insert mean",
            "donor insert max",
            "src journal+delta before",
            "after commit compaction",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &(batch, label) in batches {
        let mut spec = ClusterSpec::small(2, 1);
        spec.chunks_per_shard = 1;
        spec.store = StoreConfig {
            shard_key: ShardKeyKind::Ranged,
            max_chunk_docs: if quick_mode() { 800 } else { 2_000 },
            migration_batch_docs: batch,
            // Compact only via the migration's triggered checkpoint, so
            // the before/after columns isolate the commit hand-back.
            checkpoint_bytes: 0,
            ..Default::default()
        };
        let label_dir = format!("figmig-{batch}");
        let cluster = Cluster::start(
            spec,
            move |sid| Ok(Box::new(LocalDir::temp(&format!("{label_dir}-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        let docs: Vec<Document> = (0..corpus).map(doc).collect();
        for chunk in docs.chunks(1_000) {
            client.insert_many(chunk.to_vec()).unwrap();
        }
        let before = cluster.shard_stats();
        let donor = before
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.collection.docs)
            .map(|(i, _)| i)
            .unwrap();
        let before_disk =
            before[donor].journal_disk_bytes + before[donor].delta_disk_bytes;

        // Background client: keeps inserting into the donor's key range
        // while the balancer round streams chunks away; its observed
        // latencies are the availability measurement.
        let stop = Arc::new(AtomicBool::new(false));
        let probe = {
            let stop = stop.clone();
            let c = cluster.client();
            let base = corpus;
            std::thread::spawn(move || -> Vec<f64> {
                let mut lat = Vec::new();
                let mut ts = base;
                while !stop.load(Ordering::Relaxed) {
                    let batch: Vec<Document> =
                        (0..probe_batch as i64).map(|i| doc(ts + i)).collect();
                    ts += probe_batch as i64;
                    let t = Instant::now();
                    c.insert_many(batch).unwrap();
                    lat.push(t.elapsed().as_nanos() as f64);
                }
                lat
            })
        };
        let t = Instant::now();
        let moved = cluster.run_balancer_round().unwrap();
        let round_ns = t.elapsed().as_nanos() as u64;
        stop.store(true, Ordering::Relaxed);
        let lat = probe.join().unwrap();
        let mean = lat.iter().sum::<f64>() / lat.len().max(1) as f64;
        let max = lat.iter().cloned().fold(0.0f64, f64::max);

        let after = cluster.shard_stats();
        let after_disk =
            after[donor].journal_disk_bytes + after[donor].delta_disk_bytes;
        let stats = cluster.stats();
        let moved_docs = cluster.metrics().counter("cluster.migration_docs").get();
        assert_eq!(stats.docs as i64, corpus + lat.len() as i64 * probe_batch as i64);
        assert!(moved > 0, "the skewed corpus must trigger migrations");

        report.add_row(vec![
            label.to_string(),
            moved.to_string(),
            human_count(moved_docs),
            format!("{:.1}", round_ns as f64 / 1e6),
            format!("{:.2} ms", mean / 1e6),
            format!("{:.2} ms", max / 1e6),
            format!("{} B", human_count(before_disk)),
            format!("{} B", human_count(after_disk)),
        ]);
        cluster.shutdown();
    }
    report.print();
    println!(
        "\nclaim: the donor's worst-case insert latency tracks the migration batch \
         size (one bounded batch per mailbox turn), and the post-commit compaction \
         hands the moved-away bytes back to the shared filesystem\n"
    );

    // --- DES axis: the same trade at paper scale. ---------------------
    let cost = CostModel::default().with_network_floor();
    let sweep: &[usize] = if quick_mode() {
        &[1 << 20, 1_024]
    } else {
        &[1 << 20, 8_192, 1_024, 256]
    };
    let mut report = Report::new("Migration — DES axis (32-node preset, 8 migrations)");
    report.set_custom(
        ["batch docs", "migrations", "stall ms (max)", "ingest virt s", "docs/s"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &mb in sweep {
        let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
        spec.monitored_nodes = 256;
        spec.max_chunk_docs = 16_000;
        spec.migrations = 8;
        spec.migration_batch = mb;
        let r = ClusterSim::new(spec).run();
        report.add_row(vec![
            if mb >= (1 << 20) { "one-shot".into() } else { mb.to_string() },
            r.migrations.to_string(),
            format!("{:.2}", r.migration_stall_ns as f64 / 1e6),
            format!("{:.1}", r.ingest_virt_ns as f64 / 1e9),
            human_count(r.docs_per_sec as u64),
        ]);
    }
    report.print();
    println!(
        "\nclaim: smaller stream batches bound the donor stall (the latency a \
         co-scheduled request can hide behind) at a modest fixed-cost premium\n"
    );
}
