//! Live-cluster integration: config + shards + routers as real threads,
//! pymongo-like client, splits, balancer migrations, persistence across
//! "jobs".
//!
//! Uses the scalar kernel fallback so these tests run without
//! `artifacts/` (the HLO path is sealed by `runtime_roundtrip.rs`).

use hpcstore::config::{ShardKeyKind, StoreConfig};
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::{CmpOp, Filter, FindOptions};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::util::rng::Pcg32;

fn start(spec: ClusterSpec, label: &str) -> Cluster {
    Cluster::start(
        spec,
        |sid| Ok(Box::new(LocalDir::temp(&format!("{label}-{sid}"))?)),
        Kernels::fallback(),
        Registry::new(),
    )
    .unwrap()
}

fn metric_doc(ts: i64, node: i64) -> Document {
    Document::new()
        .set("ts", ts)
        .set("node_id", node)
        .set("cpu_user", (ts % 100) as f64 / 100.0)
        .set("mem_used", (node * 1024) as f64)
}

#[test]
fn insert_and_find_round_trip() {
    let cluster = start(ClusterSpec::small(3, 2), "rt");
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    client.create_index(IndexSpec::single("node_id")).unwrap();

    let docs: Vec<Document> = (0..500).map(|i| metric_doc(1000 + i, i % 10)).collect();
    let rep = client.insert_many(docs).unwrap();
    assert_eq!(rep.inserted, 500);

    // Conditional find: paper's shape (ts range + node set).
    let f = Filter::and(vec![
        Filter::is_in("node_id", vec![Value::Int(3), Value::Int(4)]),
        Filter::cmp("ts", CmpOp::Gte, 1000i64),
        Filter::cmp("ts", CmpOp::Lt, 1100i64),
    ]);
    let got: Vec<Document> = client.find(f, FindOptions::default()).unwrap().collect();
    assert_eq!(got.len(), 20); // 100 ts values, 2 of 10 nodes
    assert!(got.iter().all(|d| {
        let n = d.get_i64("node_id").unwrap();
        n == 3 || n == 4
    }));

    let stats = cluster.stats();
    assert_eq!(stats.docs, 500);
    // Hashed keys spread docs across all shards.
    assert!(stats.per_shard_docs.iter().all(|&d| d > 0), "{:?}", stats.per_shard_docs);
    cluster.shutdown();
}

#[test]
fn count_documents_and_limit_and_projection() {
    let cluster = start(ClusterSpec::small(2, 1), "cnt");
    let client = cluster.client();
    let docs: Vec<Document> = (0..300).map(|i| metric_doc(i, i % 5)).collect();
    client.insert_many(docs).unwrap();

    assert_eq!(client.count_documents(Filter::True).unwrap(), 300);
    assert_eq!(
        client.count_documents(Filter::range("ts", 100i64, 200i64)).unwrap(),
        100
    );

    let got: Vec<Document> = client
        .find(
            Filter::True,
            FindOptions::default().limit(25).project(&["ts"]).batch_size(7),
        )
        .unwrap()
        .collect();
    assert_eq!(got.len(), 25);
    assert!(got.iter().all(|d| d.len() == 1 && d.get("ts").is_some()));
    cluster.shutdown();
}

#[test]
fn chunk_splits_happen_under_load() {
    let mut spec = ClusterSpec::small(2, 1);
    spec.store = StoreConfig { max_chunk_docs: 50, ..Default::default() };
    spec.chunks_per_shard = 1;
    let cluster = start(spec, "split");
    let client = cluster.client();
    let docs: Vec<Document> = (0..2000).map(|i| metric_doc(i, i % 50)).collect();
    for chunk in docs.chunks(200) {
        client.insert_many(chunk.to_vec()).unwrap();
    }
    let stats = cluster.stats();
    assert_eq!(stats.docs, 2000);
    assert!(
        stats.chunks > 2,
        "expected splits beyond the 2 pre-split chunks, got {}",
        stats.chunks
    );
    assert!(stats.map_version > 1);
    cluster.shutdown();
}

#[test]
fn balancer_moves_chunks_on_ranged_skew() {
    // Ranged shard key + time-ordered inserts = hot last chunk (the
    // pathology hashed sharding avoids); the balancer must spread chunks.
    let mut spec = ClusterSpec::small(3, 1);
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 100,
        ..Default::default()
    };
    spec.chunks_per_shard = 1;
    let cluster = start(spec, "bal");
    let client = cluster.client();
    for wave in 0..10 {
        let docs: Vec<Document> =
            (0..300).map(|i| metric_doc(wave * 300 + i, 7)).collect();
        client.insert_many(docs).unwrap();
        cluster.run_balancer_round().unwrap();
    }
    let stats = cluster.stats();
    assert_eq!(stats.docs, 3000);
    assert!(stats.migrations > 0, "balancer never migrated");
    // After balancing, no shard should hold everything.
    let max = *stats.per_shard_docs.iter().max().unwrap();
    assert!(max < 3000, "all docs on one shard: {:?}", stats.per_shard_docs);
    cluster.shutdown();
}

#[test]
fn data_persists_across_jobs() {
    // Job 1 ingests and checkpoints; job 2 reattaches to the same
    // directories and reads the data — the paper's transient-job model.
    let dirs: Vec<LocalDir> = (0..2).map(|i| LocalDir::temp(&format!("persist-{i}")).unwrap()).collect();
    let roots: Vec<String> = dirs
        .iter()
        .map(|d| {
            use hpcstore::mongo::storage::StorageDir;
            d.describe()
        })
        .collect();
    drop(dirs);

    let spec = ClusterSpec::small(2, 1);
    {
        let roots = roots.clone();
        let cluster = Cluster::start(
            spec.clone(),
            move |sid| Ok(Box::new(LocalDir::new(&roots[sid.index()])?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::single("node_id")).unwrap();
        client
            .insert_many((0..400).map(|i| metric_doc(i, i % 8)).collect())
            .unwrap();
        cluster.checkpoint_all().unwrap();
        cluster.shutdown();
    }
    {
        let cluster = Cluster::start(
            spec,
            move |sid| Ok(Box::new(LocalDir::new(&roots[sid.index()])?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        assert_eq!(client.count_documents(Filter::True).unwrap(), 400);
        assert_eq!(
            client.count_documents(Filter::eq("node_id", 3i64)).unwrap(),
            50
        );
        cluster.shutdown();
    }
}

#[test]
fn shard_compaction_hook_bounds_journal_under_ingest() {
    // Storage lifecycle end-to-end: with a small compaction threshold,
    // the per-shard background hook (run after every group commit)
    // must checkpoint repeatedly and keep each shard's on-disk journal
    // below one threshold + one segment.
    let threshold: u64 = 32 * 1024;
    let mut spec = ClusterSpec::small(2, 1);
    spec.store = StoreConfig {
        checkpoint_bytes: threshold,
        journal_segments: 4,
        compress_checkpoints: true,
        ..Default::default()
    };
    let cluster = start(spec, "lifecycle");
    let client = cluster.client();
    for wave in 0..20i64 {
        let docs: Vec<Document> =
            (0..200i64).map(|i| metric_doc(wave * 200 + i, i % 8)).collect();
        client.insert_many(docs).unwrap();
    }
    assert_eq!(cluster.stats().docs, 4000);
    let segment = threshold / 4;
    for (i, s) in cluster.shard_stats().iter().enumerate() {
        assert!(s.checkpoint_generation > 0, "shard {i} never compacted");
        assert!(
            s.journal_disk_bytes <= threshold + segment,
            "shard {i} journal {} exceeds the lifecycle bound",
            s.journal_disk_bytes
        );
    }
    assert!(cluster.metrics().counter("shard.checkpoints").get() > 0);
    cluster.shutdown();
}

#[test]
fn buffered_ingest_and_bulk_writer() {
    let cluster = start(ClusterSpec::small(2, 2), "buf");
    let client = cluster.client();

    // Router-side ingest buffer: two client threads pinned to the same
    // router are coalesced into shared flushes (group commit across
    // clients), and every contributor still gets an exact ack.
    let mut handles = Vec::new();
    for pe in 0..2i64 {
        let c = client.pinned(0);
        handles.push(std::thread::spawn(move || {
            let mut inserted = 0usize;
            for wave in 0..4i64 {
                let docs: Vec<Document> = (0..50i64)
                    .map(|i| metric_doc(pe * 1000 + wave * 50 + i, i % 8))
                    .collect();
                inserted += c.insert_buffered(docs).unwrap().inserted;
            }
            inserted
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 400);

    // Client-side BulkWriter: local buffering with size/deadline flush.
    let mut bw = client.bulk_writer(64, std::time::Duration::from_millis(250));
    for i in 0..200i64 {
        bw.push(metric_doc(5000 + i, i % 8)).unwrap();
    }
    assert!(bw.buffered() < 64, "auto-flush must cap the local buffer");
    assert!(bw.flushes() >= 3);
    let rep = bw.finish().unwrap();
    assert_eq!(rep.inserted, 200);

    assert_eq!(client.count_documents(Filter::True).unwrap(), 600);
    assert!(cluster.metrics().counter("router.ingest_flushes").get() > 0);
    assert!(cluster.metrics().counter("shard.group_commits").get() > 0);
    cluster.shutdown();
}

#[test]
fn bulk_writer_drop_flushes_the_tail() {
    // Regression: a BulkWriter dropped part-full used to silently lose
    // its buffered tail — every push below the flush threshold since
    // the last flush vanished unless the caller remembered `finish()`.
    // Drop now flushes best-effort.
    let cluster = start(ClusterSpec::small(2, 1), "bwdrop");
    let client = cluster.client();
    {
        let mut bw = client.bulk_writer(64, std::time::Duration::from_secs(60));
        for i in 0..100i64 {
            bw.push(metric_doc(i, i % 4)).unwrap();
        }
        // 64 flushed by the size trigger, 36 still buffered; the
        // deadline is far away, so only Drop can save them.
        assert_eq!(bw.buffered(), 36);
    }
    assert_eq!(
        client.count_documents(Filter::True).unwrap(),
        100,
        "BulkWriter::drop lost the buffered tail"
    );
    cluster.shutdown();
}

#[test]
fn concurrent_clients_ingest_safely() {
    let cluster = start(ClusterSpec::small(3, 2), "conc");
    let mut handles = Vec::new();
    for pe in 0..4 {
        let client = cluster.client().pinned(pe);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::seeded(pe as u64);
            let mut inserted = 0;
            for wave in 0..5 {
                let docs: Vec<Document> = (0..100)
                    .map(|i| {
                        metric_doc(
                            (pe * 10_000 + wave * 100 + i) as i64,
                            rng.next_bounded(20) as i64,
                        )
                    })
                    .collect();
                inserted += client.insert_many(docs).unwrap().inserted;
            }
            inserted
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 2000);
    assert_eq!(cluster.stats().docs, 2000);
    cluster.shutdown();
}

#[test]
fn queries_stay_exact_under_concurrent_ingest_and_delta_compaction() {
    use hpcstore::config::WorkloadConfig;
    use hpcstore::workload::jobs::generate_jobs;
    use hpcstore::workload::ovis::OvisGenerator;
    use hpcstore::workload::{IngestDriver, QueryDriver};

    // The paper's concurrent ingest+query piece, under the delta
    // lifecycle: tiny compaction threshold + a 2-delta rebase limit so
    // checkpoints, chains, and rebases all fire while queries run.
    let mut spec = ClusterSpec::small(2, 2);
    spec.store = StoreConfig {
        checkpoint_bytes: 16 * 1024,
        journal_segments: 2,
        full_checkpoint_chain: 2,
        compress_checkpoints: true,
        ..Default::default()
    };
    let cluster = start(spec, "mixed");
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    client.create_index(IndexSpec::single("node_id")).unwrap();

    // Phase 1: fully ingest (and ack) the query corpus, so every
    // conditional find below has an exact expected count.
    let wl = WorkloadConfig {
        monitored_nodes: 12,
        metrics_per_doc: 4,
        days: 30.0 / 1440.0, // 30 minutes
        query_jobs: 24,
        ..Default::default()
    };
    let gen = OvisGenerator::new(wl.clone());
    let corpus = gen.total_docs();
    IngestDriver::new(gen, 64, 2).run(&client).unwrap();

    // Phase 2: buffered ingest of a disjoint key range (ts far below
    // every query window) racing the full query workload.
    let writer = {
        let c = cluster.client().pinned(1);
        std::thread::spawn(move || -> usize {
            let mut inserted = 0usize;
            for wave in 0..30i64 {
                let docs: Vec<Document> =
                    (0..100i64).map(|i| metric_doc(wave * 100 + i, i % 8)).collect();
                inserted += c.insert_buffered(docs).unwrap().inserted;
            }
            inserted
        })
    };
    let jobs = generate_jobs(&wl);
    let n_jobs = jobs.len() as u64;
    let report = QueryDriver::new(jobs, 3).run(&client).unwrap();
    let side = writer.join().unwrap();
    assert_eq!(report.queries, n_jobs);
    assert_eq!(
        report.count_mismatches, 0,
        "compaction racing queries must not change any result"
    );
    assert_eq!(side, 3000);
    assert_eq!(client.count_documents(Filter::True).unwrap() as u64, corpus + 3000);
    // The lifecycle really churned underneath the queries: compactions
    // fired, and at least one chain rebased (generation 1 is a rebase,
    // so any auto-checkpoint guarantees the counter moves).
    assert!(cluster.metrics().counter("shard.checkpoints").get() > 0);
    assert!(cluster.metrics().counter("shard.rebases").get() > 0);
    for (i, s) in cluster.shard_stats().iter().enumerate() {
        assert!(s.checkpoint_generation > 0, "shard {i} never compacted");
        assert!(
            s.checkpoint_chain_len <= 2,
            "shard {i} chain {} exceeds the rebase threshold",
            s.checkpoint_chain_len
        );
    }
    cluster.shutdown();
}

#[test]
fn balancer_streams_migrations_without_blocking_donor_ingest() {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;
    // Skewed ranged corpus on shard 0; a probe client keeps inserting
    // into the *migrating* key range while balancer rounds stream the
    // chunks away. The stream must (a) really batch — several
    // MigrateBatch messages per chunk, (b) keep acking the probe's
    // inserts while it runs (the donor's event loop interleaves), and
    // (c) neither lose nor duplicate a single document, including the
    // probe's writes that race the ownership flip (the catch-up phase).
    let mut spec = ClusterSpec::small(2, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 400,
        migration_batch_docs: 64,
        ..Default::default()
    };
    let cluster = start(spec, "migflow");
    let client = cluster.client();
    let corpus = 3_000i64;
    let docs: Vec<Document> = (0..corpus).map(|i| metric_doc(i, 7)).collect();
    for c in docs.chunks(500) {
        client.insert_many(c.to_vec()).unwrap();
    }
    let stats = cluster.stats();
    assert!(stats.chunks > 4, "skewed ingest must have split chunks");

    let stop = Arc::new(AtomicBool::new(false));
    let acked = Arc::new(AtomicU64::new(0));
    let probe = {
        let stop = stop.clone();
        let acked = acked.clone();
        let c = cluster.client();
        std::thread::spawn(move || -> i64 {
            let mut ts = corpus;
            while !stop.load(Ordering::Relaxed) {
                let batch: Vec<Document> =
                    (0..20).map(|i| metric_doc(ts + i, 7)).collect();
                ts += 20;
                c.insert_many(batch).unwrap();
                acked.fetch_add(20, Ordering::Relaxed);
            }
            ts - corpus
        })
    };
    let before_rounds = acked.load(Ordering::Relaxed);
    let mut moved = 0;
    for _ in 0..4 {
        moved += cluster.run_balancer_round().unwrap();
    }
    let during_rounds = acked.load(Ordering::Relaxed) - before_rounds;
    stop.store(true, Ordering::Relaxed);
    let probed = probe.join().unwrap();

    assert!(moved > 0, "skew must trigger migrations");
    assert!(
        cluster.metrics().counter("cluster.migration_batches").get() > moved as u64,
        "chunks must stream in several bounded batches, not one-shot"
    );
    assert!(
        during_rounds > 0,
        "donor must keep acking ingest while its chunks migrate"
    );
    let stats = cluster.stats();
    assert_eq!(
        stats.docs as i64,
        corpus + probed,
        "exactly-once under writes racing the migration"
    );
    assert_eq!(stats.migrations_failed, 0);
    assert!(stats.per_shard_docs.iter().all(|&d| d > 0), "{:?}", stats.per_shard_docs);
    // The storage hand-back (IM4): every commit triggered a source
    // compaction, so the donor's journal really gave bytes back to the
    // shared filesystem — no moved-away data squatting until an
    // unrelated threshold crossing.
    assert!(cluster.metrics().counter("shard.checkpoints").get() > 0);
    assert!(
        cluster.metrics().counter("shard.journal_bytes_truncated").get() > 0,
        "post-commit compaction must reclaim donor journal bytes"
    );
    cluster.shutdown();
}

#[test]
fn queries_stay_sorted_and_counts_exact_across_balancer_rounds() {
    use hpcstore::mongo::query::SortDir;
    // Balancer rounds run *while* buffered ingest and sorted queries
    // race them. Mid-migration scatter reads may transiently disagree
    // about one in-flight chunk (the publish on the destination and the
    // delete on the donor are separate event loops), but the k-way
    // merged output must stay sorted at every probe — and at every
    // round boundary (no migration in flight) the global doc count must
    // be exact: staging is invisible, publish and delete are atomic
    // frames.
    let mut spec = ClusterSpec::small(3, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 200,
        migration_batch_docs: 32,
        ..Default::default()
    };
    let cluster = start(spec, "migsort");
    let client = cluster.client();
    let corpus = 2_400i64;
    for c in (0..corpus).collect::<Vec<i64>>().chunks(400) {
        let docs: Vec<Document> = c.iter().map(|&i| metric_doc(i, 3)).collect();
        client.insert_many(docs).unwrap();
    }

    let mut side_total = 0i64;
    for round in 0..6i64 {
        let writer = {
            let c = cluster.client().pinned(0);
            std::thread::spawn(move || -> i64 {
                let mut inserted = 0i64;
                for wave in 0..4i64 {
                    let base = 1_000_000 + round * 1_000 + wave * 50;
                    let docs: Vec<Document> =
                        (0..50).map(|i| metric_doc(base + i, 3)).collect();
                    inserted += c.insert_buffered(docs).unwrap().inserted as i64;
                }
                inserted
            })
        };
        let prober = {
            let c = cluster.client();
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let ts: Vec<i64> = c
                        .find(
                            Filter::range("ts", 0i64, corpus),
                            FindOptions::default()
                                .sort("ts", SortDir::Asc)
                                .batch_size(128),
                        )
                        .unwrap()
                        .map(|d| d.get_i64("ts").unwrap())
                        .collect();
                    assert!(
                        ts.windows(2).all(|w| w[0] <= w[1]),
                        "merged stream went unsorted during a migration"
                    );
                }
            })
        };
        // The balancer round races the writer and the prober.
        cluster.run_balancer_round().unwrap();
        side_total += writer.join().unwrap();
        prober.join().unwrap();
        // Round boundary: nothing in flight — counts must be exact.
        assert_eq!(
            client.count_documents(Filter::True).unwrap() as i64,
            corpus + side_total,
            "round {round}: migration left a lost or duplicated document"
        );
    }
    let stats = cluster.stats();
    assert!(stats.migrations > 0, "skew must have triggered migrations");
    assert_eq!(stats.migrations_failed, 0);
    // Final content check: the stable corpus reads back exactly, in
    // global order, wherever its chunks ended up.
    let ts: Vec<i64> = client
        .find(
            Filter::range("ts", 0i64, corpus),
            FindOptions::default().sort("ts", SortDir::Asc),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(ts, (0..corpus).collect::<Vec<i64>>());
    cluster.shutdown();
}

#[test]
fn scatter_count_stays_exact_at_every_instant_across_migrations() {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;
    // Headline regression for the orphan-read window (ARCHITECTURE.md
    // §6.3). Before the fix, a scatter Count issued in the instant
    // between a migration's publish on the destination and the delete
    // on the donor could see the moved chunk on both shards — or, with
    // the old delete-before-publish ordering, on neither. The donor
    // fence plus the version-uniform count scatter must make Count
    // exact at *arbitrary* instants, not just at round boundaries,
    // while updates and deletes hammer the same chunks the balancer is
    // streaming.
    let mut spec = ClusterSpec::small(3, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 150,
        migration_batch_docs: 25,
        ..Default::default()
    };
    let cluster = start(spec, "orphan");
    let client = cluster.client();
    let corpus = 1_800i64;
    for c in (0..corpus).collect::<Vec<i64>>().chunks(300) {
        let docs: Vec<Document> = c.iter().map(|&i| metric_doc(i, 3)).collect();
        client.insert_many(docs).unwrap();
    }

    // Prober: hammers Count over the stable ts range for the whole
    // run. Updates inside the range are count-neutral and the churn
    // deletes only touch ts >= 1_000_000, so the expected value is a
    // constant — any deviation, at any instant, is a lost or
    // double-counted chunk.
    let stop = Arc::new(AtomicBool::new(false));
    let probes = Arc::new(AtomicU64::new(0));
    let prober = {
        let stop = stop.clone();
        let probes = probes.clone();
        let c = cluster.client();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let n = c.count_documents(Filter::range("ts", 0i64, corpus)).unwrap();
                assert_eq!(
                    n as i64, corpus,
                    "orphan window: count drifted mid-migration"
                );
                probes.fetch_add(1, Ordering::Relaxed);
            }
        })
    };

    let mut side_ledger = 0i64;
    for round in 0..6i64 {
        // Mutator: updates inside the counted range (MVCC kill+insert
        // churn on the very chunks being streamed) plus insert→delete
        // churn outside it, racing the balancer round.
        let mutator = {
            let c = cluster.client().pinned(1);
            std::thread::spawn(move || -> i64 {
                let mut delta = 0i64;
                for wave in 0..4i64 {
                    let lo = (round * 4 + wave) * 70 % corpus;
                    let rep = c
                        .update_many(
                            Filter::range("ts", lo, lo + 70),
                            Document::new().set("tag", round * 10 + wave),
                        )
                        .unwrap();
                    assert!(rep.modified <= rep.matched);
                    let base = 1_000_000 + round * 1_000 + wave * 100;
                    let docs: Vec<Document> =
                        (0..60).map(|i| metric_doc(base + i, 3)).collect();
                    delta += c.insert_many(docs).unwrap().inserted as i64;
                    let del =
                        c.delete_many(Filter::range("ts", base, base + 30)).unwrap();
                    assert_eq!(del.deleted, 30, "delete must be exactly-once");
                    delta -= del.deleted as i64;
                }
                delta
            })
        };
        cluster.run_balancer_round().unwrap();
        side_ledger += mutator.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    prober.join().unwrap();
    assert!(probes.load(Ordering::Relaxed) > 0, "prober never got a probe in");

    let stats = cluster.stats();
    assert!(stats.migrations > 0, "skew must have triggered migrations");
    assert_eq!(stats.migrations_failed, 0);
    assert_eq!(
        client.count_documents(Filter::True).unwrap() as i64,
        corpus + side_ledger,
        "full-corpus ledger out of balance after migrations + churn"
    );
    cluster.shutdown();
}

#[test]
fn filter_writes_reach_documents_migrated_mid_scatter() {
    // Headline regression for the migration lost-write window
    // (ARCHITECTURE.md §6.3 item 5). The dangerous interleaving: the
    // router's first scatter pass succeeds on the *destination* of an
    // in-flight migration while that shard still holds the moving
    // range invisibly staged, and the donor rejects with
    // MigrationInFlight. Once the chunks publish, a router that only
    // retries not-yet-done shards never re-sends to the destination —
    // the migrated documents silently miss the update or delete while
    // the call reports success. Counts never drift (a lost `$set` is
    // count-neutral), which is exactly why the orphan-count test above
    // cannot see this bug: only a per-document field assertion can.
    let mut spec = ClusterSpec::small(3, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 150,
        migration_batch_docs: 25,
        ..Default::default()
    };
    let cluster = start(spec, "lostwrite");
    let client = cluster.client();
    // Skewed corpus: everything on one ranged chunk chain, so every
    // balancer round streams chunks the writer is stamping.
    let corpus = 1_500i64;
    for c in (0..corpus).collect::<Vec<i64>>().chunks(300) {
        let docs: Vec<Document> = c.iter().map(|&i| metric_doc(i, 3)).collect();
        client.insert_many(docs).unwrap();
    }

    for round in 0..5i64 {
        // Writer: stamps the WHOLE corpus in waves while the balancer
        // streams chunks, so some scatters are guaranteed to straddle
        // an active handoff (donor rejecting, destination holding part
        // of the matches staged).
        let writer = {
            let c = cluster.client().pinned(1);
            std::thread::spawn(move || {
                let mut last = 0i64;
                for wave in 0..3i64 {
                    last = round * 10 + wave;
                    let rep = c
                        .update_many(
                            Filter::range("ts", 0i64, corpus),
                            Document::new().set("stamp", last),
                        )
                        .unwrap();
                    // Exactly-once across both migration ends: every
                    // document matched once and changed once — a
                    // double apply (donor copy + published twin) would
                    // overshoot, a lost re-send would undershoot.
                    assert_eq!(rep.matched as i64, corpus, "wave {last}: matched");
                    assert_eq!(rep.modified as i64, corpus, "wave {last}: modified");
                }
                last
            })
        };
        cluster.run_balancer_round().unwrap();
        let last = writer.join().unwrap();
        // The write completed, the round settled: EVERY document must
        // carry the final wave's stamp. One missing stamp is one
        // document the scatter lost to a mid-write chunk move.
        let stamped = client
            .count_documents(Filter::and(vec![
                Filter::range("ts", 0i64, corpus),
                Filter::eq("stamp", last),
            ]))
            .unwrap();
        assert_eq!(
            stamped as i64, corpus,
            "round {round}: documents missed a racing update_many"
        );
    }

    // Delete leg of the same window: remove a band while one more
    // round runs. Both migration ends refuse in-range matches until
    // the handoff clears, so a donor orphan and its published twin can
    // never both report a delete — the tally must be exact.
    let band = 200i64;
    let deleter = {
        let c = cluster.client().pinned(1);
        std::thread::spawn(move || {
            c.delete_many(Filter::range("ts", 0i64, band)).unwrap().deleted
        })
    };
    cluster.run_balancer_round().unwrap();
    assert_eq!(deleter.join().unwrap() as i64, band, "delete must be exactly-once");
    assert_eq!(
        client.count_documents(Filter::True).unwrap() as i64,
        corpus - band,
        "ledger out of balance after racing delete"
    );

    let stats = cluster.stats();
    assert!(stats.migrations > 0, "skew must have triggered migrations");
    assert_eq!(stats.migrations_failed, 0);
    cluster.shutdown();
}

#[test]
fn compound_plan_makes_candidates_equal_matches_and_bounds_decodes() {
    // The read-path acceptance regression: on a seeded cluster with the
    // (node_id, ts) compound index, the canonical query shape must scan
    // *exactly* its result set (shard.find_candidates ==
    // shard.find_matches) and decode at most one document per returned
    // result (shard.find_decodes).
    let metrics = Registry::new();
    let cluster = Cluster::start(
        ClusterSpec::small(2, 1),
        |sid| Ok(Box::new(LocalDir::temp(&format!("cmpd-{sid}"))?)),
        Kernels::fallback(),
        metrics.clone(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
    let docs: Vec<Document> = (0..400).map(|i| metric_doc(1000 + i, i % 8)).collect();
    assert_eq!(client.insert_many(docs).unwrap().inserted, 400);

    // Canonical shape: ts ∈ [1100, 1300), node_id ∈ {2, 3} → i ∈
    // [100, 300) with i % 8 ∈ {2, 3} → 25 + 25 = 50 documents.
    let f = Filter::and(vec![
        Filter::is_in("node_id", vec![Value::Int(2), Value::Int(3)]),
        Filter::cmp("ts", CmpOp::Gte, 1100i64),
        Filter::cmp("ts", CmpOp::Lt, 1300i64),
    ]);
    let got: Vec<Document> =
        client.find(f.clone(), FindOptions::default().batch_size(16)).unwrap().collect();
    assert_eq!(got.len(), 50);
    assert!(got.iter().all(|d| {
        let n = d.get_i64("node_id").unwrap();
        let ts = d.get_i64("ts").unwrap();
        (n == 2 || n == 3) && (1100..1300).contains(&ts)
    }));

    let candidates = metrics.counter("shard.find_candidates").get();
    let matches = metrics.counter("shard.find_matches").get();
    let decodes = metrics.counter("shard.find_decodes").get();
    assert!(metrics.counter("shard.plan_compound").get() > 0, "compound plan not chosen");
    assert_eq!(candidates, matches, "compound plan must not overscan");
    assert_eq!(matches, 50);
    assert_eq!(decodes, 50, "exactly one decode per returned document");

    // The exact-count path shares the plan and decodes nothing more.
    assert_eq!(client.count_documents(f).unwrap(), 50);
    assert_eq!(metrics.counter("shard.find_decodes").get(), 50);
    cluster.shutdown();
}

#[test]
fn single_index_intersection_still_exact_but_overscans() {
    // Fallback regression: with only the single-field indexes the
    // planner intersects (probing the smaller side); results stay
    // exact, candidates may exceed matches, and the intersection
    // counter proves the path taken.
    let metrics = Registry::new();
    let cluster = Cluster::start(
        ClusterSpec::small(2, 1),
        |sid| Ok(Box::new(LocalDir::temp(&format!("isect-{sid}"))?)),
        Kernels::fallback(),
        metrics.clone(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    client.create_index(IndexSpec::single("node_id")).unwrap();
    let docs: Vec<Document> = (0..400).map(|i| metric_doc(1000 + i, i % 8)).collect();
    client.insert_many(docs).unwrap();
    let f = Filter::and(vec![
        Filter::is_in("node_id", vec![Value::Int(2), Value::Int(3)]),
        Filter::cmp("ts", CmpOp::Gte, 1100i64),
        Filter::cmp("ts", CmpOp::Lt, 1300i64),
    ]);
    let got = client.find(f, FindOptions::default()).unwrap().count();
    assert_eq!(got, 50);
    assert!(metrics.counter("shard.plan_intersect").get() > 0, "intersection not chosen");
    let candidates = metrics.counter("shard.find_candidates").get();
    let matches = metrics.counter("shard.find_matches").get();
    assert_eq!(matches, 50);
    assert!(candidates >= matches);
    cluster.shutdown();
}

#[test]
fn sorted_limit_streams_from_the_index_without_materializing() {
    use hpcstore::mongo::query::SortDir;
    // Index-ordered sorts: a sorted-limit find must stream rids from
    // the ts index (early cutoff) instead of materializing and
    // decoding the whole corpus — visible through shard.find_decodes.
    let metrics = Registry::new();
    let cluster = Cluster::start(
        ClusterSpec::small(2, 1),
        |sid| Ok(Box::new(LocalDir::temp(&format!("isort-{sid}"))?)),
        Kernels::fallback(),
        metrics.clone(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    let n = 600i64;
    // Scrambled insert order; 131 is coprime to 600, so ts values are
    // the full 0..600 set.
    let docs: Vec<Document> = (0..n).map(|i| metric_doc((i * 131) % n, i % 5)).collect();
    client.insert_many(docs).unwrap();

    let got: Vec<i64> = client
        .find(
            Filter::True,
            FindOptions::default().sort("ts", SortDir::Desc).limit(10).batch_size(4),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, (n - 10..n).rev().collect::<Vec<i64>>());
    let decodes = metrics.counter("shard.find_decodes").get();
    assert!(
        decodes <= 20,
        "sorted-limit must decode at most limit docs per shard, got {decodes} for 600 docs"
    );
    assert!(metrics.counter("shard.plan_index_sort").get() > 0, "index sort not chosen");

    // Ascending with a filter range: still index-ordered, still exact.
    let got: Vec<i64> = client
        .find(
            Filter::range("ts", 100i64, 500i64),
            FindOptions::default().sort("ts", SortDir::Asc).limit(5),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, vec![100, 101, 102, 103, 104]);
    cluster.shutdown();
}

#[test]
fn sorted_scatter_gather_is_globally_ordered_across_shards() {
    use hpcstore::mongo::query::SortDir;
    // ≥ 2 shards, documents spread across them (hashed key), inserted in
    // scrambled ts order. The router must k-way merge the per-shard
    // sorted streams: ascending/descending with and without a limit all
    // have to come back in *global* order, not per-shard order.
    let cluster = start(ClusterSpec::small(3, 1), "sort");
    let client = cluster.client();
    let n = 120i64;
    let scrambled: Vec<Document> =
        (0..n).map(|i| metric_doc((i * 77) % n, i % 7)).collect();
    client.insert_many(scrambled).unwrap();
    let stats = cluster.stats();
    assert!(
        stats.per_shard_docs.iter().filter(|&&d| d > 0).count() >= 2,
        "regression needs data on ≥ 2 shards, got {:?}",
        stats.per_shard_docs
    );

    // Ascending with a limit: exactly the n smallest, in order. A small
    // batch size forces the merge to span several GetMore rounds.
    let got: Vec<i64> = client
        .find(
            Filter::True,
            FindOptions::default().sort("ts", SortDir::Asc).limit(20).batch_size(6),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, (0..20).collect::<Vec<i64>>());

    // Descending with a limit: exactly the n largest, in order.
    let got: Vec<i64> = client
        .find(
            Filter::True,
            FindOptions::default().sort("ts", SortDir::Desc).limit(15).batch_size(4),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, (n - 15..n).rev().collect::<Vec<i64>>());

    // Full unlimited sort: the entire corpus, globally ascending.
    let got: Vec<i64> = client
        .find(
            Filter::True,
            FindOptions::default().sort("ts", SortDir::Asc).batch_size(17),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, (0..n).collect::<Vec<i64>>());

    // Sort composes with a filter: the merge sees only matching docs.
    let got: Vec<i64> = client
        .find(
            Filter::range("ts", 40i64, 80i64),
            FindOptions::default().sort("ts", SortDir::Desc),
        )
        .unwrap()
        .map(|d| d.get_i64("ts").unwrap())
        .collect();
    assert_eq!(got, (40..80).rev().collect::<Vec<i64>>());
    cluster.shutdown();
}

#[test]
fn reader_pool_serves_exact_results_under_concurrent_ingest() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use hpcstore::metrics::names;

    // The per-shard MVCC reader pool (--reader-threads): finds and
    // counts run on pool workers against pinned snapshots while the
    // writer keeps committing on the event loop. Every query must see
    // one frozen epoch — exact counts against a closed-form corpus —
    // and the snapshot-read counter proves the path taken.
    let mut spec = ClusterSpec::small(2, 2);
    spec.store = StoreConfig { reader_threads: 2, ..Default::default() };
    let metrics = Registry::new();
    let cluster = Cluster::start(
        spec,
        |sid| Ok(Box::new(LocalDir::temp(&format!("rpool-{sid}"))?)),
        Kernels::fallback(),
        metrics.clone(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
    // Stable corpus: ts 0..1000, node_id = ts % 10, so any (node set,
    // ts range) result size is computable in closed form.
    let docs: Vec<Document> = (0..1000).map(|i| metric_doc(i, i % 10)).collect();
    client.insert_many(docs).unwrap();

    // Background writer on a disjoint ts range (>= 1_000_000): commits
    // churn epochs under the readers without touching their windows.
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let c = cluster.client().pinned(1);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || -> usize {
            let mut inserted = 0usize;
            let mut ts = 1_000_000i64;
            while !stop.load(Ordering::Relaxed) {
                let docs: Vec<Document> =
                    (0..50).map(|i| metric_doc(ts + i, (ts + i) % 10)).collect();
                ts += 50;
                inserted += c.insert_many(docs).unwrap().inserted;
            }
            inserted
        })
    };
    let mut readers = Vec::new();
    for t in 0..4u64 {
        let c = cluster.client().pinned(t as usize);
        readers.push(std::thread::spawn(move || {
            let mut rng = Pcg32::seeded(0xC0FFEE + t);
            for _ in 0..25 {
                let lo = rng.next_bounded(900) as i64;
                let hi = lo + 1 + rng.next_bounded(100) as i64;
                let n0 = rng.next_bounded(10) as i64;
                let n1 = (n0 + 1) % 10;
                let f = Filter::and(vec![
                    Filter::is_in("node_id", vec![Value::Int(n0), Value::Int(n1)]),
                    Filter::cmp("ts", CmpOp::Gte, lo),
                    Filter::cmp("ts", CmpOp::Lt, hi),
                ]);
                let expected =
                    (lo..hi).filter(|ts| ts % 10 == n0 || ts % 10 == n1).count();
                let got = c
                    .find(f.clone(), FindOptions::default().batch_size(16))
                    .unwrap()
                    .count();
                assert_eq!(got, expected, "find [{lo},{hi}) nodes {{{n0},{n1}}}");
                assert_eq!(c.count_documents(f).unwrap(), expected);
            }
        }));
    }
    for r in readers {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    assert!(writer.join().unwrap() > 0);
    assert_eq!(client.count_documents(Filter::range("ts", 0i64, 1000i64)).unwrap(), 1000);
    assert!(
        metrics.counter(names::SHARD_SNAPSHOT_READS).get() > 0,
        "pool reads must be served from pinned snapshots"
    );
    cluster.shutdown();
}

#[test]
fn cursor_across_migration_commit_drains_the_pinned_snapshot_exactly_once() {
    use std::collections::HashMap;

    // Snapshot/migration interplay (ARCHITECTURE.md §9): a cursor
    // opened *before* a chunk migration commits must drain its pinned
    // world exactly once. The donor's moved range is dead-marked, not
    // physically dropped, while the pin holds; the recipient's copy was
    // born after every shard stream pinned — so each moved document
    // appears exactly once, never twice and never zero times.
    let mut spec = ClusterSpec::small(2, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        max_chunk_docs: 200,
        migration_batch_docs: 32,
        reader_threads: 1,
        ..Default::default()
    };
    let cluster = start(spec, "migcur");
    let client = cluster.client();
    let corpus = 2_000i64;
    for c in (0..corpus).collect::<Vec<i64>>().chunks(400) {
        let docs: Vec<Document> = c.iter().map(|&i| metric_doc(i, 7)).collect();
        client.insert_many(docs).unwrap();
    }
    assert!(cluster.stats().chunks > 4, "skewed ingest must have split chunks");

    // Open the cursor and pull a prefix: every shard stream pins its
    // snapshot here, before any chunk moves.
    let mut cur =
        client.find(Filter::True, FindOptions::default().batch_size(64)).unwrap();
    let mut seen: Vec<i64> = Vec::with_capacity(corpus as usize);
    for _ in 0..100 {
        seen.push(cur.next().expect("corpus prefix").get_i64("ts").unwrap());
    }

    // Chunks migrate while the cursor is parked mid-drain.
    let mut moved = 0;
    for _ in 0..3 {
        moved += cluster.run_balancer_round().unwrap();
    }
    assert!(moved > 0, "skew must trigger migrations");

    // Drain the rest of the pinned pre-migration world.
    seen.extend(cur.by_ref().map(|d| d.get_i64("ts").unwrap()));
    assert!(
        cur.error().is_none(),
        "retention 0 must never expire a cursor: {:?}",
        cur.error()
    );
    let mut counts: HashMap<i64, usize> = HashMap::new();
    for ts in &seen {
        *counts.entry(*ts).or_default() += 1;
    }
    for ts in 0..corpus {
        assert_eq!(
            counts.get(&ts).copied().unwrap_or(0),
            1,
            "ts {ts}: the moved range must be seen exactly once"
        );
    }
    assert_eq!(seen.len() as i64, corpus);

    // The post-migration world reads back the same set on a fresh find.
    assert_eq!(client.count_documents(Filter::True).unwrap() as i64, corpus);
    let stats = cluster.stats();
    assert_eq!(stats.migrations_failed, 0);
    assert!(stats.per_shard_docs.iter().all(|&d| d > 0), "{:?}", stats.per_shard_docs);
    cluster.shutdown();
}

#[test]
fn cursor_past_retention_fails_retryably_and_a_fresh_find_succeeds() {
    use hpcstore::mongo::wire::WireError;

    // The IS2 bound end-to-end: with --snapshot-retention set, a cursor
    // parked while the writer commits past the window dies with the
    // clean, retryable SnapshotExpired — never a silent short or wrong
    // result — and reissuing the find (fresh pin at the current epoch)
    // succeeds.
    let mut spec = ClusterSpec::small(2, 1);
    spec.store =
        StoreConfig { reader_threads: 1, snapshot_retention: 4, ..Default::default() };
    let cluster = start(spec, "retexp");
    let client = cluster.client();
    client
        .insert_many((0..600).map(|i| metric_doc(i, i % 6)).collect())
        .unwrap();

    // Park a cursor after exactly its first batch.
    let mut cur = client
        .find(Filter::range("ts", 0i64, 600i64), FindOptions::default().batch_size(32))
        .unwrap();
    for _ in 0..32 {
        cur.next().expect("first batch");
    }

    // 40 separate commits: each group commit bumps the epoch and runs
    // reclamation, so the parked pin falls past retention = 4 on every
    // shard the writer touches.
    for wave in 0..40i64 {
        client
            .insert_many(
                (0..10).map(|i| metric_doc(1_000_000 + wave * 10 + i, 0)).collect(),
            )
            .unwrap();
    }

    // Whatever was already buffered router-side may still arrive; the
    // first shard GetMore against the expired pin must end the cursor
    // with the retryable error, not a quiet truncation.
    let tail = cur.by_ref().count();
    assert!(tail < 600 - 32, "expired cursor cannot have drained the corpus");
    let err = cur
        .error()
        .cloned()
        .expect("parked cursor must fail loudly, not truncate silently");
    match err {
        WireError::SnapshotExpired { at, floor } => {
            assert!(at < floor, "expiry means the floor passed the pin: {at} vs {floor}")
        }
        other => panic!("expected SnapshotExpired, got {other:?}"),
    }

    // The documented recovery: retry with a fresh find.
    let again = client
        .find(Filter::range("ts", 0i64, 600i64), FindOptions::default().batch_size(32))
        .unwrap()
        .count();
    assert_eq!(again, 600);
    cluster.shutdown();
}

#[test]
fn replica_set_fails_over_and_acked_writes_read_back_exactly_once() {
    use std::time::{Duration, Instant};

    use hpcstore::config::WriteConcern;
    use hpcstore::mongo::wire::{rpc, ShardRequest};

    // End-to-end failover drill (ARCHITECTURE.md §10): a 3-member
    // replica set loses its primary mid-run; the router rides the
    // election on its retry loop, a secondary wins, and every
    // w:majority-acknowledged write reads back exactly once.
    let mut spec = ClusterSpec::small(1, 1);
    spec.store.replicas = 3;
    spec.store.write_concern = WriteConcern::Majority;
    spec.store.election_timeout_ms = 100;
    spec.store.heartbeat_ms = 20;
    spec.store.write_retry_ms = 10_000;
    let cluster = start(spec, "failover");
    let client = cluster.client();

    let find_primary = |deadline: Duration| -> usize {
        let t = Instant::now();
        loop {
            for (m, tx) in cluster.member_mailboxes(0).iter().enumerate() {
                if let Ok(info) = rpc(tx, |reply| ShardRequest::RoleInfo { reply }) {
                    if info.role == "primary" {
                        return m;
                    }
                }
            }
            assert!(t.elapsed() < deadline, "no member became primary");
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    let docs: Vec<Document> = (0..200).map(|i| metric_doc(i, i % 8)).collect();
    assert_eq!(client.insert_many(docs).unwrap().inserted, 200);

    let old = find_primary(Duration::from_secs(5));
    cluster.kill_member(0, old);

    // The very next insertMany rides through the election: the router
    // bounces off the dead mailbox and NotPrimary rejects with jittered
    // backoff until a surviving secondary wins and starts acking.
    let docs: Vec<Document> = (200..400).map(|i| metric_doc(i, i % 8)).collect();
    assert_eq!(client.insert_many(docs).unwrap().inserted, 200);

    let new = find_primary(Duration::from_secs(5));
    assert_ne!(new, old, "the killed member cannot serve as primary");
    assert!(
        cluster.metrics().counter("shard.elections").get() > 0,
        "the kill must have forced a real election"
    );

    // Exactly-once readback: every acked document, no double-applies.
    let mut counts = std::collections::HashMap::new();
    let mut cur = client.find(Filter::True, FindOptions::default()).unwrap();
    for d in cur.by_ref() {
        *counts.entry(d.get_i64("ts").unwrap()).or_insert(0u32) += 1;
    }
    assert!(cur.error().is_none(), "readback died: {:?}", cur.error());
    for ts in 0..400i64 {
        assert_eq!(
            counts.get(&ts).copied().unwrap_or(0),
            1,
            "acked ts {ts} must survive failover exactly once"
        );
    }
    assert_eq!(counts.len(), 400);
    cluster.shutdown();
}

#[test]
fn dead_secondaries_degrade_reads_to_surviving_members_without_hanging() {
    use std::time::{Duration, Instant};

    use hpcstore::config::{ReadPreference, WriteConcern};
    use hpcstore::mongo::wire::{rpc, ShardRequest};

    // Availability regression: with the read preference aimed at
    // secondaries and every secondary dead, reads must degrade to the
    // surviving primary — exact results, a counted degrade, no hang —
    // and w:1 writes keep acking from the primary alone.
    let mut spec = ClusterSpec::small(1, 1);
    spec.store.replicas = 3;
    spec.store.write_concern = WriteConcern::One;
    spec.store.read_preference = ReadPreference::Secondary;
    // Frozen election clock: the surviving primary must not flap.
    spec.store.election_timeout_ms = 60_000;
    spec.store.heartbeat_ms = 20;
    let cluster = start(spec, "degrade");
    let client = cluster.client();
    client
        .insert_many((0..300).map(|i| metric_doc(i, i % 8)).collect())
        .unwrap();

    let primary = (0..3)
        .find(|&m| {
            rpc(&cluster.member_mailboxes(0)[m], |reply| ShardRequest::RoleInfo { reply })
                .map(|info| info.role == "primary")
                .unwrap_or(false)
        })
        .expect("bootstrap primary");
    for m in 0..3 {
        if m != primary {
            cluster.kill_member(0, m);
        }
    }

    let t = Instant::now();
    assert_eq!(client.count_documents(Filter::True).unwrap(), 300);
    let got = client
        .find(Filter::range("ts", 0i64, 300i64), FindOptions::default())
        .unwrap()
        .count();
    assert_eq!(got, 300, "degraded reads must stay exact");
    assert!(
        t.elapsed() < Duration::from_secs(10),
        "degraded reads must not stall on the dead members"
    );
    assert!(
        cluster.metrics().counter("router.shard_unavailable").get() > 0,
        "the degrade away from the dead secondary must be counted"
    );

    // w:1 needs no quorum: the lone primary still acks writes.
    let rep = client
        .insert_many((300..360).map(|i| metric_doc(i, i % 8)).collect())
        .unwrap();
    assert_eq!(rep.inserted, 60);
    assert_eq!(client.count_documents(Filter::True).unwrap(), 360);
    cluster.shutdown();
}

#[test]
fn fully_dead_shard_surfaces_typed_errors_and_retryable_cursors_never_hangs() {
    use std::time::{Duration, Instant};

    use hpcstore::mongo::wire::WireError;

    // The no-hang contract: once every member of a shard is gone, every
    // request that needs it must return the typed ShardUnavailable —
    // a parked cursor dies distinguishable-and-read-retryable, fresh
    // reads and writes fail fast — and none of them block forever.
    let mut spec = ClusterSpec::small(2, 1);
    spec.store.write_retry_ms = 300; // bound the router retry loops
    let cluster = start(spec, "deadshard");
    let client = cluster.client();
    client
        .insert_many((0..400).map(|i| metric_doc(i, i % 8)).collect())
        .unwrap();
    let stats = cluster.stats();
    assert!(stats.per_shard_docs.iter().all(|&d| d > 0), "{:?}", stats.per_shard_docs);

    // Park a cursor mid-drain (small batches keep shard-side cursors
    // open on both shards), then kill shard 0's only member.
    let mut cur = client
        .find(Filter::True, FindOptions::default().batch_size(16))
        .unwrap();
    for _ in 0..16 {
        cur.next().expect("first batch");
    }
    cluster.kill_member(0, 0);

    let t = Instant::now();
    let _ = cur.by_ref().count();
    let err = cur
        .error()
        .cloned()
        .expect("a cursor over a dead shard must fail loudly, not truncate");
    assert!(
        matches!(err, WireError::ShardUnavailable { shard: 0 }),
        "expected ShardUnavailable, got {err:?}"
    );
    assert!(cur.retryable(), "a re-read of a dead shard is cleanly retryable");

    match client.count_documents(Filter::True) {
        Err(WireError::ShardUnavailable { shard: 0 }) => {}
        other => panic!("count on a dead shard must fail typed, got {other:?}"),
    }
    match client.insert_many((400..500).map(|i| metric_doc(i, i % 8)).collect()) {
        Err(WireError::ShardUnavailable { shard: 0 }) => {}
        other => panic!("insert on a dead shard must fail typed, got {other:?}"),
    }
    match client.find(Filter::True, FindOptions::default()) {
        Err(WireError::ShardUnavailable { shard: 0 }) => {}
        Ok(_) => panic!("find on a dead shard must not open a cursor"),
        Err(other) => panic!("find on a dead shard must fail typed, got {other:?}"),
    }
    assert!(
        t.elapsed() < Duration::from_secs(10),
        "dead-shard requests must fail fast, never hang"
    );
    assert!(cluster.metrics().counter("router.shard_unavailable").get() > 0);
    cluster.shutdown();
}

#[test]
fn aggregation_pushdown_ships_groups_not_documents() {
    use hpcstore::metrics::names;
    use hpcstore::mongo::aggregate::AggPipeline;
    use hpcstore::mongo::query::SortDir;

    // The push-down reply-size law, counter-asserted end to end: with
    // --agg-partial the shards reply with one accumulator row per live
    // group and decode nothing; with the full-ship baseline every
    // matched document crosses the wire and is decoded for the
    // router's central fold. Both modes must agree bit-for-bit with
    // the in-process reference executor — including `avg`, whose
    // sum/count parts only finalize at the router merge.
    let corpus: Vec<Document> = (0..900).map(|i| metric_doc(i, i % 6)).collect();
    let matched = 600u64; // ts in [100, 700)
    let groups = 6u64;
    let shards = 2u64;
    let pipeline = AggPipeline::new()
        .matching(Filter::range("ts", 100i64, 700i64))
        .group_by("node_id")
        .count("n")
        .sum("cpu", "cpu_user")
        .avg("mem", "mem_used")
        .min("first_ts", "ts")
        .max("last_ts", "ts")
        .sort("n", SortDir::Desc)
        .limit(8);
    let expected = pipeline.execute_docs(&corpus);
    assert_eq!(expected.len(), groups as usize);

    for partial in [true, false] {
        let mut spec = ClusterSpec::small(shards as u32, 1);
        spec.store = StoreConfig { agg_partial: partial, ..Default::default() };
        let cluster = start(spec, if partial { "aggp" } else { "aggf" });
        let client = cluster.client();
        client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
        client.insert_many(corpus.clone()).unwrap();

        let decodes_before =
            cluster.metrics().counter(names::SHARD_FIND_DECODES).get();
        let got = client.aggregate(pipeline.clone()).unwrap();
        assert_eq!(
            got, expected,
            "partial={partial}: distributed aggregate diverged from the \
             reference executor"
        );

        let m = cluster.metrics();
        let rows = m.counter(names::ROUTER_AGG_PARTIAL_ROWS).get();
        let shipped = m.counter(names::ROUTER_AGG_DOCS_SHIPPED).get();
        let decodes = m.counter(names::SHARD_FIND_DECODES).get() - decodes_before;
        assert_eq!(m.counter(names::SHARD_AGG_DOCS).get(), matched);
        if partial {
            assert!(rows > 0 && rows <= groups * shards, "rows = {rows}");
            assert_eq!(shipped, 0, "push-down must ship no documents");
            assert_eq!(decodes, 0, "the raw fold must leave find_decodes flat");
            // sum/avg in the pipeline force the scalar fold.
            assert_eq!(m.counter(names::SHARD_AGG_SCALAR_PATH).get(), shards);
            assert_eq!(m.counter(names::SHARD_AGG_KERNEL_PATH).get(), 0);
        } else {
            assert_eq!(rows, 0);
            assert_eq!(shipped, matched, "full ship moves every match");
            assert_eq!(decodes, matched, "full ship decodes every match");
        }
        cluster.shutdown();
    }
}
