//! M1 — storage/codec/index microbenchmarks: the primitive costs that
//! feed the DES cost model (compare with `artifacts/costmodel.json`).

use hpcstore::benchkit::{Bench, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::{Engine, LocalDir};
use hpcstore::workload::ovis::OvisGenerator;

fn main() {
    let bench = Bench::default();
    let mut report = Report::new("M1 — storage engine / codec / index microbenchmarks");

    let gen = OvisGenerator::new(WorkloadConfig {
        monitored_nodes: 512,
        ..Default::default()
    });
    let docs: Vec<Document> = (0..4096u64).map(|i| gen.doc_at(i)).collect();
    let encoded: Vec<Vec<u8>> = docs.iter().map(Document::encode).collect();

    // Codec.
    report.push(bench.run("bson encode (75 metrics)", 1.0, {
        let d = docs[0].clone();
        move || {
            std::hint::black_box(d.encode());
        }
    }));
    report.push(bench.run("bson decode (75 metrics)", 1.0, {
        let bytes = encoded[0].clone();
        move || {
            std::hint::black_box(Document::decode(&bytes).unwrap());
        }
    }));
    report.push(bench.run("ovis doc synthesis", 1.0, {
        let gen = gen.clone();
        let mut i = 0u64;
        move || {
            i += 1;
            std::hint::black_box(gen.doc_at(i % 4096));
        }
    }));

    // Engine insert paths (fresh engine per case to keep state bounded).
    for (label, journal, indexes) in [
        ("engine insert (no journal, no index)", false, false),
        ("engine insert (journal)", true, false),
        ("engine insert (journal + 2 indexes)", true, true),
    ] {
        let dir = LocalDir::temp("m1").unwrap();
        let mut eng = Engine::open(Box::new(dir), journal, false).unwrap();
        eng.create_collection("m");
        if indexes {
            eng.create_index("m", IndexSpec::single("ts")).unwrap();
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
        }
        let docs = docs.clone();
        let mut i = 0usize;
        report.push(bench.run(label, 1.0, move || {
            eng.insert("m", &docs[i % docs.len()]).unwrap();
            i += 1;
            if i % 1000 == 0 {
                eng.sync().unwrap();
            }
        }));
    }

    // Index operations on a populated index.
    {
        let dir = LocalDir::temp("m1-idx").unwrap();
        let mut eng = Engine::open(Box::new(dir), false, false).unwrap();
        eng.create_collection("m");
        eng.create_index("m", IndexSpec::single("ts")).unwrap();
        for d in &docs {
            eng.insert("m", d).unwrap();
        }
        let start = gen.config().start_epoch_min as i64;
        let mut i = 0i64;
        let eng_ref = &eng;
        report.push(bench.run("index range scan (~512 rids)", 512.0, move || {
            let lo = Value::Int(start + (i % 4));
            let hi = Value::Int(start + (i % 4) + 1);
            i += 1;
            let idx = eng_ref.index("m", "ts_1").unwrap();
            std::hint::black_box(idx.range_superset(Some(&lo), Some(&hi)).count());
        }));
        let mut j = 0u64;
        report.push(bench.run("record fetch+decode", 1.0, move || {
            std::hint::black_box(eng.fetch("m", j % 4096));
            j += 1;
        }));
    }

    report.print();
}
