//! FC — mixed CRUD workloads: full write path under read/insert/
//! update/delete mixes, live and at DES scale.
//!
//! The tentpole under test is the full CRUD write path: `updateMany`
//! and `deleteMany` as first-class wire ops — shard-key-targeted
//! scatter on the router, batch-atomic MVCC mutations on the shards,
//! one journal frame per batch (`OP_UPDATE_MANY`/`OP_DELETE_MANY`).
//! The live table runs the three named mix profiles
//! (`workload::mixed`) over a two-shard cluster with zipfian node
//! popularity and checks the document-count ledger (inserted −
//! deleted) at the end of each run. The DES table charges the same
//! mixes at paper scale with the calibrated `update_doc_ns` /
//! `delete_doc_ns` terms.
//!
//! Run: `cargo bench --bench fig_crud` (add `--quick` for a small
//! sweep). See `docs/EXPERIMENTS.md` for the recorded-results template.

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::Filter;
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::{human_count, human_duration_ns};
use hpcstore::workload::{MixProfile, MixedDriver};

fn main() {
    let ops: u64 = if quick_mode() { 240 } else { 2_000 };

    let mut report =
        Report::new("CRUD mix — live 2-shard cluster, zipfian node popularity");
    report.set_custom(
        [
            "profile",
            "ops/s",
            "reads",
            "inserts",
            "updates",
            "deletes",
            "docs +ins/~mod/-del",
            "op p50",
            "op p95",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for profile in MixProfile::ALL {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            |sid| Ok(Box::new(LocalDir::temp(&format!("figcrud-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
        client.create_index(IndexSpec::single("ts")).unwrap();

        let cfg = WorkloadConfig {
            monitored_nodes: 64,
            metrics_per_doc: 8,
            ..Default::default()
        };
        let driver = MixedDriver::new(cfg, profile, ops, 4);
        let r = driver.run(&client).unwrap();

        // Ledger check: inserts add, deletes remove, updates are
        // count-neutral — the cluster must agree exactly.
        let count = client.count_documents(Filter::True).unwrap() as u64;
        assert_eq!(
            count,
            r.docs_inserted - r.docs_deleted,
            "{}: count ledger out of balance",
            profile.name()
        );
        assert!(r.docs_modified <= r.docs_matched);

        report.add_row(vec![
            r.profile.to_string(),
            format!("{:.0}", r.ops_per_sec()),
            r.reads.to_string(),
            r.inserts.to_string(),
            r.updates.to_string(),
            r.deletes.to_string(),
            format!(
                "+{}/~{}/-{}",
                human_count(r.docs_inserted),
                human_count(r.docs_modified),
                human_count(r.docs_deleted)
            ),
            human_duration_ns(r.latency.p50()),
            human_duration_ns(r.latency.p95()),
        ]);
        cluster.shutdown();
    }
    report.print();
    println!(
        "\nclaim: update/delete scatters ride the same shard-targeted write path as \
         inserts — the document-count ledger stays exact under every mix, and \
         mutation latency stays in the insert band (one journal frame per batch)\n"
    );

    // --- DES axis: the same mixes at paper scale. ---------------------
    let cost = CostModel::default().with_network_floor();
    let mixes: &[(&str, u32, u32)] = &[
        ("ingest-only", 0, 0),
        ("update-heavy", 30, 5),
        ("delete-heavy", 5, 30),
        ("churn (15/15)", 15, 15),
    ];
    let mut report = Report::new("CRUD mix — DES axis (32-node preset)");
    report.set_custom(
        ["mix (upd/del per 100)", "updates", "deletes", "ingest virt s", "docs/s"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &(label, upd, del) in mixes {
        let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
        spec.monitored_nodes = 256;
        spec.max_chunk_docs = 16_000;
        spec.updates_per_100_batches = upd;
        spec.deletes_per_100_batches = del;
        let r = ClusterSim::new(spec).run();
        report.add_row(vec![
            label.to_string(),
            r.updates.to_string(),
            r.deletes.to_string(),
            format!("{:.1}", r.ingest_virt_ns as f64 / 1e9),
            human_count(r.docs_per_sec as u64),
        ]);
    }
    report.print();
    println!(
        "\nclaim: with the calibrated per-document terms, update-heavy mixes cost \
         more ingest headroom than delete-heavy ones (full replacement bytes vs \
         rid-only journal frames)\n"
    );
}
