//! F3 — regenerate Figure 3: concurrent conditional-find latency vs
//! cluster size, plus the read-path axis.
//!
//! Paper: "cluster size maintains a similar query performance for
//! various MongoDB cluster sizes ... each cluster size is servicing
//! more concurrent quarries" (32 nodes → up to 64 concurrent finds,
//! 64 → up to 128, and so on). The DES scales concurrency with client
//! PEs and the latency distribution should stay roughly flat.
//!
//! The second DES table sweeps the **read-path regimes** at one cluster
//! size: the pre-overhaul single-index plan with a decode per candidate,
//! the raw (zero-copy) matcher over the same plan, and the compound
//! `(node_id, ts)` plan where candidates == matches. The live
//! cross-check runs the same sweep on a real mini-cluster and reads the
//! planner/decode counters (`shard.find_candidates` vs
//! `shard.find_matches`, `shard.find_decodes`) so the candidate ratio
//! and decode-per-result are visible, not inferred.

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_duration_ns;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::{IngestDriver, QueryDriver};

fn main() {
    let cost = CostModel::load_or_default(std::path::Path::new("artifacts")).with_network_floor();
    let mut report = Report::new("Figure 3 — concurrent conditional-find latency (DES)");
    report.set_custom(
        ["nodes", "concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for nodes in [32u32, 64, 128, 256] {
        let spec = SimSpec::paper_preset(nodes, cost.clone()).unwrap();
        let r = ClusterSim::new(spec).run();
        report.add_row(r.query_row());
    }
    report.print();
    println!("\npaper: similar latency across cluster sizes despite proportional concurrency — shape reproduced\n");

    // Read-path axis (DES, 64 nodes): what the compound plan and the
    // raw matcher each buy on the canonical shape.
    let mut axis = Report::new("Figure 3b — read-path axis (DES, 64 nodes)");
    axis.set_custom(
        ["plan", "finds/s", "p50", "p95", "p99"].iter().map(|s| s.to_string()).collect(),
    );
    for (label, compound, raw) in [
        ("single-index + decode per candidate (pre-overhaul)", false, false),
        ("single-index + raw matcher", false, true),
        ("compound (node_id, ts) + raw (current)", true, true),
    ] {
        let mut spec = SimSpec::paper_preset(64, cost.clone()).unwrap();
        spec.compound_index = compound;
        spec.raw_match = raw;
        let r = ClusterSim::new(spec).run();
        axis.add_row(vec![
            label.to_string(),
            format!("{:.1}", r.queries_per_sec),
            human_duration_ns(r.query_latency.p50()),
            human_duration_ns(r.query_latency.p95()),
            human_duration_ns(r.query_latency.p99()),
        ]);
    }
    axis.print();

    if quick_mode() {
        return;
    }
    // Live cross-check 1: one cluster, concurrency sweep.
    let kernels = Kernels::load_or_fallback("artifacts");
    let cluster = Cluster::start(
        ClusterSpec::small(3, 2),
        |sid| Ok(Box::new(LocalDir::temp(&format!("f3-{sid}"))?)),
        kernels,
        Registry::new(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
    let wl = WorkloadConfig {
        monitored_nodes: 128,
        metrics_per_doc: 20,
        days: 30.0 / 1440.0,
        query_jobs: 32,
        ..Default::default()
    };
    IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 4)
        .run(&client)
        .unwrap();
    let mut live = Report::new("Figure 3 cross-check — live cluster, concurrency sweep");
    live.set_custom(
        ["concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for conc in [1usize, 2, 4, 8] {
        let rep = QueryDriver::new(generate_jobs(&wl), conc).run(&client).unwrap();
        assert_eq!(rep.count_mismatches, 0);
        live.add_row(vec![
            conc.to_string(),
            rep.queries.to_string(),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            human_duration_ns(rep.latency.p95()),
            human_duration_ns(rep.latency.p99()),
        ]);
    }
    live.print();
    cluster.shutdown();

    // Live cross-check 2: index-plan sweep on identical corpora. The
    // candidate ratio and decode count come from the shard counters —
    // compound must show candidates == matches and one decode per
    // returned document.
    let mut plans = Report::new("Figure 3c — live read-path sweep (plan vs overscan vs decodes)");
    plans.set_custom(
        ["indexes", "finds/s", "p50", "cand/match", "decodes/doc"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let cases: Vec<(&str, Vec<IndexSpec>)> = vec![
        (
            "ts + node_id singles (intersection)",
            vec![IndexSpec::single("ts"), IndexSpec::single("node_id")],
        ),
        ("compound (node_id, ts)", vec![IndexSpec::compound(&["node_id", "ts"])]),
    ];
    for (label, specs) in cases {
        let metrics = Registry::new();
        let cluster = Cluster::start(
            ClusterSpec::small(2, 1),
            |sid| Ok(Box::new(LocalDir::temp(&format!("f3c-{sid}"))?)),
            Kernels::fallback(),
            metrics.clone(),
        )
        .unwrap();
        let client = cluster.client();
        for spec in &specs {
            client.create_index(spec.clone()).unwrap();
        }
        let wl = WorkloadConfig {
            monitored_nodes: 128,
            metrics_per_doc: 20,
            days: 20.0 / 1440.0,
            query_jobs: 16,
            ..Default::default()
        };
        IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 2)
            .run(&client)
            .unwrap();
        let before_cand = metrics.counter("shard.find_candidates").get();
        let before_match = metrics.counter("shard.find_matches").get();
        let before_dec = metrics.counter("shard.find_decodes").get();
        let rep = QueryDriver::new(generate_jobs(&wl), 4).run(&client).unwrap();
        assert_eq!(rep.count_mismatches, 0);
        let cand = metrics.counter("shard.find_candidates").get() - before_cand;
        let matched = metrics.counter("shard.find_matches").get() - before_match;
        let decodes = metrics.counter("shard.find_decodes").get() - before_dec;
        plans.add_row(vec![
            label.to_string(),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            format!("{:.3}", cand as f64 / matched.max(1) as f64),
            format!("{:.3}", decodes as f64 / rep.docs_returned.max(1) as f64),
        ]);
        cluster.shutdown();
    }
    plans.print();
}
