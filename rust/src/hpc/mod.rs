//! The shared-HPC substrate the paper runs on:
//!
//! * [`scheduler`] — a Moab/Torque-like batch scheduler (queue, node
//!   pool, FCFS + EASY backfill, walltime enforcement).
//! * [`lustre`] — a Lustre-like striped parallel filesystem (MDS
//!   namespace, OST objects, stripe layouts, bandwidth accounting) whose
//!   live mode backs bytes on a real local directory.
//! * [`gemini`] — a Cray Gemini-like 3D-torus interconnect cost model.
//! * [`runscript`] — the paper's contribution: the run-script execution
//!   model that assigns cluster roles to the processing elements of a
//!   queued job, publishes the router host list, and persists the store
//!   across job boundaries.

pub mod gemini;
pub mod lustre;
pub mod runscript;
pub mod scheduler;

pub use lustre::Lustre;
pub use runscript::{DeployedCluster, RoleMap, RunScript};
pub use scheduler::{Job, JobState, Scheduler};
