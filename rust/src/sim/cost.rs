//! DES cost model, calibrated from the live implementation.
//!
//! Every service time the cluster simulation charges comes from here.
//! [`CostModel::calibrate`] measures the real storage engine, route
//! kernel, index scans, document codec, and chunk-map operations on this
//! machine and writes `artifacts/costmodel.json`; [`CostModel::default`]
//! carries the values measured on the reference box so the sim runs
//! without calibration.

use std::time::Instant;

use anyhow::Result;

use crate::json::{self, Value};
use crate::runtime::Kernels;

/// Nanosecond costs of the primitive operations (per unit noted).
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Synthesize one OVIS document (client CPU).
    pub gen_doc_ns: f64,
    /// Encoded document size (bytes).
    pub doc_bytes: f64,
    /// Route-kernel invocation overhead per batch (router CPU).
    pub route_batch_fixed_ns: f64,
    /// Routing cost per document (router CPU).
    pub route_doc_ns: f64,
    /// Sub-batch assembly / dispatch per document (router CPU).
    pub dispatch_doc_ns: f64,
    /// Storage-engine insert incl. journal append + 2 index updates
    /// (shard CPU), per document.
    pub insert_doc_ns: f64,
    /// Storage-engine in-place update per document: kill the old
    /// version, insert the replacement, maintain both indexes, journal
    /// one OP_UPDATE_MANY frame per batch (shard CPU).
    pub update_doc_ns: f64,
    /// Storage-engine delete per document: kill + index removal, rids
    /// journaled in one OP_DELETE_MANY frame per batch (shard CPU).
    pub delete_doc_ns: f64,
    /// Journal bytes per document (OST traffic).
    pub journal_bytes_per_doc: f64,
    /// Fixed cost per journal *frame* (group commit: append + flush),
    /// paid once per shard sub-batch. This is the term the batch-size
    /// axis amortizes.
    pub journal_frame_ns: f64,
    /// Delta-checkpoint serialization per *newly written* document
    /// (shard CPU; the OST transfer of the delta is charged
    /// separately). The steady-state compaction term — proportional to
    /// work done since the last checkpoint, not to the live set.
    pub checkpoint_doc_ns: f64,
    /// Chain-rebase serialization per *live* document (shard CPU) —
    /// paid only when the delta chain reaches
    /// `SimSpec::full_checkpoint_chain` and a full snapshot is written.
    pub rebase_doc_ns: f64,
    /// Chunk-migration cost per moved document, split between the donor
    /// (extract: fetch + filter) and the recipient (install: index +
    /// journal). Derived at calibration time as `result_doc_ns +
    /// insert_doc_ns` — a migrated document is read once on one shard
    /// and written once on the other.
    pub migrate_doc_ns: f64,
    /// Fixed per-shard cost of opening a find (planner, cursor).
    pub find_fixed_ns: f64,
    /// Index-scan cost per candidate record id.
    pub index_candidate_ns: f64,
    /// Raw field probe per candidate (seek `ts`/`node_id` in the
    /// encoded record bytes — the zero-copy matcher/kernel-extraction
    /// cost; no allocation).
    pub doc_probe_ns: f64,
    /// Full document decode (the serve-path materialization, and the
    /// per-candidate cost of the pre-raw read path).
    pub doc_decode_ns: f64,
    /// Fetch + filter + serialize per result document (shard CPU).
    pub result_doc_ns: f64,
    /// Router-side merge per result document.
    pub merge_doc_ns: f64,
    /// Aggregation push-down: fold one matching record into the
    /// shard's partial accumulator table (raw field probes + group
    /// upsert; no decode).
    pub agg_doc_ns: f64,
    /// Router-side merge per partial accumulator row received — the
    /// per-group term that replaces `merge_doc_ns` × matches when
    /// push-down is on.
    pub agg_merge_group_ns: f64,
    /// Config-server fixed cost of committing a chunk split.
    pub split_base_ns: f64,
    /// Config-server cost per chunk-map *entry* per member refresh
    /// (serialize + copy; the per-entry part of metadata churn).
    pub map_entry_ns: f64,
    /// Fixed cost of one chunk-map refresh RPC served by the config
    /// server (request handling; network latency added separately).
    pub refresh_fixed_ns: f64,
    /// Per-OST streaming bandwidth (MiB/s).
    pub ost_bandwidth_mib_s: f64,
    /// Torus per-link bandwidth (bytes/s) for the bisection model.
    pub link_bandwidth_bps: f64,
    /// Message latency floor (ns).
    pub net_latency_ns: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Measured on the reference box (1-CPU container, see
        // EXPERIMENTS.md §Calibration); override with `hpcstore
        // calibrate`.
        Self {
            gen_doc_ns: 4_000.0,
            doc_bytes: 1_400.0,
            route_batch_fixed_ns: 120_000.0,
            route_doc_ns: 25.0,
            dispatch_doc_ns: 120.0,
            insert_doc_ns: 6_000.0,
            update_doc_ns: 7_000.0,
            delete_doc_ns: 2_500.0,
            journal_bytes_per_doc: 1_450.0,
            journal_frame_ns: 25_000.0,
            checkpoint_doc_ns: 400.0,
            rebase_doc_ns: 400.0,
            migrate_doc_ns: 7_500.0,
            find_fixed_ns: 40_000.0,
            index_candidate_ns: 90.0,
            doc_probe_ns: 120.0,
            doc_decode_ns: 1_100.0,
            result_doc_ns: 1_500.0,
            merge_doc_ns: 120.0,
            agg_doc_ns: 350.0,
            agg_merge_group_ns: 400.0,
            split_base_ns: 80_000.0,
            map_entry_ns: 2.0,
            refresh_fixed_ns: 60_000.0,
            ost_bandwidth_mib_s: 500.0,
            link_bandwidth_bps: 3.0e9,
            net_latency_ns: 1_500.0,
        }
    }
}

impl CostModel {
    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("gen_doc_ns", self.gen_doc_ns)
            .set("doc_bytes", self.doc_bytes)
            .set("route_batch_fixed_ns", self.route_batch_fixed_ns)
            .set("route_doc_ns", self.route_doc_ns)
            .set("dispatch_doc_ns", self.dispatch_doc_ns)
            .set("insert_doc_ns", self.insert_doc_ns)
            .set("update_doc_ns", self.update_doc_ns)
            .set("delete_doc_ns", self.delete_doc_ns)
            .set("journal_bytes_per_doc", self.journal_bytes_per_doc)
            .set("journal_frame_ns", self.journal_frame_ns)
            .set("checkpoint_doc_ns", self.checkpoint_doc_ns)
            .set("rebase_doc_ns", self.rebase_doc_ns)
            .set("migrate_doc_ns", self.migrate_doc_ns)
            .set("find_fixed_ns", self.find_fixed_ns)
            .set("index_candidate_ns", self.index_candidate_ns)
            .set("doc_probe_ns", self.doc_probe_ns)
            .set("doc_decode_ns", self.doc_decode_ns)
            .set("result_doc_ns", self.result_doc_ns)
            .set("merge_doc_ns", self.merge_doc_ns)
            .set("agg_doc_ns", self.agg_doc_ns)
            .set("agg_merge_group_ns", self.agg_merge_group_ns)
            .set("split_base_ns", self.split_base_ns)
            .set("map_entry_ns", self.map_entry_ns)
            .set("refresh_fixed_ns", self.refresh_fixed_ns)
            .set("ost_bandwidth_mib_s", self.ost_bandwidth_mib_s)
            .set("link_bandwidth_bps", self.link_bandwidth_bps)
            .set("net_latency_ns", self.net_latency_ns);
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Self::default();
        let f = |k: &str, dflt: f64| v.get(k).and_then(Value::as_f64).unwrap_or(dflt);
        Ok(Self {
            gen_doc_ns: f("gen_doc_ns", d.gen_doc_ns),
            doc_bytes: f("doc_bytes", d.doc_bytes),
            route_batch_fixed_ns: f("route_batch_fixed_ns", d.route_batch_fixed_ns),
            route_doc_ns: f("route_doc_ns", d.route_doc_ns),
            dispatch_doc_ns: f("dispatch_doc_ns", d.dispatch_doc_ns),
            insert_doc_ns: f("insert_doc_ns", d.insert_doc_ns),
            update_doc_ns: f("update_doc_ns", d.update_doc_ns),
            delete_doc_ns: f("delete_doc_ns", d.delete_doc_ns),
            journal_bytes_per_doc: f("journal_bytes_per_doc", d.journal_bytes_per_doc),
            journal_frame_ns: f("journal_frame_ns", d.journal_frame_ns),
            checkpoint_doc_ns: f("checkpoint_doc_ns", d.checkpoint_doc_ns),
            rebase_doc_ns: f("rebase_doc_ns", d.rebase_doc_ns),
            migrate_doc_ns: f("migrate_doc_ns", d.migrate_doc_ns),
            find_fixed_ns: f("find_fixed_ns", d.find_fixed_ns),
            index_candidate_ns: f("index_candidate_ns", d.index_candidate_ns),
            doc_probe_ns: f("doc_probe_ns", d.doc_probe_ns),
            doc_decode_ns: f("doc_decode_ns", d.doc_decode_ns),
            result_doc_ns: f("result_doc_ns", d.result_doc_ns),
            merge_doc_ns: f("merge_doc_ns", d.merge_doc_ns),
            agg_doc_ns: f("agg_doc_ns", d.agg_doc_ns),
            agg_merge_group_ns: f("agg_merge_group_ns", d.agg_merge_group_ns),
            split_base_ns: f("split_base_ns", d.split_base_ns),
            map_entry_ns: f("map_entry_ns", d.map_entry_ns),
            refresh_fixed_ns: f("refresh_fixed_ns", d.refresh_fixed_ns),
            ost_bandwidth_mib_s: f("ost_bandwidth_mib_s", d.ost_bandwidth_mib_s),
            link_bandwidth_bps: f("link_bandwidth_bps", d.link_bandwidth_bps),
            net_latency_ns: f("net_latency_ns", d.net_latency_ns),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        json::to_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&json::from_file(path)?)
    }

    /// Load `artifacts/costmodel.json` if present, else defaults.
    pub fn load_or_default(artifact_dir: &std::path::Path) -> Self {
        let p = artifact_dir.join("costmodel.json");
        if p.exists() {
            Self::load(&p).unwrap_or_default()
        } else {
            Self::default()
        }
    }

    /// TCP-class floor for one metadata RPC (connection handling, BSON
    /// codec, kernel network stack) — what the in-process mpsc transport
    /// substitution removed relative to the paper's real deployment
    /// ("MongoDB is natively deployed on a TCP/IP network").
    pub const TCP_RPC_FLOOR_NS: f64 = 60_000.0;

    /// Restore network-stack costs for cluster-scale simulation: the
    /// live calibration measures our in-process transport (µs-class
    /// metadata RPCs); a Gemini/TCP deployment pays tens of µs per RPC.
    /// Applied by the Figure-2/3 harnesses; the raw measured values are
    /// reported in the sensitivity ablation.
    pub fn with_network_floor(mut self) -> Self {
        self.refresh_fixed_ns = self.refresh_fixed_ns.max(Self::TCP_RPC_FLOOR_NS);
        self.split_base_ns = self.split_base_ns.max(Self::TCP_RPC_FLOOR_NS);
        self
    }

    /// Measure the live implementation. `kernels` decides whether the
    /// routing costs reflect the HLO or the scalar fallback path.
    pub fn calibrate(kernels: &Kernels, quick: bool) -> Result<Self> {
        use crate::config::WorkloadConfig;
        use crate::mongo::storage::index::IndexSpec;
        use crate::mongo::storage::{Engine, LocalDir};
        use crate::workload::ovis::OvisGenerator;

        let mut cm = Self::default();
        let n_docs: usize = if quick { 1_000 } else { 8_000 };

        // --- Client: doc synthesis + size.
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 64,
            ..Default::default()
        });
        let t = Instant::now();
        let mut bytes = 0usize;
        for i in 0..n_docs as u64 {
            bytes += gen.doc_at(i).encoded_len();
        }
        cm.gen_doc_ns = t.elapsed().as_nanos() as f64 / n_docs as f64;
        cm.doc_bytes = bytes as f64 / n_docs as f64;
        cm.journal_bytes_per_doc = cm.doc_bytes + 40.0;

        // --- Shard: engine insert with journal + both indexes.
        let dir = LocalDir::temp("calib")?;
        let mut eng = Engine::open(Box::new(dir), true, false)?;
        eng.create_collection("m");
        eng.create_index("m", IndexSpec::single("ts"))?;
        eng.create_index("m", IndexSpec::single("node_id"))?;
        let docs: Vec<_> = (0..n_docs as u64).map(|i| gen.doc_at(i)).collect();
        let t = Instant::now();
        for (i, d) in docs.iter().enumerate() {
            eng.insert("m", d)?;
            if i % 1000 == 999 {
                eng.sync()?;
            }
        }
        eng.sync()?;
        cm.insert_doc_ns = t.elapsed().as_nanos() as f64 / n_docs as f64;

        // --- Shard: per-frame journal cost — a group commit of one tiny
        // frame (append + flush) minus the insert work itself. The
        // batch-size axis amortizes this fixed term.
        {
            let reps = if quick { 200 } else { 1000 };
            let d0 = gen.doc_at(0);
            let t = Instant::now();
            for _ in 0..reps {
                eng.insert("m", &d0)?;
                eng.sync()?;
            }
            let per_commit = t.elapsed().as_nanos() as f64 / reps as f64;
            cm.journal_frame_ns = (per_commit - cm.insert_doc_ns).max(1_000.0);
        }

        // --- Router: route kernel fixed + per-doc via two batch sizes.
        let shapes = kernels.shapes();
        let bounds: Vec<u32> = (1..=64u32)
            .map(|i| ((u32::MAX as u64 + 1) / 64 * i as u64 - 1) as u32)
            .collect();
        let c2s: Vec<i32> = (0..64).map(|i| i % 7).collect();
        let big = shapes.route_b;
        let small = shapes.route_b / 8;
        let time_route = |n: usize, reps: usize| -> Result<f64> {
            let node: Vec<u32> = (0..n as u32).collect();
            let ts: Vec<u32> = (0..n as u32).map(|i| i * 7).collect();
            let t = Instant::now();
            for _ in 0..reps {
                kernels.route(&node, &ts, &bounds, &c2s, 7)?;
            }
            Ok(t.elapsed().as_nanos() as f64 / reps as f64)
        };
        let reps = if quick { 3 } else { 10 };
        let t_big = time_route(big, reps)?;
        let t_small = time_route(small, reps)?;
        cm.route_doc_ns = ((t_big - t_small) / (big - small) as f64).max(1.0);
        cm.route_batch_fixed_ns = (t_small - small as f64 * cm.route_doc_ns).max(0.0);

        // Dispatch per doc: move+push into per-shard vectors (the router
        // moves documents, it never clones them).
        let moved: Vec<crate::mongo::bson::Document> = docs.clone();
        let t = Instant::now();
        let mut sink: Vec<Vec<crate::mongo::bson::Document>> =
            (0..7).map(|_| Vec::new()).collect();
        for (i, d) in moved.into_iter().enumerate() {
            sink[i % 7].push(d);
        }
        cm.dispatch_doc_ns = t.elapsed().as_nanos() as f64 / n_docs as f64;
        drop(sink);

        // --- Query path: index scan + fetch/serialize.
        let idx = eng.index("m", "ts_1").expect("calibration index");
        let t = Instant::now();
        let mut candidates = 0usize;
        let reps = if quick { 20 } else { 100 };
        for i in 0..reps {
            let lo = crate::mongo::bson::Value::Int(
                gen.config().start_epoch_min as i64 + i as i64,
            );
            let hi = crate::mongo::bson::Value::Int(
                gen.config().start_epoch_min as i64 + i as i64 + 4,
            );
            candidates += idx.range_superset(Some(&lo), Some(&hi)).count();
        }
        cm.index_candidate_ns =
            (t.elapsed().as_nanos() as f64 / candidates.max(1) as f64).max(10.0);

        let t = Instant::now();
        let mut fetched = 0;
        for rid in 0..(n_docs as u64).min(2000) {
            if eng.fetch("m", rid).is_some() {
                fetched += 1;
            }
        }
        cm.result_doc_ns = t.elapsed().as_nanos() as f64 / fetched.max(1) as f64;

        // --- Read path: raw field probe vs full document decode, over
        // the calibration corpus's encoded records. The ratio is what
        // the zero-copy matcher saves per *rejected* candidate; the
        // decode term is what each *served* document still pays.
        {
            use crate::mongo::bson::{Document, RawDoc};
            let encs: Vec<Vec<u8>> = docs.iter().map(Document::encode).collect();
            let reps = if quick { 4 } else { 20 };
            let t = Instant::now();
            let mut acc = 0i64;
            for _ in 0..reps {
                for e in &encs {
                    let rd = RawDoc::new(e);
                    acc += rd.get_i64("ts").unwrap_or(0)
                        + rd.get_i64("node_id").unwrap_or(0);
                }
            }
            std::hint::black_box(acc);
            cm.doc_probe_ns =
                (t.elapsed().as_nanos() as f64 / (reps * encs.len()) as f64).max(5.0);
            let t = Instant::now();
            for _ in 0..reps {
                for e in &encs {
                    std::hint::black_box(Document::decode(e).expect("calib doc").len());
                }
            }
            cm.doc_decode_ns =
                (t.elapsed().as_nanos() as f64 / (reps * encs.len()) as f64).max(20.0);
        }

        // --- Aggregation push-down: fold one encoded record into a
        // partial accumulator table (the shard-side scalar path), and
        // the router-side merge per partial row received.
        {
            use crate::mongo::aggregate::{AggPipeline, PartialTable};
            use crate::mongo::bson::{Document, RawDoc};
            let p = AggPipeline::new()
                .group_by("node_id")
                .count("n")
                .avg("mean_ts", "ts");
            let encs: Vec<Vec<u8>> = docs.iter().map(Document::encode).collect();
            let reps = if quick { 4 } else { 20 };
            let t = Instant::now();
            let mut table = PartialTable::new();
            for _ in 0..reps {
                for e in &encs {
                    table.fold_raw(&p, &RawDoc::new(e));
                }
            }
            cm.agg_doc_ns =
                (t.elapsed().as_nanos() as f64 / (reps * encs.len()) as f64).max(10.0);
            let rows = table.into_rows();
            let merges = if quick { 50 } else { 500 };
            let t = Instant::now();
            for _ in 0..merges {
                let mut m = PartialTable::new();
                m.merge_rows(&p, rows.clone());
                std::hint::black_box(m.len());
            }
            cm.agg_merge_group_ns = (t.elapsed().as_nanos() as f64
                / (merges as f64 * rows.len().max(1) as f64))
                .max(10.0);
        }

        // --- Shard: update / delete per document, measured as one
        // batch each (both journal a single frame per batch, like the
        // live write path). Updates overwrite a prefix of the corpus
        // with a re-tagged copy; the delete then removes exactly the
        // replacement records, leaving the rest of the corpus for the
        // checkpoint calibration below.
        {
            let rids = eng.record_ids("m");
            let k = rids.len().min(if quick { 512 } else { 2048 }).max(1);
            let updates: Vec<(crate::mongo::storage::RecordId, crate::mongo::bson::Document)> =
                rids[..k]
                    .iter()
                    .enumerate()
                    .map(|(i, &rid)| (rid, gen.doc_at(i as u64).set("flag", 1i64)))
                    .collect();
            let t = Instant::now();
            let new_rids = eng.update_many("m", &updates)?;
            eng.sync()?;
            cm.update_doc_ns =
                (t.elapsed().as_nanos() as f64 / k as f64).max(50.0);
            let t = Instant::now();
            eng.delete_many("m", &new_rids)?;
            eng.sync()?;
            cm.delete_doc_ns =
                (t.elapsed().as_nanos() as f64 / k as f64).max(50.0);
        }

        // --- Migration: a moved document is fetched + filtered once on
        // the donor and indexed + journaled once on the recipient, so
        // the per-document cost composes from the two terms measured
        // above rather than a separate (and redundant) harness.
        cm.migrate_doc_ns = cm.result_doc_ns + cm.insert_doc_ns;

        // --- Shard: checkpoint serialization costs (storage lifecycle).
        // The DES charges each checkpoint's OST transfer separately, so
        // subtract the measured cost of writing an equivalently-sized
        // blob — otherwise the transfer would be double-counted and
        // every lifecycle data point would overstate compaction cost.
        // Generation 1 is a full snapshot → the *rebase* term, per live
        // document. A later generation is a delta → the steady-state
        // term, per newly written document.
        {
            let write_ns_for = |bytes: usize| -> Result<f64> {
                let blob = vec![0xA5u8; bytes];
                let scratch = std::env::temp_dir()
                    .join(format!("hpcstore-calib-io-{}", std::process::id()));
                let t = Instant::now();
                std::fs::write(&scratch, &blob)?;
                let ns = t.elapsed().as_nanos() as f64;
                let _ = std::fs::remove_file(&scratch);
                Ok(ns)
            };
            let live = eng.stats("m").docs.max(1);
            let t = Instant::now();
            let ck = eng.checkpoint()?; // generation 1: full snapshot
            let total_ns = t.elapsed().as_nanos() as f64;
            cm.rebase_doc_ns =
                ((total_ns - write_ns_for(ck.checkpoint_bytes as usize)?) / live as f64)
                    .max(50.0);

            let fresh = (n_docs / 8).max(64);
            for i in 0..fresh as u64 {
                eng.insert("m", &gen.doc_at(i))?;
            }
            eng.sync()?;
            let t = Instant::now();
            let ck = eng.checkpoint()?; // generation 2: delta
            let total_ns = t.elapsed().as_nanos() as f64;
            debug_assert!(!ck.full, "generation 2 must be a delta");
            cm.checkpoint_doc_ns =
                ((total_ns - write_ns_for(ck.checkpoint_bytes as usize)?) / fresh as f64)
                    .max(50.0);
        }

        // --- Config: split + map clone per entry.
        use crate::mongo::sharding::chunk::{ChunkMap, ShardKey};
        let mut map = ChunkMap::pre_split(ShardKey::hashed(), 7, 2);
        for _ in 0..200 {
            let (lo, hi) = map.chunk_range(0);
            if hi - lo < 2 {
                break;
            }
            map.split(0, lo + (hi - lo) / 2).unwrap();
        }
        let t = Instant::now();
        let clones = if quick { 200 } else { 1000 };
        for _ in 0..clones {
            std::hint::black_box(map.clone());
        }
        cm.map_entry_ns =
            t.elapsed().as_nanos() as f64 / (clones as f64 * map.num_chunks() as f64);

        // Refresh RPC: a live GetMap through the wire layer (mpsc RPC +
        // map clone). A TCP deployment pays network latency on top; the
        // sim adds `net_latency_ns` per member separately.
        {
            use crate::mongo::server::config::ConfigServer;
            use crate::mongo::sharding::chunk::ShardKey as SK;
            use crate::mongo::wire::{rpc, ConfigRequest};
            let cfg = ConfigServer::new(SK::hashed(), 7, 30, 3, crate::metrics::Registry::new());
            let (tx, join) = cfg.spawn();
            let reps = if quick { 200 } else { 2000 };
            let t = Instant::now();
            for _ in 0..reps {
                let m = rpc(&tx, |reply| ConfigRequest::GetMap { reply }).unwrap();
                std::hint::black_box(m.num_chunks());
            }
            cm.refresh_fixed_ns = t.elapsed().as_nanos() as f64 / reps as f64;
            let _ = tx.send(ConfigRequest::Shutdown);
            let _ = join.join();
        }

        Ok(cm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let cm = CostModel::default();
        let back = CostModel::from_json(&cm.to_json()).unwrap();
        assert_eq!(cm, back);
    }

    #[test]
    fn load_or_default_without_file() {
        let cm = CostModel::load_or_default(std::path::Path::new("/nonexistent"));
        assert_eq!(cm, CostModel::default());
    }

    #[test]
    fn quick_calibration_produces_sane_values() {
        let kernels = Kernels::fallback();
        let cm = CostModel::calibrate(&kernels, true).unwrap();
        assert!(cm.gen_doc_ns > 100.0 && cm.gen_doc_ns < 1e6, "gen {}", cm.gen_doc_ns);
        assert!(cm.doc_bytes > 500.0 && cm.doc_bytes < 5000.0, "bytes {}", cm.doc_bytes);
        assert!(cm.insert_doc_ns > 200.0 && cm.insert_doc_ns < 1e7, "ins {}", cm.insert_doc_ns);
        assert!(cm.update_doc_ns >= 50.0 && cm.update_doc_ns < 1e7, "upd {}", cm.update_doc_ns);
        assert!(cm.delete_doc_ns >= 50.0 && cm.delete_doc_ns < 1e7, "del {}", cm.delete_doc_ns);
        assert!(cm.route_doc_ns >= 1.0 && cm.route_doc_ns < 1e5);
        assert!(cm.index_candidate_ns >= 10.0);
        assert!(cm.result_doc_ns > 50.0);
        assert!(cm.doc_probe_ns >= 5.0, "probe {}", cm.doc_probe_ns);
        assert!(cm.doc_decode_ns >= 20.0, "decode {}", cm.doc_decode_ns);
        assert!(cm.agg_doc_ns >= 10.0 && cm.agg_doc_ns < 1e6, "agg {}", cm.agg_doc_ns);
        assert!(
            cm.agg_merge_group_ns >= 10.0 && cm.agg_merge_group_ns < 1e7,
            "agg merge {}",
            cm.agg_merge_group_ns
        );
        assert!(cm.map_entry_ns > 0.0);
        assert!(cm.journal_frame_ns >= 1_000.0, "frame {}", cm.journal_frame_ns);
        assert!(cm.checkpoint_doc_ns >= 50.0, "ckpt {}", cm.checkpoint_doc_ns);
        assert!(cm.rebase_doc_ns >= 50.0, "rebase {}", cm.rebase_doc_ns);
        assert!(
            (cm.migrate_doc_ns - cm.result_doc_ns - cm.insert_doc_ns).abs() < 1e-6,
            "migrate {} must compose extract + install",
            cm.migrate_doc_ns
        );
    }
}
