//! Incremental-checkpoint encode/decode — the `HPCCKPT3` on-disk format.
//!
//! A *delta* checkpoint carries only the records inserted or removed
//! since the previous generation, so steady-state compaction cost
//! scales with work done instead of with the live set. Each delta names
//! the *base generation* (the full snapshot its chain extends) and the
//! journal-segment watermark it covers; recovery folds base + chain in
//! generation order before replaying the journal tail. The engine
//! (`engine.rs`) owns the chain policy (when to rebase into a fresh
//! full snapshot); this module owns the bytes.
//!
//! Header, shared by v3 full snapshots (`store.ckpt`) and deltas
//! (`delta-NNNNNN.ckpt`), all integers little-endian:
//!
//! ```text
//! 8 bytes  magic "HPCCKPT3"
//! u8       kind             0 = full snapshot, 1 = delta
//! u64      generation
//! u64      base_generation  full: == generation; delta: chain base
//! u64      covered_seq      highest journal segment this covers
//! u8       compressed       1 = payload is LZSS-compressed
//! ...      payload          full body (see `Engine::checkpoint`) or
//!                           delta body (`encode_body`)
//! ```
//!
//! Delta body: `u32 ncolls`, then per collection `u8 name_len | name |
//! u64 next_rid | u32 n_indexes`, per index `u8 len | comma-joined
//! fields`, `u64 n_upserts`, per upsert `u64 rid | u32 len | bytes`,
//! `u64 n_removes`, per remove `u64 rid`.

use anyhow::{bail, Result};

use super::engine::RecordId;

/// Magic of the v3 (incremental-capable) checkpoint header.
pub const MAGIC_V3: &[u8; 8] = b"HPCCKPT3";
/// Header `kind`: full snapshot.
pub const KIND_FULL: u8 = 0;
/// Header `kind`: delta over `base_generation`'s chain.
pub const KIND_DELTA: u8 = 1;
/// Fixed byte length of the v3 header.
pub const HEADER_LEN: usize = 34;

/// File name of the delta checkpoint of `generation`.
pub fn delta_file_name(generation: u64) -> String {
    format!("delta-{generation:06}.ckpt")
}

/// Parse a delta file name back to its generation (`None` for anything
/// else, including `.tmp` staging files).
pub fn parse_delta_gen(name: &str) -> Option<u64> {
    name.strip_prefix("delta-")?.strip_suffix(".ckpt")?.parse().ok()
}

/// Decoded v3 checkpoint header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeaderV3 {
    pub kind: u8,
    pub generation: u64,
    pub base_generation: u64,
    pub covered_seq: u64,
    pub compressed: bool,
}

/// Serialize a v3 header (the payload is appended by the caller).
pub fn encode_header(h: &HeaderV3) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN);
    out.extend_from_slice(MAGIC_V3);
    out.push(h.kind);
    out.extend_from_slice(&h.generation.to_le_bytes());
    out.extend_from_slice(&h.base_generation.to_le_bytes());
    out.extend_from_slice(&h.covered_seq.to_le_bytes());
    out.push(h.compressed as u8);
    out
}

/// Parse a v3 header, returning it and the remaining payload bytes.
pub fn parse_header(raw: &[u8]) -> Result<(HeaderV3, &[u8])> {
    if raw.len() < HEADER_LEN || &raw[..8] != MAGIC_V3 {
        bail!("bad v3 checkpoint header");
    }
    let kind = raw[8];
    if kind != KIND_FULL && kind != KIND_DELTA {
        bail!("unknown v3 checkpoint kind {kind}");
    }
    let compressed = match raw[33] {
        0 => false,
        1 => true,
        b => bail!("bad v3 checkpoint compression flag {b}"),
    };
    Ok((
        HeaderV3 {
            kind,
            generation: u64::from_le_bytes(raw[9..17].try_into()?),
            base_generation: u64::from_le_bytes(raw[17..25].try_into()?),
            covered_seq: u64::from_le_bytes(raw[25..33].try_into()?),
            compressed,
        },
        &raw[HEADER_LEN..],
    ))
}

/// One collection's slice of a delta checkpoint.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeltaColl {
    pub name: String,
    /// Rid allocator position at checkpoint time (monotone; fold takes
    /// the max so replayed chains never re-issue a rid).
    pub next_rid: RecordId,
    /// Comma-joined field lists of *every* secondary index — the full
    /// list, not a diff: specs are tiny and folding them is idempotent
    /// (`create_index` backfills only indexes it has not seen).
    pub index_specs: Vec<String>,
    /// Records inserted since the previous generation.
    pub upserts: Vec<(RecordId, Vec<u8>)>,
    /// Records removed since the previous generation that existed *at*
    /// the previous generation (insert + remove within one interval
    /// nets out and appears in neither list).
    pub removes: Vec<RecordId>,
}

/// Serialize a delta body (uncompressed; the engine applies LZSS on
/// top when configured).
pub fn encode_body(colls: &[DeltaColl]) -> Vec<u8> {
    let mut body = Vec::new();
    body.extend_from_slice(&(colls.len() as u32).to_le_bytes());
    for c in colls {
        body.push(c.name.len() as u8);
        body.extend_from_slice(c.name.as_bytes());
        body.extend_from_slice(&c.next_rid.to_le_bytes());
        body.extend_from_slice(&(c.index_specs.len() as u32).to_le_bytes());
        for joined in &c.index_specs {
            body.push(joined.len() as u8);
            body.extend_from_slice(joined.as_bytes());
        }
        body.extend_from_slice(&(c.upserts.len() as u64).to_le_bytes());
        for (rid, bytes) in &c.upserts {
            body.extend_from_slice(&rid.to_le_bytes());
            body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            body.extend_from_slice(bytes);
        }
        body.extend_from_slice(&(c.removes.len() as u64).to_le_bytes());
        for rid in &c.removes {
            body.extend_from_slice(&rid.to_le_bytes());
        }
    }
    body
}

/// Decode a delta body (inverse of [`encode_body`]).
pub fn decode_body(body: &[u8]) -> Result<Vec<DeltaColl>> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > body.len() {
            bail!("truncated delta checkpoint body");
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    // Every count below is untrusted on-disk data: clamp each
    // pre-allocation to what the remaining bytes could possibly encode
    // (per-entry minimum sizes), so a corrupt count fails in `take`
    // with a recoverable error instead of aborting the allocator.
    let ncolls = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
    let mut colls = Vec::with_capacity(ncolls.min(body.len() / 29 + 1));
    for _ in 0..ncolls {
        let name_len = take(&mut pos, 1)?[0] as usize;
        let name = std::str::from_utf8(take(&mut pos, name_len)?)?.to_string();
        let next_rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let n_idx = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut index_specs = Vec::with_capacity(n_idx.min(body.len() - pos));
        for _ in 0..n_idx {
            let len = take(&mut pos, 1)?[0] as usize;
            index_specs.push(std::str::from_utf8(take(&mut pos, len)?)?.to_string());
        }
        let n_up = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        let mut upserts = Vec::with_capacity(n_up.min((body.len() - pos) / 12 + 1));
        for _ in 0..n_up {
            let rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            upserts.push((rid, take(&mut pos, len)?.to_vec()));
        }
        let n_rm = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        let mut removes = Vec::with_capacity(n_rm.min((body.len() - pos) / 8 + 1));
        for _ in 0..n_rm {
            removes.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?));
        }
        colls.push(DeltaColl { name, next_rid, index_specs, upserts, removes });
    }
    if pos != body.len() {
        bail!("delta checkpoint body has trailing bytes");
    }
    Ok(colls)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<DeltaColl> {
        vec![
            DeltaColl {
                name: "metrics".into(),
                next_rid: 42,
                index_specs: vec!["ts".into(), "node_id,ts".into()],
                upserts: vec![(40, vec![1, 2, 3]), (41, vec![9])],
                removes: vec![7, 12],
            },
            DeltaColl { name: "empty".into(), next_rid: 0, ..Default::default() },
        ]
    }

    #[test]
    fn body_round_trip() {
        let colls = sample();
        let body = encode_body(&colls);
        assert_eq!(decode_body(&body).unwrap(), colls);
    }

    #[test]
    fn header_round_trip() {
        let h = HeaderV3 {
            kind: KIND_DELTA,
            generation: 9,
            base_generation: 5,
            covered_seq: 31,
            compressed: true,
        };
        let mut raw = encode_header(&h);
        assert_eq!(raw.len(), HEADER_LEN);
        raw.extend_from_slice(b"payload");
        let (back, payload) = parse_header(&raw).unwrap();
        assert_eq!(back, h);
        assert_eq!(payload, b"payload");
    }

    #[test]
    fn truncated_body_is_rejected() {
        let body = encode_body(&sample());
        for cut in [0usize, 3, body.len() / 2, body.len() - 1] {
            assert!(decode_body(&body[..cut]).is_err(), "cut={cut}");
        }
        let mut trailing = body.clone();
        trailing.push(0);
        assert!(decode_body(&trailing).is_err(), "trailing byte must fail");
    }

    #[test]
    fn corrupt_counts_fail_without_allocating() {
        // An absurd on-disk count must come back as a decode error, not
        // a capacity panic / allocator abort.
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes()); // one collection
        body.push(1);
        body.push(b'm');
        body.extend_from_slice(&0u64.to_le_bytes()); // next_rid
        body.extend_from_slice(&0u32.to_le_bytes()); // no indexes
        body.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd n_upserts
        assert!(decode_body(&body).is_err());
    }

    #[test]
    fn delta_file_names_round_trip() {
        assert_eq!(delta_file_name(7), "delta-000007.ckpt");
        assert_eq!(parse_delta_gen("delta-000007.ckpt"), Some(7));
        assert_eq!(parse_delta_gen("delta-000007.ckpt.tmp"), None);
        assert_eq!(parse_delta_gen("journal-000007.wal"), None);
        assert_eq!(parse_delta_gen("store.ckpt"), None);
    }

    #[test]
    fn bad_headers_are_rejected() {
        assert!(parse_header(b"HPCCKPT3").is_err(), "too short");
        let mut raw = encode_header(&HeaderV3 {
            kind: KIND_FULL,
            generation: 1,
            base_generation: 1,
            covered_seq: 0,
            compressed: false,
        });
        raw[0] = b'X';
        assert!(parse_header(&raw).is_err(), "bad magic");
        let mut raw = encode_header(&HeaderV3 {
            kind: 9,
            generation: 1,
            base_generation: 1,
            covered_seq: 0,
            compressed: false,
        });
        assert!(parse_header(&raw).is_err(), "bad kind");
        raw[8] = KIND_FULL;
        raw[33] = 7;
        assert!(parse_header(&raw).is_err(), "bad compression flag");
    }
}
