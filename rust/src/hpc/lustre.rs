//! Lustre-like striped parallel filesystem.
//!
//! Model: an MDS owning the namespace plus `osts` object storage
//! targets. Every file gets a stripe layout (`stripe_count` OSTs chosen
//! round-robin from a per-file starting offset, `stripe_size` bytes per
//! stripe unit) — so when the run script assigns each shard its own
//! directory, writes spread over distinct OSTs exactly as the paper
//! describes ("luster will distribute those files to an object storage
//! server that should optimize further I/O").
//!
//! Live mode: bytes really land in a backing directory (one file per
//! logical file) while per-OST byte counters are maintained for reports;
//! the DES uses [`Lustre::transfer_ns`] for virtual-time cost.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::LustreConfig;
use crate::mongo::storage::{LocalDir, StorageDir, StorageFile};
use crate::util::hash::fnv1a_64;

/// Per-OST counters.
#[derive(Default)]
struct OstState {
    bytes_written: AtomicU64,
    bytes_read: AtomicU64,
    objects: AtomicU64,
}

/// Stripe layout of one file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Layout {
    pub stripe_count: u32,
    pub stripe_size: u64,
    /// First OST index; stripes go round-robin from here.
    pub start_ost: u32,
}

struct Mds {
    /// namespace: logical path → layout
    files: BTreeMap<String, Layout>,
    /// Directory default stripe counts (`lfs setstripe` analogue).
    dir_stripe: BTreeMap<String, u32>,
}

struct Inner {
    cfg: LustreConfig,
    osts: Vec<OstState>,
    mds: Mutex<Mds>,
    backing: PathBuf,
}

/// Shared filesystem handle.
#[derive(Clone)]
pub struct Lustre {
    inner: Arc<Inner>,
}

impl Lustre {
    /// Mount: `cfg.backing_dir` (or a fresh temp dir when empty) holds
    /// the real bytes.
    pub fn mount(cfg: LustreConfig) -> Result<Self> {
        let backing = if cfg.backing_dir.is_empty() {
            use std::sync::atomic::{AtomicU64, Ordering};
            static NEXT: AtomicU64 = AtomicU64::new(0);
            std::env::temp_dir().join(format!(
                "hpcstore-lustre-{}-{}",
                std::process::id(),
                NEXT.fetch_add(1, Ordering::Relaxed)
            ))
        } else {
            PathBuf::from(&cfg.backing_dir)
        };
        std::fs::create_dir_all(&backing)
            .with_context(|| format!("creating lustre backing dir {}", backing.display()))?;
        let osts = (0..cfg.osts).map(|_| OstState::default()).collect();
        Ok(Self {
            inner: Arc::new(Inner {
                cfg,
                osts,
                mds: Mutex::new(Mds { files: BTreeMap::new(), dir_stripe: BTreeMap::new() }),
                backing,
            }),
        })
    }

    pub fn config(&self) -> &LustreConfig {
        &self.inner.cfg
    }

    /// `lfs setstripe -c` analogue for a directory.
    pub fn set_dir_stripe(&self, dir: &str, stripe_count: u32) {
        self.inner
            .mds
            .lock()
            .unwrap()
            .dir_stripe
            .insert(dir.trim_matches('/').to_string(), stripe_count.max(1));
    }

    fn layout_for(&self, path: &str) -> Layout {
        let mds = self.inner.mds.lock().unwrap();
        let dir = path.rsplit_once('/').map(|(d, _)| d).unwrap_or("");
        let stripe_count = mds
            .dir_stripe
            .iter()
            .filter(|(d, _)| dir.starts_with(d.as_str()))
            .map(|(_, c)| *c)
            .next_back()
            .unwrap_or(self.inner.cfg.default_stripe_count)
            .min(self.inner.cfg.osts);
        Layout {
            stripe_count: stripe_count.max(1),
            stripe_size: self.inner.cfg.stripe_size_kib as u64 * 1024,
            start_ost: (fnv1a_64(path.as_bytes()) % self.inner.cfg.osts as u64) as u32,
        }
    }

    fn register_file(&self, path: &str) -> Layout {
        let layout = self.layout_for(path);
        let mut mds = self.inner.mds.lock().unwrap();
        let fresh = mds.files.insert(path.to_string(), layout.clone()).is_none();
        drop(mds);
        if fresh {
            for i in 0..layout.stripe_count {
                let ost = (layout.start_ost + i) % self.inner.cfg.osts;
                self.inner.osts[ost as usize].objects.fetch_add(1, Ordering::Relaxed);
            }
        }
        layout
    }

    /// Account a write of `bytes` at file offset `offset` against OSTs.
    fn account_write(&self, layout: &Layout, offset: u64, bytes: u64) {
        let mut remaining = bytes;
        let mut off = offset;
        while remaining > 0 {
            let unit = (off / layout.stripe_size) % layout.stripe_count as u64;
            let ost = (layout.start_ost + unit as u32) % self.inner.cfg.osts;
            let in_unit = layout.stripe_size - (off % layout.stripe_size);
            let chunk = remaining.min(in_unit);
            self.inner.osts[ost as usize]
                .bytes_written
                .fetch_add(chunk, Ordering::Relaxed);
            off += chunk;
            remaining -= chunk;
        }
    }

    fn account_read(&self, layout: &Layout, bytes: u64) {
        // Reads are whole-file in our usage; spread evenly.
        let per = bytes / layout.stripe_count as u64;
        for i in 0..layout.stripe_count {
            let ost = (layout.start_ost + i) % self.inner.cfg.osts;
            self.inner.osts[ost as usize]
                .bytes_read
                .fetch_add(per, Ordering::Relaxed);
        }
    }

    /// A shard-visible directory (implements [`StorageDir`]) rooted at
    /// logical `path`.
    pub fn dir(&self, path: &str) -> Result<LustreDir> {
        let clean = path.trim_matches('/').to_string();
        let local = LocalDir::new(self.inner.backing.join(&clean))?;
        Ok(LustreDir { fs: self.clone(), prefix: clean, local })
    }

    /// Modeled time to move `bytes` through `stripes` OSTs at the
    /// configured per-OST bandwidth (DES cost; contention is layered on
    /// top by the resource model).
    pub fn transfer_ns(&self, bytes: u64, stripes: u32) -> u64 {
        let bw = self.inner.cfg.ost_bandwidth_mib_s * 1024.0 * 1024.0; // B/s per OST
        let eff = bw * stripes.max(1).min(self.inner.cfg.osts) as f64;
        ((bytes as f64 / eff) * 1e9) as u64
    }

    /// Per-OST written bytes (reports, imbalance checks).
    pub fn ost_written(&self) -> Vec<u64> {
        self.inner
            .osts
            .iter()
            .map(|o| o.bytes_written.load(Ordering::Relaxed))
            .collect()
    }

    pub fn ost_read(&self) -> Vec<u64> {
        self.inner
            .osts
            .iter()
            .map(|o| o.bytes_read.load(Ordering::Relaxed))
            .collect()
    }

    pub fn total_written(&self) -> u64 {
        self.ost_written().iter().sum()
    }

    pub fn file_count(&self) -> usize {
        self.inner.mds.lock().unwrap().files.len()
    }

    pub fn backing_path(&self) -> &std::path::Path {
        &self.inner.backing
    }
}

/// A directory on the Lustre sim, usable as shard storage.
pub struct LustreDir {
    fs: Lustre,
    prefix: String,
    local: LocalDir,
}

impl LustreDir {
    fn logical(&self, name: &str) -> String {
        format!("{}/{}", self.prefix, name)
    }
}

struct LustreFile {
    fs: Lustre,
    layout: Layout,
    inner: Box<dyn StorageFile>,
}

impl StorageFile for LustreFile {
    fn append(&mut self, bytes: &[u8]) -> Result<()> {
        let offset = self.inner.len();
        self.inner.append(bytes)?;
        self.fs.account_write(&self.layout, offset, bytes.len() as u64);
        Ok(())
    }

    fn sync(&mut self) -> Result<()> {
        self.inner.sync()
    }

    fn len(&self) -> u64 {
        self.inner.len()
    }
}

impl StorageDir for LustreDir {
    fn create(&self, name: &str) -> Result<Box<dyn StorageFile>> {
        let layout = self.fs.register_file(&self.logical(name));
        Ok(Box::new(LustreFile { fs: self.fs.clone(), layout, inner: self.local.create(name)? }))
    }

    fn append_to(&self, name: &str) -> Result<Box<dyn StorageFile>> {
        let layout = self.fs.register_file(&self.logical(name));
        Ok(Box::new(LustreFile {
            fs: self.fs.clone(),
            layout,
            inner: self.local.append_to(name)?,
        }))
    }

    fn read(&self, name: &str) -> Result<Vec<u8>> {
        let bytes = self.local.read(name)?;
        let layout = self.fs.register_file(&self.logical(name));
        self.fs.account_read(&layout, bytes.len() as u64);
        Ok(bytes)
    }

    fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()> {
        let layout = self.fs.register_file(&self.logical(name));
        self.fs.account_write(&layout, 0, bytes.len() as u64);
        self.local.write_atomic(name, bytes)
    }

    fn exists(&self, name: &str) -> bool {
        self.local.exists(name)
    }

    fn remove(&self, name: &str) -> Result<()> {
        self.local.remove(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        // Namespace read served by the MDS: no OST traffic to account.
        self.local.list()
    }

    fn describe(&self) -> String {
        format!("lustre:/{} (backing {})", self.prefix, self.local.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs(osts: u32, stripe: u32) -> Lustre {
        Lustre::mount(LustreConfig {
            osts,
            default_stripe_count: stripe,
            stripe_size_kib: 1, // 1 KiB units make striping visible
            ost_bandwidth_mib_s: 100.0,
            backing_dir: String::new(),
        })
        .unwrap()
    }

    #[test]
    fn bytes_round_trip_through_backing() {
        let fs = fs(4, 2);
        let dir = fs.dir("scratch/shard-0").unwrap();
        let mut f = dir.create("journal.wal").unwrap();
        f.append(b"hello lustre").unwrap();
        f.sync().unwrap();
        assert_eq!(dir.read("journal.wal").unwrap(), b"hello lustre");
        assert_eq!(fs.total_written(), 12);
    }

    #[test]
    fn striping_spreads_across_osts() {
        let fs = fs(4, 4);
        let dir = fs.dir("scratch/s").unwrap();
        let mut f = dir.create("big").unwrap();
        // 8 KiB over 1-KiB stripe units on 4 OSTs → 2 KiB per OST.
        f.append(&vec![0u8; 8192]).unwrap();
        let written = fs.ost_written();
        assert_eq!(written.iter().sum::<u64>(), 8192);
        assert!(written.iter().all(|&w| w == 2048), "{written:?}");
    }

    #[test]
    fn stripe_count_one_hits_one_ost() {
        let fs = fs(4, 1);
        let dir = fs.dir("d").unwrap();
        let mut f = dir.create("x").unwrap();
        f.append(&vec![0u8; 4096]).unwrap();
        let written = fs.ost_written();
        assert_eq!(written.iter().filter(|&&w| w > 0).count(), 1, "{written:?}");
    }

    #[test]
    fn per_directory_stripe_override() {
        let fs = fs(8, 1);
        fs.set_dir_stripe("wide", 8);
        let narrow = fs.dir("narrow").unwrap();
        let wide = fs.dir("wide").unwrap();
        narrow.create("f").unwrap().append(&vec![0u8; 8192]).unwrap();
        wide.create("f").unwrap().append(&vec![0u8; 8192]).unwrap();
        let w = fs.ost_written();
        // Wide file touched all 8; narrow file only 1 → at least 8 OSTs
        // have bytes and one has double share.
        assert!(w.iter().filter(|&&b| b > 0).count() >= 8 || w.iter().any(|&b| b >= 8192));
    }

    #[test]
    fn shard_dirs_get_distinct_starting_osts() {
        let fs = fs(8, 2);
        let mut starts = std::collections::BTreeSet::new();
        for i in 0..8 {
            let layout = fs.layout_for(&format!("scratch/shard-{i}/journal.wal"));
            starts.insert(layout.start_ost);
        }
        // Hashing shouldn't collapse everything onto one OST.
        assert!(starts.len() >= 4, "{starts:?}");
    }

    #[test]
    fn transfer_time_scales() {
        let fs = fs(8, 2);
        let t1 = fs.transfer_ns(100 * 1024 * 1024, 1);
        let t4 = fs.transfer_ns(100 * 1024 * 1024, 4);
        assert!(t1 > 3 * t4, "t1={t1} t4={t4}");
        // 100 MiB at 100 MiB/s on 1 stripe ≈ 1 s.
        assert!((t1 as f64 - 1e9).abs() < 2e8, "t1={t1}");
    }

    #[test]
    fn engine_runs_on_lustre_dir() {
        use crate::mongo::bson::Document;
        use crate::mongo::storage::Engine;
        let fs = fs(4, 2);
        let dir = fs.dir("scratch/mongo/shard-3").unwrap();
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("m");
        eng.insert("m", &Document::new().set("ts", 1i64).set("node_id", 2i64)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        assert!(fs.total_written() > 0);
        assert!(fs.file_count() >= 2); // journal + checkpoint
    }
}
