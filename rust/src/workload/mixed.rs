//! Mixed CRUD workload driver — reads, inserts, updates, and deletes
//! interleaved over the live cluster.
//!
//! The paper's workloads are ingest-then-query; operational clusters
//! also re-tag samples (`updateMany`) and expire old windows
//! (`deleteMany`). This driver generalizes the PE model of
//! [`super::ingest`]: each PE thread owns a disjoint timestamp column,
//! draws operations from a weighted mix, and targets nodes by a
//! zipfian popularity law — a few hot nodes absorb most of the update
//! and read traffic, the realistic worst case for the shard holding
//! the hot chunk.
//!
//! Three named profiles drive the `fig_crud` bench and the live/DES
//! comparison (docs/EXPERIMENTS.md):
//!
//! * `update_heavy` — re-tagging burst: updates dominate mutations.
//! * `delete_heavy` — retention storm: deletes dominate mutations.
//! * `time_window_churn` — steady ingest with the *oldest* time
//!   window expired cluster-wide as new data lands (ts-only broadcast
//!   deletes, the churn pattern of a ring-buffer retention policy).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::ovis::OvisGenerator;
use crate::config::WorkloadConfig;
use crate::metrics::Histogram;
use crate::mongo::bson::{Document, Value};
use crate::mongo::client::MongoClient;
use crate::mongo::query::{CmpOp, Filter, FindOptions};
use crate::util::rng::Pcg32;

/// Operation weights (relative, not percentages).
#[derive(Clone, Copy, Debug)]
pub struct OpMix {
    pub reads: u32,
    pub inserts: u32,
    pub updates: u32,
    pub deletes: u32,
}

impl OpMix {
    pub fn total(&self) -> u32 {
        self.reads + self.inserts + self.updates + self.deletes
    }
}

/// Named workload profiles (the bench's sweep axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixProfile {
    UpdateHeavy,
    DeleteHeavy,
    TimeWindowChurn,
}

impl MixProfile {
    pub const ALL: [MixProfile; 3] =
        [MixProfile::UpdateHeavy, MixProfile::DeleteHeavy, MixProfile::TimeWindowChurn];

    pub fn name(self) -> &'static str {
        match self {
            MixProfile::UpdateHeavy => "update_heavy",
            MixProfile::DeleteHeavy => "delete_heavy",
            MixProfile::TimeWindowChurn => "time_window_churn",
        }
    }

    pub fn parse(s: &str) -> Option<MixProfile> {
        Self::ALL.iter().copied().find(|p| p.name() == s)
    }

    pub fn weights(self) -> OpMix {
        match self {
            MixProfile::UpdateHeavy => {
                OpMix { reads: 30, inserts: 30, updates: 35, deletes: 5 }
            }
            MixProfile::DeleteHeavy => {
                OpMix { reads: 30, inserts: 30, updates: 5, deletes: 35 }
            }
            MixProfile::TimeWindowChurn => {
                OpMix { reads: 30, inserts: 50, updates: 5, deletes: 15 }
            }
        }
    }
}

/// Zipfian sampler over ranks `0..n` (rank 0 hottest): cumulative
/// `1/(i+1)^s` table, inverted by binary search.
pub struct Zipf {
    cum: Vec<f64>,
}

impl Zipf {
    pub fn new(n: u32, s: f64) -> Self {
        let n = n.max(1);
        let mut cum = Vec::with_capacity(n as usize);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / f64::from(i + 1).powf(s);
            cum.push(acc);
        }
        for c in &mut cum {
            *c /= acc;
        }
        Self { cum }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> u32 {
        let u = rng.next_f64();
        let i = self.cum.partition_point(|&c| c < u);
        i.min(self.cum.len() - 1) as u32
    }
}

/// Outcome of a mixed run.
#[derive(Clone, Debug)]
pub struct MixedReport {
    pub profile: &'static str,
    pub ops: u64,
    pub reads: u64,
    pub inserts: u64,
    pub updates: u64,
    pub deletes: u64,
    pub docs_read: u64,
    pub docs_inserted: u64,
    pub docs_matched: u64,
    pub docs_modified: u64,
    pub docs_deleted: u64,
    pub wall_ns: u64,
    /// Per-operation end-to-end latency, all classes pooled.
    pub latency: Histogram,
    pub pes: usize,
}

impl MixedReport {
    pub fn ops_per_sec(&self) -> f64 {
        self.ops as f64 * 1e9 / self.wall_ns.max(1) as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: {} ops ({}r/{}i/{}u/{}d) in {:.2}s over {} PEs → {:.0} ops/s; \
             +{} docs, ~{} modified, -{} deleted; latency p50 {} p95 {}",
            self.profile,
            self.ops,
            self.reads,
            self.inserts,
            self.updates,
            self.deletes,
            self.wall_ns as f64 / 1e9,
            self.pes,
            self.ops_per_sec(),
            self.docs_inserted,
            self.docs_modified,
            self.docs_deleted,
            crate::util::fmt::human_duration_ns(self.latency.p50()),
            crate::util::fmt::human_duration_ns(self.latency.p95()),
        )
    }
}

/// Mixed CRUD driver. `ops` operations are split across `pes` PE
/// threads; each PE writes timestamps in its own disjoint column so
/// deletes/updates by one PE never race another PE's bookkeeping.
pub struct MixedDriver {
    pub gen: OvisGenerator,
    pub profile: MixProfile,
    pub ops: u64,
    pub pes: usize,
    /// Documents per insert operation.
    pub insert_batch: usize,
    /// Minutes covered by one read / update / delete window.
    pub window: u32,
    /// Zipf skew for node popularity (0 = uniform).
    pub zipf_s: f64,
    pub seed: u64,
}

/// Width of one PE's private timestamp column.
const PE_TS_STRIDE: u32 = 1 << 24;

impl MixedDriver {
    pub fn new(cfg: WorkloadConfig, profile: MixProfile, ops: u64, pes: usize) -> Self {
        Self {
            gen: OvisGenerator::new(cfg),
            profile,
            ops,
            pes: pes.max(1),
            insert_batch: 32,
            window: 16,
            zipf_s: 1.1,
            seed: 0xC0DE,
        }
    }

    pub fn run(&self, client: &MongoClient) -> Result<MixedReport> {
        let gen = Arc::new(self.gen.clone());
        let nodes = gen.config().monitored_nodes.max(1);
        let mix = self.profile.weights();
        let total_w = mix.total().max(1);
        let profile = self.profile;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for pe in 0..self.pes {
            let gen = gen.clone();
            let client = client.pinned(pe);
            let ops = per_pe_ops(self.ops, self.pes, pe);
            let (insert_batch, window, zipf_s, seed) =
                (self.insert_batch.max(1), self.window.max(1), self.zipf_s, self.seed);
            handles.push(std::thread::spawn(move || -> Result<PeTally> {
                let mut rng = Pcg32::new(seed, pe as u64 + 1);
                let zipf = Zipf::new(nodes, zipf_s);
                let mut tally = PeTally::default();
                // This PE's private timestamp column: inserts advance
                // `next_ts`, churn deletes trail behind at `expired`.
                let base = pe as u32 * PE_TS_STRIDE;
                let mut next_ts = base;
                let mut expired = base;
                for _ in 0..ops {
                    let pick = rng.next_bounded(total_w);
                    let t = Instant::now();
                    if pick < mix.reads {
                        let node = zipf.sample(&mut rng);
                        let (lo, hi) = span_window(&mut rng, base, next_ts, window);
                        let docs = client
                            .find(
                                window_filter(&[node], lo, hi),
                                FindOptions::default().batch_size(512),
                            )
                            .map_err(|e| anyhow::anyhow!("find: {e}"))?
                            .count();
                        tally.reads += 1;
                        tally.docs_read += docs as u64;
                    } else if pick < mix.reads + mix.inserts {
                        let batch: Vec<Document> = (0..insert_batch)
                            .map(|_| {
                                let node = zipf.sample(&mut rng);
                                let d = gen.doc(node, next_ts);
                                next_ts += 1;
                                d
                            })
                            .collect();
                        let n = batch.len();
                        client
                            .insert_many(batch)
                            .map_err(|e| anyhow::anyhow!("insert_many: {e}"))?;
                        tally.inserts += 1;
                        tally.docs_inserted += n as u64;
                    } else if pick < mix.reads + mix.inserts + mix.updates {
                        // Re-tag one hot node's recent window.
                        let node = zipf.sample(&mut rng);
                        let (lo, hi) = span_window(&mut rng, base, next_ts, window);
                        let set = Document::new()
                            .set("flag", 1i64)
                            .set("m00", rng.next_f64());
                        let rep = client
                            .update_many(window_filter(&[node], lo, hi), set)
                            .map_err(|e| anyhow::anyhow!("update_many: {e}"))?;
                        tally.updates += 1;
                        tally.docs_matched += rep.matched;
                        tally.docs_modified += rep.modified;
                    } else {
                        let filter = if profile == MixProfile::TimeWindowChurn {
                            // Expire the oldest not-yet-expired window of
                            // this PE's column, across every node.
                            let lo = expired;
                            let hi = lo.saturating_add(window).min(next_ts);
                            expired = hi;
                            ts_filter(lo, hi)
                        } else {
                            let node = zipf.sample(&mut rng);
                            let (lo, hi) = span_window(&mut rng, base, next_ts, window);
                            window_filter(&[node], lo, hi)
                        };
                        let rep = client
                            .delete_many(filter)
                            .map_err(|e| anyhow::anyhow!("delete_many: {e}"))?;
                        tally.deletes += 1;
                        tally.docs_deleted += rep.deleted;
                    }
                    tally.latency.record(t.elapsed().as_nanos() as u64);
                }
                Ok(tally)
            }));
        }
        let mut agg = PeTally::default();
        for h in handles {
            let t = h.join().expect("mixed PE panicked")?;
            agg.merge(&t);
        }
        let wall_ns = t0.elapsed().as_nanos() as u64;
        Ok(MixedReport {
            profile: self.profile.name(),
            ops: agg.reads + agg.inserts + agg.updates + agg.deletes,
            reads: agg.reads,
            inserts: agg.inserts,
            updates: agg.updates,
            deletes: agg.deletes,
            docs_read: agg.docs_read,
            docs_inserted: agg.docs_inserted,
            docs_matched: agg.docs_matched,
            docs_modified: agg.docs_modified,
            docs_deleted: agg.docs_deleted,
            wall_ns,
            latency: agg.latency,
            pes: self.pes,
        })
    }
}

#[derive(Default)]
struct PeTally {
    reads: u64,
    inserts: u64,
    updates: u64,
    deletes: u64,
    docs_read: u64,
    docs_inserted: u64,
    docs_matched: u64,
    docs_modified: u64,
    docs_deleted: u64,
    latency: Histogram,
}

impl PeTally {
    fn merge(&mut self, o: &PeTally) {
        self.reads += o.reads;
        self.inserts += o.inserts;
        self.updates += o.updates;
        self.deletes += o.deletes;
        self.docs_read += o.docs_read;
        self.docs_inserted += o.docs_inserted;
        self.docs_matched += o.docs_matched;
        self.docs_modified += o.docs_modified;
        self.docs_deleted += o.docs_deleted;
        self.latency.merge(&o.latency);
    }
}

/// Operations assigned to PE `pe` of `pes` (remainder spread left).
fn per_pe_ops(total: u64, pes: usize, pe: usize) -> u64 {
    let pes = pes as u64;
    total / pes + u64::from((pe as u64) < total % pes)
}

/// A random `window`-minute `[lo, hi)` inside `[base, next_ts)`;
/// degenerates to the first window before anything was inserted.
fn span_window(rng: &mut Pcg32, base: u32, next_ts: u32, window: u32) -> (u32, u32) {
    let span = next_ts.saturating_sub(base);
    if span == 0 {
        return (base, base + window);
    }
    let lo = base + rng.next_bounded(span);
    (lo, lo.saturating_add(window))
}

/// The canonical conditional-find shape over an explicit node list.
pub fn window_filter(nodes: &[u32], lo: u32, hi: u32) -> Filter {
    Filter::And(vec![
        Filter::is_in("node_id", nodes.iter().map(|&n| Value::Int(n as i64)).collect()),
        Filter::Cmp { field: "ts".into(), op: CmpOp::Gte, value: Value::Int(lo as i64) },
        Filter::Cmp { field: "ts".into(), op: CmpOp::Lt, value: Value::Int(hi as i64) },
    ])
}

/// Timestamp-window-only filter (node-agnostic churn deletes).
pub fn ts_filter(lo: u32, hi: u32) -> Filter {
    Filter::And(vec![
        Filter::Cmp { field: "ts".into(), op: CmpOp::Gte, value: Value::Int(lo as i64) },
        Filter::Cmp { field: "ts".into(), op: CmpOp::Lt, value: Value::Int(hi as i64) },
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Registry;
    use crate::mongo::cluster::{Cluster, ClusterSpec};
    use crate::mongo::storage::LocalDir;
    use crate::runtime::Kernels;

    #[test]
    fn zipf_prefers_low_ranks() {
        let zipf = Zipf::new(64, 1.2);
        let mut rng = Pcg32::seeded(7);
        let mut head = 0u32;
        for _ in 0..2_000 {
            let r = zipf.sample(&mut rng);
            assert!(r < 64);
            if r < 8 {
                head += 1;
            }
        }
        // With s=1.2 the top 8 of 64 ranks carry well over half the mass.
        assert!(head > 1_000, "zipf head got only {head}/2000 samples");
    }

    #[test]
    fn profiles_parse_and_weigh() {
        for p in MixProfile::ALL {
            assert_eq!(MixProfile::parse(p.name()), Some(p));
            assert!(p.weights().total() > 0);
        }
        assert_eq!(MixProfile::parse("nope"), None);
        assert!(
            MixProfile::UpdateHeavy.weights().updates
                > MixProfile::UpdateHeavy.weights().deletes
        );
        assert!(
            MixProfile::DeleteHeavy.weights().deletes
                > MixProfile::DeleteHeavy.weights().updates
        );
    }

    #[test]
    fn mixed_workload_runs_and_counts_balance() {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            |sid| Ok(Box::new(LocalDir::temp(&format!("mix-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        for profile in MixProfile::ALL {
            let cfg = WorkloadConfig {
                monitored_nodes: 16,
                metrics_per_doc: 4,
                ..Default::default()
            };
            let mut driver = MixedDriver::new(cfg, profile, 60, 2);
            driver.insert_batch = 8;
            let before = client.count_documents(Filter::True).unwrap() as u64;
            let report = driver.run(&client).unwrap();
            assert_eq!(report.ops, 60, "{}: every op must run", profile.name());
            assert!(report.inserts > 0, "{}: no inserts drawn", profile.name());
            assert!(report.docs_inserted > 0);
            assert!(report.docs_modified <= report.docs_matched);
            // The cluster-wide document count must balance the ledger:
            // inserts add, deletes remove, updates are count-neutral.
            let after = client.count_documents(Filter::True).unwrap() as u64;
            assert_eq!(
                after,
                before + report.docs_inserted - report.docs_deleted,
                "{}: count ledger out of balance",
                profile.name()
            );
        }
        cluster.shutdown();
    }
}
