//! A3 — Lustre sensitivity: OST count (DES, 256-node preset where the
//! journal traffic is heaviest) and stripe count (live accounting).

use hpcstore::benchkit::Report;
use hpcstore::config::LustreConfig;
use hpcstore::hpc::lustre::Lustre;
use hpcstore::mongo::storage::StorageDir;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::{human_bytes, human_count};

fn main() {
    let cost = CostModel::load_or_default(std::path::Path::new("artifacts")).with_network_floor();

    let mut report = Report::new("A3a — OST count vs ingest rate (DES, 256-node preset)");
    report.set_custom(
        ["OSTs", "docs/s", "OST util", "shard util", "config util"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &osts in &[4u32, 8, 16, 64, 256] {
        let mut spec = SimSpec::paper_preset(256, cost.clone()).unwrap();
        spec.osts = osts;
        spec.monitored_nodes = 1_024; // keep the sweep fast
        let r = ClusterSim::new(spec).run();
        report.add_row(vec![
            osts.to_string(),
            human_count(r.docs_per_sec as u64),
            format!("{:.0}%", r.util_ost * 100.0),
            format!("{:.0}%", r.util_shard * 100.0),
            format!("{:.0}%", r.util_config * 100.0),
        ]);
    }
    report.print();
    println!("\nfew OSTs → journal-bound; past ~16 the config/shard CPUs bind instead\n");

    // Live stripe-count accounting: same bytes, different spread.
    let mut live = Report::new("A3b — stripe count vs OST spread (live accounting, 16 MiB file)");
    live.set_custom(
        ["stripe_count", "OSTs touched", "max OST bytes", "min OST bytes"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &stripes in &[1u32, 2, 4, 8] {
        let fs = Lustre::mount(LustreConfig {
            osts: 8,
            default_stripe_count: stripes,
            stripe_size_kib: 1024,
            ..Default::default()
        })
        .unwrap();
        let dir = fs.dir("scratch/shard-0").unwrap();
        let mut f = dir.create("journal.wal").unwrap();
        f.append(&vec![0u8; 16 << 20]).unwrap();
        let written = fs.ost_written();
        let touched = written.iter().filter(|&&b| b > 0).count();
        live.add_row(vec![
            stripes.to_string(),
            touched.to_string(),
            human_bytes(*written.iter().max().unwrap()),
            human_bytes(*written.iter().filter(|&&b| b > 0).min().unwrap()),
        ]);
    }
    live.print();
}
