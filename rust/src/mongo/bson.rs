//! BSON-lite: the document model and its binary encoding.
//!
//! Documents are ordered field lists (like BSON); values cover what the
//! OVIS workload and the query engine need: null, bool, i64, f64,
//! string, array, nested document. The binary form is a compact
//! tag-prefixed encoding with explicit lengths, cheap to skip-scan.
//!
//! Wire format (little-endian):
//! ```text
//! doc    := u16 field_count, field*
//! field  := u8 name_len, name bytes, value
//! value  := tag u8, payload
//!   0 null | 1 bool(u8) | 2 i64 | 3 f64 | 4 str(u32 len, bytes)
//!   5 array(u16 count, value*) | 6 doc
//! ```

use anyhow::{bail, Result};

/// A field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    F64(f64),
    Str(String),
    Array(Vec<Value>),
    Doc(Document),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Total ordering for index keys and comparisons: type class first
    /// (null < numbers < strings < arrays < docs), numeric classes
    /// compare by value across Int/F64.
    pub fn type_rank(&self) -> u8 {
        match self {
            Value::Null => 0,
            Value::Bool(_) => 1,
            Value::Int(_) | Value::F64(_) => 2,
            Value::Str(_) => 3,
            Value::Array(_) => 4,
            Value::Doc(_) => 5,
        }
    }

    /// Compare two values under the total order. `None` only for NaN.
    pub fn cmp_total(&self, other: &Value) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        let (ra, rb) = (self.type_rank(), other.type_rank());
        if ra != rb {
            return ra.cmp(&rb);
        }
        match (self, other) {
            (Value::Null, Value::Null) => Equal,
            (Value::Bool(a), Value::Bool(b)) => a.cmp(b),
            (a, b) if ra == 2 => {
                let (x, y) = (a.as_f64().unwrap(), b.as_f64().unwrap());
                x.partial_cmp(&y).unwrap_or(Equal)
            }
            (Value::Str(a), Value::Str(b)) => a.cmp(b),
            (Value::Array(a), Value::Array(b)) => {
                for (x, y) in a.iter().zip(b) {
                    let o = x.cmp_total(y);
                    if o != Equal {
                        return o;
                    }
                }
                a.len().cmp(&b.len())
            }
            (Value::Doc(a), Value::Doc(b)) => {
                for ((ka, va), (kb, vb)) in a.fields.iter().zip(&b.fields) {
                    let o = ka.cmp(kb).then_with(|| va.cmp_total(vb));
                    if o != Equal {
                        return o;
                    }
                }
                a.fields.len().cmp(&b.fields.len())
            }
            _ => Equal,
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// An ordered document.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Document {
    pub fields: Vec<(String, Value)>,
}

impl Document {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style append (replaces an existing field of that name).
    pub fn set(mut self, name: &str, value: impl Into<Value>) -> Self {
        self.put(name, value);
        self
    }

    pub fn put(&mut self, name: &str, value: impl Into<Value>) {
        let value = value.into();
        for (k, v) in self.fields.iter_mut() {
            if k == name {
                *v = value;
                return;
            }
        }
        self.fields.push((name.to_string(), value));
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.fields.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(Value::as_f64)
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Project onto the named fields (keeping document order).
    pub fn project(&self, names: &[String]) -> Document {
        Document {
            fields: self
                .fields
                .iter()
                .filter(|(k, _)| names.iter().any(|n| n == k))
                .cloned()
                .collect(),
        }
    }

    /// Encode to the binary wire form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    pub fn encode_into(&self, out: &mut Vec<u8>) {
        assert!(self.fields.len() <= u16::MAX as usize, "too many fields");
        out.extend_from_slice(&(self.fields.len() as u16).to_le_bytes());
        for (name, value) in &self.fields {
            assert!(name.len() <= u8::MAX as usize, "field name too long");
            out.push(name.len() as u8);
            out.extend_from_slice(name.as_bytes());
            encode_value(value, out);
        }
    }

    /// Exact size of [`Self::encode`] output (used for wire accounting
    /// without encoding).
    pub fn encoded_len(&self) -> usize {
        2 + self
            .fields
            .iter()
            .map(|(n, v)| 1 + n.len() + value_len(v))
            .sum::<usize>()
    }

    pub fn decode(bytes: &[u8]) -> Result<Document> {
        let mut cur = Cursor { bytes, pos: 0 };
        let doc = decode_doc(&mut cur)?;
        if cur.pos != bytes.len() {
            bail!("trailing bytes after document");
        }
        Ok(doc)
    }
}

fn value_len(v: &Value) -> usize {
    1 + match v {
        Value::Null => 0,
        Value::Bool(_) => 1,
        Value::Int(_) => 8,
        Value::F64(_) => 8,
        Value::Str(s) => 4 + s.len(),
        Value::Array(items) => 2 + items.iter().map(value_len).sum::<usize>(),
        Value::Doc(d) => d.encoded_len(),
    }
}

fn encode_value(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Null => out.push(0),
        Value::Bool(b) => {
            out.push(1);
            out.push(*b as u8);
        }
        Value::Int(i) => {
            out.push(2);
            out.extend_from_slice(&i.to_le_bytes());
        }
        Value::F64(f) => {
            out.push(3);
            out.extend_from_slice(&f.to_le_bytes());
        }
        Value::Str(s) => {
            out.push(4);
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        Value::Array(items) => {
            out.push(5);
            assert!(items.len() <= u16::MAX as usize);
            out.extend_from_slice(&(items.len() as u16).to_le_bytes());
            for item in items {
                encode_value(item, out);
            }
        }
        Value::Doc(d) => {
            out.push(6);
            d.encode_into(out);
        }
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated document (need {n} bytes at {})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

fn decode_doc(cur: &mut Cursor) -> Result<Document> {
    let count = cur.u16()? as usize;
    let mut fields = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = cur.u8()? as usize;
        let name = std::str::from_utf8(cur.take(name_len)?)?.to_string();
        let value = decode_value(cur)?;
        fields.push((name, value));
    }
    Ok(Document { fields })
}

fn decode_value(cur: &mut Cursor) -> Result<Value> {
    Ok(match cur.u8()? {
        0 => Value::Null,
        1 => Value::Bool(cur.u8()? != 0),
        2 => Value::Int(i64::from_le_bytes(cur.take(8)?.try_into().unwrap())),
        3 => Value::F64(f64::from_le_bytes(cur.take(8)?.try_into().unwrap())),
        4 => {
            let len = cur.u32()? as usize;
            Value::Str(std::str::from_utf8(cur.take(len)?)?.to_string())
        }
        5 => {
            let count = cur.u16()? as usize;
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(decode_value(cur)?);
            }
            Value::Array(items)
        }
        6 => Value::Doc(decode_doc(cur)?),
        t => bail!("unknown value tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Document {
        Document::new()
            .set("ts", 25_246_080i64)
            .set("node_id", 1234i64)
            .set("cpu_user", 0.37)
            .set("hostname", "nid01234")
            .set("flags", Value::Array(vec![Value::Bool(true), Value::Int(7)]))
            .set(
                "nested",
                Value::Doc(Document::new().set("a", 1i64).set("b", "x")),
            )
            .set("none", Value::Null)
    }

    #[test]
    fn round_trip() {
        let d = sample();
        let bytes = d.encode();
        assert_eq!(bytes.len(), d.encoded_len());
        let d2 = Document::decode(&bytes).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn put_replaces() {
        let mut d = Document::new().set("a", 1i64);
        d.put("a", 2i64);
        assert_eq!(d.len(), 1);
        assert_eq!(d.get_i64("a"), Some(2));
    }

    #[test]
    fn field_order_preserved() {
        let d = Document::new().set("z", 1i64).set("a", 2i64);
        assert_eq!(d.fields[0].0, "z");
        let d2 = Document::decode(&d.encode()).unwrap();
        assert_eq!(d2.fields[0].0, "z");
    }

    #[test]
    fn projection() {
        let d = sample();
        let p = d.project(&["ts".to_string(), "hostname".to_string()]);
        assert_eq!(p.len(), 2);
        assert!(p.get("cpu_user").is_none());
    }

    #[test]
    fn numeric_cross_type_compare() {
        use std::cmp::Ordering::*;
        assert_eq!(Value::Int(2).cmp_total(&Value::F64(2.0)), Equal);
        assert_eq!(Value::Int(2).cmp_total(&Value::F64(2.5)), Less);
        assert_eq!(Value::F64(3.0).cmp_total(&Value::Int(2)), Greater);
        // Type classes: numbers < strings.
        assert_eq!(Value::Int(999).cmp_total(&Value::Str("a".into())), Less);
        assert_eq!(Value::Null.cmp_total(&Value::Bool(false)), Less);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Document::decode(&[]).is_err());
        assert!(Document::decode(&[1, 0]).is_err()); // count=1, truncated
        let mut ok = sample().encode();
        ok.push(0xFF); // trailing byte
        assert!(Document::decode(&ok).is_err());
        // Unknown tag.
        assert!(Document::decode(&[1, 0, 1, b'a', 99]).is_err());
    }

    #[test]
    fn encoded_len_matches_for_everything() {
        use crate::testing::{check, gens, Gen};
        use crate::util::rng::Pcg32;
        check(
            "encoded-len",
            &(|rng: &mut Pcg32| {
                let mut d = Document::new();
                let n = rng.next_bounded(10);
                for i in 0..n {
                    let v = match rng.next_bounded(5) {
                        0 => Value::Null,
                        1 => Value::Int(rng.next_u64() as i64),
                        2 => Value::F64(rng.next_f64()),
                        3 => Value::Str(gens::ident(12).generate(rng)),
                        _ => Value::Array(vec![Value::Int(1), Value::Null]),
                    };
                    d.put(&format!("f{i}"), v);
                }
                d
            }),
            |d| {
                let bytes = d.encode();
                if bytes.len() != d.encoded_len() {
                    return Err(format!("len {} != {}", bytes.len(), d.encoded_len()));
                }
                let d2 = Document::decode(&bytes).map_err(|e| e.to_string())?;
                if &d2 != d {
                    return Err("round trip mismatch".into());
                }
                Ok(())
            },
        );
    }
}
