//! The flat-CSV corpus on the shared filesystem.
//!
//! "Storage for this data in flat csv file on Blue Waters Luster
//! filesystem is about 200 terabytes" — ingest clients read their slice
//! of these files and convert rows to documents ("A[n] insertMany is
//! performed by collecting a list of python dictionaries from the
//! metric data csv file"). We write one CSV file per corpus day-slice
//! so client PEs stream disjoint files.

use anyhow::{Context, Result};

use super::ovis::OvisGenerator;
use crate::mongo::bson::Document;
use crate::mongo::storage::StorageDir;

/// File name for minute-slice `[t0, t1)`.
fn slice_name(t0: u32, t1: u32) -> String {
    format!("ovis_{t0:07}_{t1:07}.csv")
}

/// Write the corpus as CSV slices of `minutes_per_file` each.
/// Returns the file names written.
pub fn write_corpus(
    gen: &OvisGenerator,
    dir: &dyn StorageDir,
    minutes_per_file: u32,
) -> Result<Vec<String>> {
    let total_minutes = gen.config().minutes();
    let mut files = Vec::new();
    let mut t0 = 0;
    while t0 < total_minutes {
        let t1 = (t0 + minutes_per_file).min(total_minutes);
        let name = slice_name(t0, t1);
        let mut f = dir.create(&name)?;
        let mut buf = gen.csv_header();
        buf.push('\n');
        for t in t0..t1 {
            for node in 0..gen.config().monitored_nodes {
                buf.push_str(&gen.csv_row(node, t));
                buf.push('\n');
                if buf.len() > 1 << 20 {
                    f.append(buf.as_bytes())?;
                    buf.clear();
                }
            }
        }
        f.append(buf.as_bytes())?;
        f.sync()?;
        files.push(name);
        t0 = t1;
    }
    Ok(files)
}

/// Parse one CSV slice back into documents (the ingest client's
/// dictionary-building step). `metrics_per_doc` columns are read; the
/// header row defines field names.
pub fn read_slice(dir: &dyn StorageDir, name: &str) -> Result<Vec<Document>> {
    let raw = dir.read(name).with_context(|| format!("reading corpus slice {name}"))?;
    let text = std::str::from_utf8(&raw)?;
    let mut lines = text.lines();
    let header = lines.next().context("empty csv")?;
    let fields: Vec<&str> = header.split(',').collect();
    anyhow::ensure!(fields.len() >= 2, "csv header too short");
    let mut docs = Vec::new();
    for (lineno, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut doc = Document::new();
        for (i, col) in line.split(',').enumerate() {
            let name = *fields
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("row {lineno}: too many columns"))?;
            if i < 2 {
                let v: i64 = col
                    .parse()
                    .with_context(|| format!("row {lineno} col {name}: bad int `{col}`"))?;
                doc.put(name, v);
            } else {
                let v: f64 = col
                    .parse()
                    .with_context(|| format!("row {lineno} col {name}: bad float `{col}`"))?;
                doc.put(name, v);
            }
        }
        docs.push(doc);
    }
    Ok(docs)
}

/// Corpus size on the filesystem (the paper's "200 terabytes" figure,
/// scaled).
pub fn corpus_bytes(gen: &OvisGenerator) -> u64 {
    let row = gen.csv_row(0, 0).len() as u64 + 1;
    row * gen.total_docs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::mongo::storage::LocalDir;

    fn small_gen() -> OvisGenerator {
        OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 4,
            metrics_per_doc: 5,
            days: 10.0 / 1440.0, // 10 minutes
            ..Default::default()
        })
    }

    #[test]
    fn write_then_read_round_trips() {
        let gen = small_gen();
        let dir = LocalDir::temp("csv").unwrap();
        let files = write_corpus(&gen, &dir, 4).unwrap();
        assert_eq!(files.len(), 3); // 4 + 4 + 2 minutes
        let mut total = 0;
        for f in &files {
            let docs = read_slice(&dir, f).unwrap();
            total += docs.len();
            for d in &docs {
                assert!(d.get_i64("ts").is_some());
                assert!(d.get_i64("node_id").is_some());
                assert!(d.get_f64("m04").is_some());
            }
        }
        assert_eq!(total as u64, gen.total_docs());
        // First doc of first file matches the generator (to CSV 4-decimal
        // precision).
        let docs = read_slice(&dir, &files[0]).unwrap();
        let want = gen.doc(0, 0);
        assert_eq!(docs[0].get_i64("ts"), want.get_i64("ts"));
        let a = docs[0].get_f64("m00").unwrap();
        let b = want.get_f64("m00").unwrap();
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }

    #[test]
    fn corpus_bytes_scales_with_rows() {
        let gen = small_gen();
        let est = corpus_bytes(&gen);
        assert!(est > 0);
        // 40 docs, each a few dozen bytes.
        assert!(est > 40 * 20 && est < 40 * 200, "{est}");
    }

    #[test]
    fn read_rejects_malformed_rows() {
        let dir = LocalDir::temp("csv-bad").unwrap();
        dir.write_atomic("bad.csv", b"ts,node_id,m00\n1,2,not-a-number\n").unwrap();
        assert!(read_slice(&dir, "bad.csv").is_err());
    }
}
