//! F3 — regenerate Figure 3: concurrent conditional-find latency vs
//! cluster size, plus the read-path axis.
//!
//! Paper: "cluster size maintains a similar query performance for
//! various MongoDB cluster sizes ... each cluster size is servicing
//! more concurrent quarries" (32 nodes → up to 64 concurrent finds,
//! 64 → up to 128, and so on). The DES scales concurrency with client
//! PEs and the latency distribution should stay roughly flat.
//!
//! The second DES table sweeps the **read-path regimes** at one cluster
//! size: the pre-overhaul single-index plan with a decode per candidate,
//! the raw (zero-copy) matcher over the same plan, and the compound
//! `(node_id, ts)` plan where candidates == matches. The live
//! cross-check runs the same sweep on a real mini-cluster and reads the
//! planner/decode counters (`shard.find_candidates` vs
//! `shard.find_matches`, `shard.find_decodes`) so the candidate ratio
//! and decode-per-result are visible, not inferred.
//!
//! The final live table is the **reader-pool axis** (EXPERIMENTS.md
//! §3b): a background writer sustains ingest while query workers run,
//! sweeping `--reader-threads` 0 (reads inline on the shard event
//! loop) vs 2 (reads served off-loop from pinned MVCC snapshots). The
//! writer's documents carry timestamps outside every job window, so
//! the count checks stay exact while the writer contends.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::names;
use hpcstore::mongo::bson::Document;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_duration_ns;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::{IngestDriver, QueryDriver};

fn main() {
    let cost = CostModel::load_or_default(std::path::Path::new("artifacts")).with_network_floor();
    let mut report = Report::new("Figure 3 — concurrent conditional-find latency (DES)");
    report.set_custom(
        ["nodes", "concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for nodes in [32u32, 64, 128, 256] {
        let spec = SimSpec::paper_preset(nodes, cost.clone()).unwrap();
        let r = ClusterSim::new(spec).run();
        report.add_row(r.query_row());
    }
    report.print();
    println!("\npaper: similar latency across cluster sizes despite proportional concurrency — shape reproduced\n");

    // Read-path axis (DES, 64 nodes): what the compound plan and the
    // raw matcher each buy on the canonical shape.
    let mut axis = Report::new("Figure 3b — read-path axis (DES, 64 nodes)");
    axis.set_custom(
        ["plan", "finds/s", "p50", "p95", "p99"].iter().map(|s| s.to_string()).collect(),
    );
    for (label, compound, raw) in [
        ("single-index + decode per candidate (pre-overhaul)", false, false),
        ("single-index + raw matcher", false, true),
        ("compound (node_id, ts) + raw (current)", true, true),
    ] {
        let mut spec = SimSpec::paper_preset(64, cost.clone()).unwrap();
        spec.compound_index = compound;
        spec.raw_match = raw;
        let r = ClusterSim::new(spec).run();
        axis.add_row(vec![
            label.to_string(),
            format!("{:.1}", r.queries_per_sec),
            human_duration_ns(r.query_latency.p50()),
            human_duration_ns(r.query_latency.p95()),
            human_duration_ns(r.query_latency.p99()),
        ]);
    }
    axis.print();

    if quick_mode() {
        return;
    }
    // Live cross-check 1: one cluster, concurrency sweep.
    let kernels = Kernels::load_or_fallback("artifacts");
    let cluster = Cluster::start(
        ClusterSpec::small(3, 2),
        |sid| Ok(Box::new(LocalDir::temp(&format!("f3-{sid}"))?)),
        kernels,
        Registry::new(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
    let wl = WorkloadConfig {
        monitored_nodes: 128,
        metrics_per_doc: 20,
        days: 30.0 / 1440.0,
        query_jobs: 32,
        ..Default::default()
    };
    IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 4)
        .run(&client)
        .unwrap();
    let mut live = Report::new("Figure 3 cross-check — live cluster, concurrency sweep");
    live.set_custom(
        ["concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for conc in [1usize, 2, 4, 8] {
        let rep = QueryDriver::new(generate_jobs(&wl), conc).run(&client).unwrap();
        assert_eq!(rep.count_mismatches, 0);
        live.add_row(vec![
            conc.to_string(),
            rep.queries.to_string(),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            human_duration_ns(rep.latency.p95()),
            human_duration_ns(rep.latency.p99()),
        ]);
    }
    live.print();
    cluster.shutdown();

    // Live cross-check 2: index-plan sweep on identical corpora. The
    // candidate ratio and decode count come from the shard counters —
    // compound must show candidates == matches and one decode per
    // returned document.
    let mut plans = Report::new("Figure 3c — live read-path sweep (plan vs overscan vs decodes)");
    plans.set_custom(
        ["indexes", "finds/s", "p50", "cand/match", "decodes/doc"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let cases: Vec<(&str, Vec<IndexSpec>)> = vec![
        (
            "ts + node_id singles (intersection)",
            vec![IndexSpec::single("ts"), IndexSpec::single("node_id")],
        ),
        ("compound (node_id, ts)", vec![IndexSpec::compound(&["node_id", "ts"])]),
    ];
    for (label, specs) in cases {
        let metrics = Registry::new();
        let cluster = Cluster::start(
            ClusterSpec::small(2, 1),
            |sid| Ok(Box::new(LocalDir::temp(&format!("f3c-{sid}"))?)),
            Kernels::fallback(),
            metrics.clone(),
        )
        .unwrap();
        let client = cluster.client();
        for spec in &specs {
            client.create_index(spec.clone()).unwrap();
        }
        let wl = WorkloadConfig {
            monitored_nodes: 128,
            metrics_per_doc: 20,
            days: 20.0 / 1440.0,
            query_jobs: 16,
            ..Default::default()
        };
        IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 2)
            .run(&client)
            .unwrap();
        let before_cand = metrics.counter("shard.find_candidates").get();
        let before_match = metrics.counter("shard.find_matches").get();
        let before_dec = metrics.counter("shard.find_decodes").get();
        let rep = QueryDriver::new(generate_jobs(&wl), 4).run(&client).unwrap();
        assert_eq!(rep.count_mismatches, 0);
        let cand = metrics.counter("shard.find_candidates").get() - before_cand;
        let matched = metrics.counter("shard.find_matches").get() - before_match;
        let decodes = metrics.counter("shard.find_decodes").get() - before_dec;
        plans.add_row(vec![
            label.to_string(),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            format!("{:.3}", cand as f64 / matched.max(1) as f64),
            format!("{:.3}", decodes as f64 / rep.docs_returned.max(1) as f64),
        ]);
        cluster.shutdown();
    }
    plans.print();

    // Live cross-check 3 (EXPERIMENTS.md §3b): the reader-pool axis
    // under a live mixed workload — sustained background ingest while
    // query workers drain conditional finds. Row 0 is the pre-MVCC
    // behaviour (reads inline on the event loop, queueing behind group
    // commits); row 2 serves reads from pinned snapshots off-loop.
    let mut mixed =
        Report::new("Figure 3d — reader-pool axis (live mixed ingest + query)");
    mixed.set_custom(
        ["reader threads", "ingest docs/s", "finds/s", "read p50", "read p99", "snapshot reads"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for readers in [0usize, 2] {
        let metrics = Registry::new();
        let mut cspec = ClusterSpec::small(2, 1);
        cspec.store.reader_threads = readers;
        let cluster = Cluster::start(
            cspec,
            |sid| Ok(Box::new(LocalDir::temp(&format!("f3d-{readers}-{sid}"))?)),
            Kernels::fallback(),
            metrics.clone(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::compound(&["node_id", "ts"])).unwrap();
        let wl = WorkloadConfig {
            monitored_nodes: 128,
            metrics_per_doc: 20,
            days: 20.0 / 1440.0,
            query_jobs: 24,
            ..Default::default()
        };
        IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 2)
            .run(&client)
            .unwrap();
        // Background writer: keeps committing while the queries run.
        // Timestamps start far past every job window, so the count
        // verification in QueryDriver stays exact under the contention.
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let client = client.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || -> (u64, u64) {
                let t0 = Instant::now();
                let mut docs = 0u64;
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let batch: Vec<Document> = (0..500)
                        .map(|j| {
                            let n = i * 500 + j;
                            Document::new()
                                .set("ts", 10_000_000 + n as i64)
                                .set("node_id", (n % 128) as i64)
                                .set("m0", n as f64)
                        })
                        .collect();
                    docs += batch.len() as u64;
                    client.insert_many(batch).expect("background ingest");
                    i += 1;
                }
                (docs, t0.elapsed().as_nanos() as u64)
            })
        };
        let rep = QueryDriver::new(generate_jobs(&wl), 4).run(&client).unwrap();
        stop.store(true, Ordering::Relaxed);
        let (w_docs, w_ns) = writer.join().expect("writer thread");
        assert_eq!(rep.count_mismatches, 0, "snapshot reads must stay exact under ingest");
        let snap_reads = metrics.counter(names::SHARD_SNAPSHOT_READS).get();
        mixed.add_row(vec![
            if readers == 0 { "0 (inline)".to_string() } else { readers.to_string() },
            format!("{:.0}", w_docs as f64 * 1e9 / w_ns.max(1) as f64),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            human_duration_ns(rep.latency.p99()),
            snap_reads.to_string(),
        ]);
        cluster.shutdown();
    }
    mixed.print();
    println!(
        "\nclaim: with --reader-threads > 0 finds are served from pinned MVCC snapshots \
         off the event loop — read p99 stops queueing behind group commits while counts \
         stay exact\n"
    );
}
