//! Recursive-descent JSON parser (RFC 8259).

use std::collections::BTreeMap;
use std::fmt;

use super::Value;

/// Parse failure with byte offset and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected `{word}`)")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-7").unwrap(), Value::Int(-7));
        assert_eq!(parse("1.5").unwrap(), Value::Num(1.5));
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.at(&["c"]).unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"q\"\\""#).unwrap(),
            Value::Str("a\nb\t\"q\"\\".into())
        );
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        // Surrogate pair: U+1F600.
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo → 世界\"").unwrap(), Value::Str("héllo → 世界".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Value::object());
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("[ ]").unwrap(), Value::Array(vec![]));
    }

    #[test]
    fn big_int_falls_back_to_float() {
        let v = parse("99999999999999999999999").unwrap();
        assert!(matches!(v, Value::Num(_)));
    }

    #[test]
    fn errors_carry_offsets() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "01x", "[1 2]", "", "{} extra"] {
            let e = parse(bad).unwrap_err();
            assert!(!e.message.is_empty(), "{bad}");
        }
    }

    #[test]
    fn rejects_control_chars() {
        assert!(parse("\"a\u{0001}b\"").is_err());
    }
}
