"""Pallas kernel: columnar conditional-find predicate evaluation.

The paper's query workload is a conditional find on the two indexed
fields: ``timestamp in [job_start, job_end)`` AND ``node_id in
job_nodes``. On the shard scan path (and for post-index refinement) the
predicate is evaluated over columnar batches.

TPU adaptation: the node-id set is a u32 bitmap resident in VMEM (the
candidate sets are drawn from ~28k Blue Waters nodes → 1024 words covers
32k ids), so membership is a vectorized word-gather + bit test instead of
a per-document hash-set probe; the timestamp range check is a dense lane
compare. Everything is mask arithmetic — no divergent control flow.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _filter_kernel(ts_ref, node_ref, lo_ref, hi_ref, bitmap_ref, mask_ref):
    ts = ts_ref[...]
    node = node_ref[...]
    lo = lo_ref[0]
    hi = hi_ref[0]
    bitmap = bitmap_ref[...]
    word = jnp.take(bitmap, (node >> 5).astype(jnp.int32))
    bit = (word >> (node & 31)) & 1
    in_range = (lo <= ts) & (ts < hi)
    mask_ref[...] = (in_range & (bit == 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def filter_scan(ts_min, node_id, ts_lo, ts_hi, node_bitmap, *, block_b=1024):
    """Evaluate the conditional-find predicate over a columnar batch.

    Args:
      ts_min:      u32[B] document timestamps (epoch minutes).
      node_id:     u32[B] document node ids.
      ts_lo/ts_hi: u32[1] half-open timestamp range.
      node_bitmap: u32[W] membership bitmap (bit ``n`` of word ``n>>5``).
      block_b:     batch tile size (must divide B).

    Returns:
      (mask i32[B], count i32[1]).
    """
    b = ts_min.shape[0]
    w = node_bitmap.shape[0]
    if b % block_b:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    grid = (b // block_b,)
    mask = pl.pallas_call(
        _filter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(ts_min, node_id, ts_lo, ts_hi, node_bitmap)
    return mask, jnp.sum(mask, dtype=jnp.int32)[None]
