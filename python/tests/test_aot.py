"""AOT lowering sanity: every artifact lowers to parseable HLO text and
the manifest mirrors the model shapes."""

import json

from compile import aot, model


def test_artifact_defs_cover_all_models():
    names = set(aot.artifact_defs().keys())
    assert names == {
        f"route_b{model.ROUTE_B}_c{model.ROUTE_C}_s{model.ROUTE_S}",
        f"filter_b{model.FILTER_B}_w{model.FILTER_W}",
        f"stats_b{model.STATS_B}_m{model.STATS_M}",
    }


def test_lowering_produces_hlo_text():
    for name, (fn, in_specs, _out) in aot.artifact_defs().items():
        text = aot.lower_artifact(name, fn, in_specs)
        assert text.startswith("HloModule"), name
        assert "ROOT" in text, name
        # return_tuple=True → root computation returns a tuple.
        assert "tuple" in text, name


def test_manifest_round_trip(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["shapes"]["route_b"] == model.ROUTE_B
    for name, meta in manifest["artifacts"].items():
        assert (tmp_path / meta["file"]).exists()
        text = (tmp_path / meta["file"]).read_text()
        assert text.startswith("HloModule")
        assert len(meta["inputs"]) >= 1
