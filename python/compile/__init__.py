"""Build-time compile path: JAX/Pallas authoring + AOT lowering.

Nothing in this package is imported at request time; ``make artifacts``
runs :mod:`compile.aot` once and the Rust binary consumes the HLO text it
writes to ``artifacts/``.
"""
