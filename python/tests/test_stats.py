"""batch_stats Pallas kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.batch_stats import batch_stats
from compile import model

RNG = np.random.default_rng(0x57A7)


def run_both(x):
    x = jnp.asarray(x)
    mn_k, mx_k, mean_k = batch_stats(x)
    mn_r, mx_r, mean_r = ref.stats_ref(x)
    return map(np.asarray, (mn_k, mx_k, mean_k, mn_r, mx_r, mean_r))


def test_kernel_matches_ref_default_shapes():
    x = RNG.normal(size=(model.STATS_B, model.STATS_M)).astype(np.float32)
    mn_k, mx_k, mean_k, mn_r, mx_r, mean_r = run_both(x)
    np.testing.assert_array_equal(mn_k, mn_r)
    np.testing.assert_array_equal(mx_k, mx_r)
    np.testing.assert_allclose(mean_k, mean_r, rtol=1e-6)


def test_constant_column():
    x = np.full((256, 4), 3.5, dtype=np.float32)
    mn_k, mx_k, mean_k, *_ = run_both(x)
    assert (mn_k == 3.5).all() and (mx_k == 3.5).all()
    np.testing.assert_allclose(mean_k, 3.5, rtol=1e-6)


def test_extreme_values():
    x = np.array([[1e30, -1e30], [-1e30, 1e30], [0.0, 0.0], [1.0, -1.0]], dtype=np.float32)
    mn_k, mx_k, _, mn_r, mx_r, _ = run_both(x)
    np.testing.assert_array_equal(mn_k, mn_r)
    np.testing.assert_array_equal(mx_k, mx_r)
    np.testing.assert_array_equal(mn_k, np.array([-1e30, -1e30], dtype=np.float32))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    b=st.sampled_from([8, 64, 256]),
    m=st.sampled_from([1, 4, 16]),
    scale=st.floats(min_value=1e-3, max_value=1e6),
)
def test_property_kernel_equals_ref(seed, b, m, scale):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(b, m)) * scale).astype(np.float32)
    mn_k, mx_k, mean_k, mn_r, mx_r, mean_r = run_both(x)
    np.testing.assert_array_equal(mn_k, mn_r)
    np.testing.assert_array_equal(mx_k, mx_r)
    np.testing.assert_allclose(mean_k, mean_r, rtol=1e-5, atol=1e-5)
