//! `hpcstore` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `deploy`    — live cluster on this machine: scheduler job → run
//!                 script → ingest → conditional finds → teardown.
//! * `sim`       — paper-scale DES sweep (Figures 2 and 3).
//! * `calibrate` — measure the live cost model for the DES.
//! * `table1`    — print the paper's Table 1 presets and workload sizes.

#![deny(unsafe_code)]

use anyhow::Result;

use hpcstore::cli::{Args, Cli, CommandSpec, FlagSpec};
use hpcstore::config::{
    LustreConfig, ReadPreference, ShardKeyKind, StoreConfig, Topology, WorkloadConfig,
    WriteConcern, TABLE1,
};
use hpcstore::hpc::lustre::Lustre;
use hpcstore::hpc::runscript::RunScript;
use hpcstore::hpc::scheduler::{Job, Scheduler};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::{human_count, markdown_table};
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::{IngestDriver, QueryDriver};

fn cli() -> Cli {
    let f = |name, hint, help| FlagSpec { name, value_hint: hint, help };
    Cli {
        binary: "hpcstore",
        about: "sharded document store as a queued job on a shared HPC architecture",
        commands: vec![
            CommandSpec {
                name: "deploy",
                about: "run a live cluster end-to-end on this machine",
                flags: vec![
                    f("shards", Some("N"), "shard servers (default 3)"),
                    f("routers", Some("N"), "router servers (default 2)"),
                    f("pes", Some("N"), "client processing elements (default 4)"),
                    f("monitored", Some("N"), "monitored nodes in the corpus (default 128)"),
                    f("minutes", Some("N"), "minutes of data (default 30)"),
                    f("shard-key", Some("KIND"), "shard key: hashed|ranged (default hashed)"),
                    f(
                        "max-chunk-docs",
                        Some("N"),
                        "split a chunk once it holds this many docs (default 100000)",
                    ),
                    f("no-journal", None, "disable write-ahead journaling on shards"),
                    f(
                        "compress-checkpoints",
                        None,
                        "compress checkpoint blocks (in-tree LZSS codec)",
                    ),
                    f("batch-size", Some("N"), "insertMany batch size (default 1000)"),
                    f(
                        "router-flush-docs",
                        Some("N"),
                        "router ingest-buffer flush threshold in docs (default 4096)",
                    ),
                    f("cursor-batch", Some("N"), "find cursor batch size (default 1000)"),
                    f("no-balancer", None, "disable the chunk balancer"),
                    f(
                        "flush-interval-ms",
                        Some("MS"),
                        "router ingest-buffer flush deadline (default 2)",
                    ),
                    f(
                        "buffered",
                        None,
                        "route ingest through the router's group-commit buffer",
                    ),
                    f(
                        "checkpoint-bytes",
                        Some("BYTES"),
                        "auto-compact a shard after this much journal (default 64 MiB, 0 = off)",
                    ),
                    f(
                        "journal-segments",
                        Some("N"),
                        "journal segments per checkpoint interval (default 4)",
                    ),
                    f(
                        "checkpoint-chain",
                        Some("N"),
                        "delta checkpoints per chain before a full rebase (default 8, 0 = always full)",
                    ),
                    f(
                        "migration-batch-docs",
                        Some("N"),
                        "docs per streaming chunk-migration batch (default 1024)",
                    ),
                    f(
                        "balancer-bytes",
                        Some("BYTES"),
                        "byte-aware balancer: move chunks past this per-shard byte spread (default 256 MiB, 0 = count-only)",
                    ),
                    f(
                        "reader-threads",
                        Some("N"),
                        "per-shard reader pool serving finds/counts off the event loop (default 0 = inline)",
                    ),
                    f(
                        "snapshot-retention",
                        Some("N"),
                        "commits an open snapshot may lag before expiring with a retryable error (default 0 = unbounded)",
                    ),
                    f(
                        "agg-partial",
                        Some("BOOL"),
                        "aggregation push-down: shards ship per-group partial accumulators (default true; false = ship matching docs, full-ship baseline)",
                    ),
                    f(
                        "replicas",
                        Some("N"),
                        "members per replica set: 1 primary + N-1 oplog-tailing secondaries (default 1 = unreplicated; >1 disables the balancer)",
                    ),
                    f(
                        "write-concern",
                        Some("W"),
                        "write acknowledgement level: 1 (primary durable) | majority (majority durable, survives failover; default)",
                    ),
                    f(
                        "read-preference",
                        Some("PREF"),
                        "member reads target: primary (default) | secondary (snapshot reads, may lag)",
                    ),
                    f(
                        "write-retry-ms",
                        Some("MS"),
                        "router write-retry deadline past StaleVersion/MigrationInFlight/NotPrimary rejects (default 2000)",
                    ),
                    f(
                        "election-timeout-ms",
                        Some("MS"),
                        "election timeout base: a quiet secondary stands for election after [t, 2t) ms (default 150)",
                    ),
                    f(
                        "heartbeat-ms",
                        Some("MS"),
                        "primary heartbeat/replication interval (default 50)",
                    ),
                    f("artifacts", Some("DIR"), "AOT artifact dir (default artifacts)"),
                    f("fallback", None, "use the scalar kernel fallback"),
                ],
            },
            CommandSpec {
                name: "sim",
                about: "paper-scale DES sweep (Fig 2 + Fig 3)",
                flags: vec![
                    f("nodes", Some("N|all"), "cluster size 32|64|128|256|all (default all)"),
                    f("monitored", Some("N"), "monitored nodes, sim-scaled (default 2048)"),
                    f("chunk-docs", Some("N"), "split threshold (default 45000)"),
                    f("osts", Some("N"), "OST count (default 64)"),
                    f("costmodel", Some("PATH"), "costmodel.json (default artifacts/)"),
                ],
            },
            CommandSpec {
                name: "calibrate",
                about: "measure the live cost model for the DES",
                flags: vec![
                    f("out", Some("PATH"), "output path (default artifacts/costmodel.json)"),
                    f("quick", None, "fewer samples"),
                    f("artifacts", Some("DIR"), "AOT artifact dir (default artifacts)"),
                    f("fallback", None, "calibrate against the scalar fallback"),
                ],
            },
            CommandSpec {
                name: "table1",
                about: "print the paper's Table 1 with realized corpus sizes",
                flags: vec![f("monitored", Some("N"), "monitored nodes (default 2048)")],
            },
        ],
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(args) = cli().parse(&argv)? else { return Ok(()) };
    match args.command.as_str() {
        "deploy" => cmd_deploy(&args),
        "sim" => cmd_sim(&args),
        "calibrate" => cmd_calibrate(&args),
        "table1" => cmd_table1(&args),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn load_kernels(args: &Args) -> Kernels {
    if args.has_switch("fallback") {
        Kernels::fallback()
    } else {
        Kernels::load_or_fallback(args.get_or("artifacts", "artifacts"))
    }
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let shards = args.get_u64("shards")?.unwrap_or(3) as u32;
    let routers = args.get_u64("routers")?.unwrap_or(2) as u32;
    let pes = args.get_u64("pes")?.unwrap_or(4) as u32;
    let monitored = args.get_u64("monitored")?.unwrap_or(128) as u32;
    let minutes = args.get_u64("minutes")?.unwrap_or(30);
    let batch = args.get_u64_or("batch-size", 1000)? as usize;
    let flush_interval_ms = args.get_u64_or("flush-interval-ms", 2)?;
    let buffered = args.has_switch("buffered");

    let kernels = load_kernels(args);
    println!("kernel backend: {:?}", kernels.backend());

    let lustre = Lustre::mount(LustreConfig::default())?;
    let topo = Topology::small(shards, routers, pes);
    let store_defaults = StoreConfig::default();
    // Every StoreConfig field is wired explicitly (no `..Default::default()`
    // spread) so pallas-lint's knob-coverage rule can pair each field with
    // its flag.
    let store = StoreConfig {
        shard_key: ShardKeyKind::parse(
            &args.get_or("shard-key", store_defaults.shard_key.name()),
        )?,
        max_chunk_docs: args.get_u64_or("max-chunk-docs", store_defaults.max_chunk_docs)?,
        journal: !args.has_switch("no-journal"),
        compress_checkpoints: args.has_switch("compress-checkpoints"),
        insert_batch: batch,
        flush_interval_ms,
        checkpoint_bytes: args
            .get_u64_or("checkpoint-bytes", store_defaults.checkpoint_bytes)?,
        journal_segments: args
            .get_u64_or("journal-segments", store_defaults.journal_segments as u64)?
            as u32,
        full_checkpoint_chain: args
            .get_u64_or("checkpoint-chain", store_defaults.full_checkpoint_chain as u64)?
            as u32,
        router_flush_docs: args
            .get_u64_or("router-flush-docs", store_defaults.router_flush_docs as u64)?
            as usize,
        cursor_batch: args
            .get_u64_or("cursor-batch", store_defaults.cursor_batch as u64)?
            as usize,
        balancer: !args.has_switch("no-balancer"),
        migration_batch_docs: args
            .get_u64_or("migration-batch-docs", store_defaults.migration_batch_docs as u64)?
            as usize,
        balancer_bytes: args
            .get_u64_or("balancer-bytes", store_defaults.balancer_bytes)?,
        reader_threads: args
            .get_u64_or("reader-threads", store_defaults.reader_threads as u64)?
            as usize,
        snapshot_retention: args
            .get_u64_or("snapshot-retention", store_defaults.snapshot_retention)?,
        agg_partial: match args.get_or("agg-partial", "true").as_str() {
            "true" | "on" | "1" => true,
            "false" | "off" | "0" => false,
            other => anyhow::bail!("--agg-partial expects true|false, got `{other}`"),
        },
        replicas: args.get_u64_or("replicas", store_defaults.replicas as u64)? as u32,
        write_concern: WriteConcern::parse(
            &args.get_or("write-concern", store_defaults.write_concern.name()),
        )?,
        read_preference: ReadPreference::parse(
            &args.get_or("read-preference", store_defaults.read_preference.name()),
        )?,
        write_retry_ms: args.get_u64_or("write-retry-ms", store_defaults.write_retry_ms)?,
        election_timeout_ms: args
            .get_u64_or("election-timeout-ms", store_defaults.election_timeout_ms)?,
        heartbeat_ms: args.get_u64_or("heartbeat-ms", store_defaults.heartbeat_ms)?,
    };
    let script = RunScript::new(topo.clone(), store, lustre.clone(), kernels);

    // Admit through the batch scheduler like any HPC job.
    let mut sched = Scheduler::new(topo.total_nodes);
    let job = sched.submit(Job::new("mongo-runscript", topo.total_nodes, 3600))?;
    let hosts = sched.hosts_of(job).expect("job admitted").to_vec();
    println!("job {job:?} running on {} hosts", hosts.len());

    let dep = script.deploy(&hosts)?;
    let client = dep.client_from_hostfile()?;
    client.create_index(IndexSpec::single("ts")).map_err(anyhow::Error::msg)?;
    client.create_index(IndexSpec::single("node_id")).map_err(anyhow::Error::msg)?;
    // The compound (node_id, ts) index serves the canonical query as
    // one bounded range scan per node (candidates == matches); the
    // singles stay as sort/fallback paths.
    client
        .create_index(IndexSpec::compound(&["node_id", "ts"]))
        .map_err(anyhow::Error::msg)?;

    let wl = WorkloadConfig {
        monitored_nodes: monitored,
        days: minutes as f64 / 1440.0,
        query_jobs: 16,
        ..Default::default()
    };
    let gen = OvisGenerator::new(wl.clone());
    println!(
        "ingesting {} docs ({} monitored nodes x {minutes} min, {} metrics/doc)...",
        human_count(gen.total_docs()),
        monitored,
        wl.metrics_per_doc
    );
    let ingest = IngestDriver::new(gen, batch, pes as usize)
        .buffered(buffered)
        .run(&client)?;
    println!("ingest: {}", ingest.summary());

    let queries = QueryDriver::new(generate_jobs(&wl), pes as usize).run(&client)?;
    println!("queries: {}", queries.summary());
    anyhow::ensure!(queries.count_mismatches == 0, "query counts mismatched");

    // Storage lifecycle: bounded on-disk journal + checkpoint generation
    // per shard (the teardown below runs the final admin checkpoint).
    for (i, s) in dep.cluster.shard_stats().iter().enumerate() {
        println!(
            "shard {i}: {} docs, journal on disk {}, checkpoint generation {} (chain {}, delta bytes {})",
            human_count(s.collection.docs),
            human_count(s.journal_disk_bytes),
            s.checkpoint_generation,
            s.checkpoint_chain_len,
            human_count(s.delta_disk_bytes)
        );
    }

    println!("lustre: {} written across {} OSTs", human_count(lustre.total_written()), lustre.config().osts);
    dep.teardown()?;
    sched.complete(job)?;
    println!("done; data persisted at {}", lustre.backing_path().display());
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cost_path = args.get_or("costmodel", "artifacts/costmodel.json");
    let cost = if std::path::Path::new(&cost_path).exists() {
        println!("cost model: {cost_path}");
        CostModel::load(std::path::Path::new(&cost_path))?
    } else {
        println!("cost model: built-in defaults (run `hpcstore calibrate`)");
        CostModel::default()
    };
    let cost = cost.with_network_floor();
    let sizes: Vec<u32> = match args.get_or("nodes", "all").as_str() {
        "all" => vec![32, 64, 128, 256],
        n => vec![n.parse()?],
    };
    let mut fig2: Vec<Vec<String>> = Vec::new();
    let mut fig3: Vec<Vec<String>> = Vec::new();
    let mut base_dps = None;
    for nodes in sizes {
        let mut spec = SimSpec::paper_preset(nodes, cost.clone())?;
        if let Some(m) = args.get_u64("monitored")? {
            spec.monitored_nodes = m as u32;
        }
        if let Some(c) = args.get_u64("chunk-docs")? {
            spec.max_chunk_docs = c;
        }
        if let Some(o) = args.get_u64("osts")? {
            spec.osts = o as u32;
        }
        let r = ClusterSim::new(spec).run();
        let base = *base_dps.get_or_insert(r.docs_per_sec);
        let mut row = r.ingest_row();
        row.push(format!("{:.2}x", r.docs_per_sec / base));
        fig2.push(row);
        fig3.push(r.query_row());
    }
    println!("\n## Figure 2 — ingest scaling (DES, calibrated)\n");
    print!(
        "{}",
        markdown_table(
            &["nodes", "shards", "client PEs", "docs", "virt s", "docs/s", "shard util", "config util", "splits", "speedup"],
            &fig2
        )
    );
    println!("\n## Figure 3 — concurrent conditional-find latency (DES)\n");
    print!(
        "{}",
        markdown_table(
            &["nodes", "concurrency", "finds", "finds/s", "p50", "p95", "p99"],
            &fig3
        )
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let kernels = load_kernels(args);
    println!("calibrating against kernel backend {:?}...", kernels.backend());
    let cm = CostModel::calibrate(&kernels, args.has_switch("quick"))?;
    let out = args.get_or("out", "artifacts/costmodel.json");
    cm.save(std::path::Path::new(&out))?;
    println!("{}", hpcstore::json::to_string_pretty(&cm.to_json()));
    println!("wrote {out}");
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let monitored = args.get_u64("monitored")?.unwrap_or(2048) as u32;
    let mut rows = Vec::new();
    for (nodes, days) in TABLE1 {
        let topo = Topology::paper_preset(nodes)?;
        let wl = WorkloadConfig { monitored_nodes: monitored, days, ..Default::default() };
        rows.push(vec![
            nodes.to_string(),
            format!("{days}"),
            topo.shards.to_string(),
            topo.routers.to_string(),
            topo.client_pes().to_string(),
            human_count(wl.total_docs()),
        ]);
    }
    println!("\n## Table 1 — days of data per cluster size (corpus scaled to {monitored} monitored nodes)\n");
    print!(
        "{}",
        markdown_table(&["nodes", "days", "shards", "routers", "client PEs", "docs"], &rows)
    );
    Ok(())
}
