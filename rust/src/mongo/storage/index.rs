//! Ordered secondary indexes.
//!
//! Keys are encoded into order-preserving byte strings (type tag +
//! big-endian payloads with sign/NaN handling), so a `BTreeMap` range
//! scan over encoded bounds is a correct index range scan under the
//! total value order of [`Value::cmp_total`].

use std::collections::BTreeMap;
use std::ops::Bound;

use super::engine::RecordId;
use super::mvcc::{visible, Epoch, LATEST, LIVE};
use crate::mongo::bson::{Document, Value};

/// Index definition: one or more fields, ascending (the workload indexes
/// `ts` and `node_id`; compound (`node_id`, `ts`) is supported and used
/// by ablation A2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexSpec {
    pub name: String,
    pub fields: Vec<String>,
}

impl IndexSpec {
    pub fn single(field: &str) -> Self {
        Self { name: format!("{field}_1"), fields: vec![field.to_string()] }
    }

    pub fn compound(fields: &[&str]) -> Self {
        Self {
            name: fields.join("_1_") + "_1",
            fields: fields.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Encode one value into an order-preserving byte string.
///
/// Layout: type-rank byte, then payload:
/// * numbers: f64 bits with sign-flip trick (order-preserving across
///   Int/F64 since comparison is numeric)
/// * strings: bytes + 0x00 terminator (no embedded NULs in our corpus)
/// * bool: 0/1
pub fn encode_value(v: &Value, out: &mut Vec<u8>) {
    out.push(v.type_rank());
    match v {
        Value::Null => {}
        Value::Bool(b) => out.push(*b as u8),
        Value::Int(_) | Value::F64(_) => {
            // lint: allow(panic, as_f64 is total for the Int and F64 variants matched here)
            let f = v.as_f64().unwrap();
            let bits = f.to_bits();
            // Flip sign bit for positives, all bits for negatives: total
            // order matches numeric order.
            let ordered = if bits >> 63 == 0 { bits ^ (1 << 63) } else { !bits };
            out.extend_from_slice(&ordered.to_be_bytes());
        }
        Value::Str(s) => {
            debug_assert!(!s.as_bytes().contains(&0), "NUL in index key");
            out.extend_from_slice(s.as_bytes());
            out.push(0);
        }
        Value::Array(items) => {
            for item in items {
                encode_value(item, out);
            }
            out.push(0xFF); // terminator above any element tag? see note
        }
        Value::Doc(d) => {
            for (k, val) in &d.fields {
                out.extend_from_slice(k.as_bytes());
                out.push(0);
                encode_value(val, out);
            }
            out.push(0xFF);
        }
    }
}

/// Encoded `[lo, hi)` byte bounds of one index scan — built by the
/// planner ([`Index::superset_bounds`] or exact `encode_key` pairs),
/// walked by resumable cursors via [`Index::pull_range`].
pub type EncodedRange = (Vec<u8>, Vec<u8>);

/// Encode a (possibly compound) key from `values`.
pub fn encode_key(values: &[&Value]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 10);
    for v in values {
        encode_value(v, &mut out);
    }
    out
}

/// One index entry: a record id plus the epoch window it is visible in
/// (see [`super::mvcc::visible`]). Postings of reclaimed records are
/// physically pruned by [`Index::prune`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Posting {
    pub rid: RecordId,
    born: Epoch,
    dead: Epoch,
}

/// An in-memory ordered index. Postings are epoch-versioned so snapshot
/// reads ([`Epoch`]-pinned `_at` variants) and latest reads (the plain
/// methods, which see exactly the live postings) share one structure.
#[derive(Clone)]
pub struct Index {
    pub spec: IndexSpec,
    /// encoded key → postings (duplicates common: same ts across all
    /// monitored nodes).
    map: BTreeMap<Vec<u8>, Vec<Posting>>,
    /// Live postings (dead versions awaiting reclamation excluded).
    entries: u64,
}

impl Index {
    pub fn new(spec: IndexSpec) -> Self {
        Self { spec, map: BTreeMap::new(), entries: 0 }
    }

    /// Extract this index's key from a document (missing fields encode
    /// as Null, as MongoDB does).
    pub fn key_of(&self, doc: &Document) -> Vec<u8> {
        let null = Value::Null;
        let vals: Vec<&Value> = self
            .spec
            .fields
            .iter()
            .map(|f| doc.get(f).unwrap_or(&null))
            .collect();
        encode_key(&vals)
    }

    /// Insert a live posting born at epoch 0 — visible to every
    /// snapshot. The standalone-index entry point (tests, benches, the
    /// planner's cost fixtures); the engine stamps real epochs via
    /// [`Index::insert_at`].
    pub fn insert(&mut self, doc: &Document, rid: RecordId) {
        self.insert_version(doc, rid, 0, LIVE);
    }

    /// Insert a live posting born at `born`.
    pub fn insert_at(&mut self, doc: &Document, rid: RecordId, born: Epoch) {
        self.insert_version(doc, rid, born, LIVE);
    }

    /// Insert a posting with explicit stamps — the index-backfill path,
    /// which must reproduce the visibility window of each record version
    /// (including dead-but-retained ones) so snapshot plans over a
    /// freshly created index stay exact.
    pub fn insert_version(&mut self, doc: &Document, rid: RecordId, born: Epoch, dead: Epoch) {
        self.map
            .entry(self.key_of(doc))
            .or_default()
            .push(Posting { rid, born, dead });
        if dead == LIVE {
            self.entries += 1;
        }
    }

    /// Physically remove `rid`'s posting (live or dead) under `doc`'s
    /// key — the pre-MVCC removal, still used by recovery folds (which
    /// run before any snapshot exists) and standalone-index callers.
    pub fn remove(&mut self, doc: &Document, rid: RecordId) {
        let key = self.key_of(doc);
        if let Some(postings) = self.map.get_mut(&key) {
            if let Some(pos) = postings.iter().position(|p| p.rid == rid) {
                if postings.swap_remove(pos).dead == LIVE {
                    self.entries -= 1;
                }
            }
            if postings.is_empty() {
                self.map.remove(&key);
            }
        }
    }

    /// Logically remove `rid`: stamp its live posting dead at `epoch`.
    /// The posting stays until [`Index::prune`] (epoch reclamation) so
    /// snapshots pinned before `epoch` keep reading it.
    pub fn kill(&mut self, doc: &Document, rid: RecordId, epoch: Epoch) {
        let key = self.key_of(doc);
        if let Some(postings) = self.map.get_mut(&key) {
            if let Some(p) =
                postings.iter_mut().find(|p| p.rid == rid && p.dead == LIVE)
            {
                p.dead = epoch;
                self.entries -= 1;
            }
        }
    }

    /// Physically drop `rid`'s *dead* posting under `doc`'s key — the
    /// reclamation step once no open snapshot can read it.
    pub fn prune(&mut self, doc: &Document, rid: RecordId) {
        let key = self.key_of(doc);
        if let Some(postings) = self.map.get_mut(&key) {
            if let Some(pos) =
                postings.iter().position(|p| p.rid == rid && p.dead != LIVE)
            {
                postings.swap_remove(pos);
            }
            if postings.is_empty() {
                self.map.remove(&key);
            }
        }
    }

    /// Record ids whose key equals `values` (latest view).
    pub fn point(&self, values: &[&Value]) -> Vec<RecordId> {
        self.point_iter_at(values, LATEST).collect()
    }

    /// [`Index::point`] without the allocation: record ids streamed from
    /// the key's posting list (latest view).
    pub fn point_iter<'a>(
        &'a self,
        values: &[&Value],
    ) -> impl Iterator<Item = RecordId> + 'a {
        self.point_iter_at(values, LATEST)
    }

    /// Record ids whose key equals `values`, visible at snapshot `at`.
    pub fn point_iter_at<'a>(
        &'a self,
        values: &[&Value],
        at: Epoch,
    ) -> impl Iterator<Item = RecordId> + 'a {
        self.map
            .get(&encode_key(values))
            .into_iter()
            .flat_map(move |ps| {
                ps.iter().filter(move |p| visible(p.born, p.dead, at)).map(|p| p.rid)
            })
    }

    /// How many record ids a point lookup of `values` would return —
    /// the planner's per-value cost estimate, one map probe.
    pub fn point_len(&self, values: &[&Value]) -> usize {
        self.point_len_at(values, LATEST)
    }

    /// [`Index::point_len`] at snapshot `at`.
    pub fn point_len_at(&self, values: &[&Value], at: Epoch) -> usize {
        self.map.get(&encode_key(values)).map_or(0, |ps| {
            ps.iter().filter(|p| visible(p.born, p.dead, at)).count()
        })
    }

    /// Record ids in `[lo, hi)` on the first key field (prefix scan),
    /// streamed without materializing a rid vector. `None` bound =
    /// unbounded.
    pub fn range<'a>(
        &'a self,
        lo: Option<&Value>,
        hi: Option<&Value>,
    ) -> impl Iterator<Item = RecordId> + 'a {
        let lo_b: Bound<Vec<u8>> = match lo {
            Some(v) => Bound::Included(encode_key(&[v])),
            None => Bound::Unbounded,
        };
        // A half-open `[lo, hi)` prefix scan must exclude `hi` and every
        // compound key extending it; `encode(hi)` is exactly that
        // exclusive bound (every extension sorts at or after it).
        let hi_b: Bound<Vec<u8>> = match hi {
            Some(v) => Bound::Excluded(encode_key(&[v])),
            None => Bound::Unbounded,
        };
        self.scan_bounds(lo_b, hi_b, LATEST)
    }

    /// Superset scan with *inclusive* bounds on the first key field —
    /// the planner's access path, streamed without materializing a rid
    /// vector. The caller always applies a residual filter (kernel or
    /// matcher), so including `hi` (and its compound extensions) is
    /// correct for every operator mix ($lte, $eq, ...).
    pub fn range_superset<'a>(
        &'a self,
        lo: Option<&Value>,
        hi: Option<&Value>,
    ) -> impl Iterator<Item = RecordId> + 'a {
        self.range_superset_at(lo, hi, LATEST)
    }

    /// [`Index::range_superset`] at snapshot `at`.
    pub fn range_superset_at<'a>(
        &'a self,
        lo: Option<&Value>,
        hi: Option<&Value>,
        at: Epoch,
    ) -> impl Iterator<Item = RecordId> + 'a {
        let (lo_b, hi_b) = Self::superset_bounds(&[], lo, hi);
        let lo_b = if lo.is_some() { Bound::Included(lo_b) } else { Bound::Unbounded };
        self.scan_bounds(lo_b, Bound::Excluded(hi_b), at)
    }

    /// How many record ids [`Index::range_superset`] would yield — the
    /// planner's scan-cost estimate: O(postings in range), no rid
    /// allocation or copying.
    pub fn range_superset_len(&self, lo: Option<&Value>, hi: Option<&Value>) -> usize {
        self.range_superset_len_at(lo, hi, LATEST)
    }

    /// [`Index::range_superset_len`] at snapshot `at`.
    pub fn range_superset_len_at(
        &self,
        lo: Option<&Value>,
        hi: Option<&Value>,
        at: Epoch,
    ) -> usize {
        let (lo_b, hi_b) = Self::superset_bounds(&[], lo, hi);
        if lo_b > hi_b {
            return 0;
        }
        self.map
            .range(lo_b..hi_b)
            .map(|(_, ps)| ps.iter().filter(|p| visible(p.born, p.dead, at)).count())
            .sum()
    }

    /// Iterate `map.range` defensively: inverted bounds (an empty query
    /// range like `ts >= 10 && ts < 5`) yield an empty scan instead of
    /// the `BTreeMap::range` start-after-end panic.
    fn scan_bounds<'a>(
        &'a self,
        lo_b: Bound<Vec<u8>>,
        hi_b: Bound<Vec<u8>>,
        at: Epoch,
    ) -> impl Iterator<Item = RecordId> + 'a {
        let inverted = match (&lo_b, &hi_b) {
            (Bound::Excluded(a), Bound::Excluded(b)) => a >= b,
            (
                Bound::Included(a) | Bound::Excluded(a),
                Bound::Included(b) | Bound::Excluded(b),
            ) => a > b,
            _ => false,
        };
        let bounds = if inverted {
            // Every real key is non-empty, so `< []` selects nothing.
            (Bound::Unbounded, Bound::Excluded(Vec::new()))
        } else {
            (lo_b, hi_b)
        };
        self.map.range(bounds).flat_map(move |(_, ps)| {
            ps.iter().filter(move |p| visible(p.born, p.dead, at)).map(|p| p.rid)
        })
    }

    /// Encoded `[lo, hi)` scan bounds over keys whose leading fields
    /// equal `prefix` and whose next field lies in `[lo, hi]` — an
    /// inclusive *superset* on both sides (the caller's residual filter
    /// restores exact operator semantics). Either bound `None` =
    /// unbounded within the prefix; an empty prefix spans the whole
    /// index (every document appears in every index: missing fields
    /// encode as Null).
    pub fn superset_bounds(
        prefix: &[&Value],
        lo: Option<&Value>,
        hi: Option<&Value>,
    ) -> EncodedRange {
        let mut lo_b = encode_key(prefix);
        if let Some(v) = lo {
            encode_value(v, &mut lo_b);
        }
        let mut hi_b = encode_key(prefix);
        if let Some(v) = hi {
            encode_value(v, &mut hi_b);
        }
        // Every key extension continues with a type-rank byte <= 6, so
        // appending 0x07 excludes nothing that starts with the hi
        // prefix (and past the last in-prefix key when hi is None).
        hi_b.push(0x07);
        (lo_b, hi_b)
    }

    /// Batched cursor step: append the rids of whole keys from `range`
    /// (after/before the `resume` key, walking descending when `rev`)
    /// into `out` until at least `max` rids are pulled or the range is
    /// exhausted, and return the last key consumed — the next resume
    /// point. One tree seek per call, sequential iteration after it;
    /// duplicate-key runs are never split. `None` = nothing left. The
    /// resume point is a *key*, not an iterator: the index may mutate
    /// between steps (concurrent ingest between getMores) and the scan
    /// resumes correctly after it.
    pub fn pull_range(
        &self,
        range: &EncodedRange,
        resume: Option<&[u8]>,
        rev: bool,
        max: usize,
        out: &mut std::collections::VecDeque<RecordId>,
    ) -> Option<Vec<u8>> {
        self.pull_range_at(range, resume, rev, max, out, LATEST)
    }

    /// [`Index::pull_range`] at snapshot `at`: only postings visible at
    /// the pinned epoch are pulled. A key whose postings are all
    /// invisible still advances the resume point (it counts toward
    /// nothing), so a cursor never stalls on a fully-dead key run.
    #[allow(clippy::too_many_arguments)]
    pub fn pull_range_at(
        &self,
        range: &EncodedRange,
        resume: Option<&[u8]>,
        rev: bool,
        max: usize,
        out: &mut std::collections::VecDeque<RecordId>,
        at: Epoch,
    ) -> Option<Vec<u8>> {
        let (lo, hi) = range;
        let mut last: Option<&[u8]> = None;
        let mut pulled = 0usize;
        if rev {
            let end: &[u8] = match resume {
                Some(k) => k,
                None => hi.as_slice(),
            };
            if lo.as_slice() >= end {
                return None;
            }
            for (k, ps) in self
                .map
                .range::<[u8], _>((Bound::Included(lo.as_slice()), Bound::Excluded(end)))
                .rev()
            {
                for p in ps {
                    if visible(p.born, p.dead, at) {
                        out.push_back(p.rid);
                        pulled += 1;
                    }
                }
                last = Some(k.as_slice());
                if pulled >= max {
                    break;
                }
            }
        } else {
            let start: Bound<&[u8]> = match resume {
                Some(k) => {
                    if k >= hi.as_slice() {
                        return None;
                    }
                    Bound::Excluded(k)
                }
                None => {
                    if lo.as_slice() >= hi.as_slice() {
                        return None;
                    }
                    Bound::Included(lo.as_slice())
                }
            };
            for (k, ps) in self
                .map
                .range::<[u8], _>((start, Bound::Excluded(hi.as_slice())))
            {
                for p in ps {
                    if visible(p.born, p.dead, at) {
                        out.push_back(p.rid);
                        pulled += 1;
                    }
                }
                last = Some(k.as_slice());
                if pulled >= max {
                    break;
                }
            }
        }
        // One owned copy of the final resume key, not one per visited
        // key — this runs on the hot read path.
        last.map(|k| k.to_vec())
    }

    /// Live postings (dead versions awaiting reclamation excluded).
    pub fn entries(&self) -> u64 {
        self.entries
    }

    pub fn distinct_keys(&self) -> usize {
        self.map.len()
    }

    /// Approximate memory footprint in bytes.
    pub fn approx_bytes(&self) -> u64 {
        self.map
            .iter()
            .map(|(k, v)| (k.len() + v.len() * std::mem::size_of::<Posting>() + 32) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn encoding_preserves_numeric_order() {
        let vals = [
            Value::F64(f64::NEG_INFINITY),
            Value::F64(-1e300),
            Value::Int(-5),
            Value::F64(-0.5),
            Value::Int(0),
            Value::F64(0.5),
            Value::Int(3),
            Value::F64(3.5),
            Value::Int(i64::MAX),
            Value::F64(f64::INFINITY),
        ];
        for w in vals.windows(2) {
            let a = encode_key(&[&w[0]]);
            let b = encode_key(&[&w[1]]);
            assert!(a < b, "{:?} !< {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn encoding_property_matches_cmp_total() {
        check(
            "index-order",
            &(|rng: &mut Pcg32| {
                let v = |rng: &mut Pcg32| match rng.next_bounded(3) {
                    0 => Value::Int(rng.next_u64() as i64),
                    1 => Value::F64((rng.next_f64() - 0.5) * 1e6),
                    _ => Value::Int(rng.next_bounded(100) as i64),
                };
                (v(rng), v(rng))
            }),
            |(a, b)| {
                let ord_enc = encode_key(&[a]).cmp(&encode_key(&[b]));
                let ord_val = a.cmp_total(b);
                if ord_enc == ord_val {
                    Ok(())
                } else {
                    Err(format!("{a:?} vs {b:?}: enc {ord_enc:?} val {ord_val:?}"))
                }
            },
        );
    }

    #[test]
    fn type_classes_sort_by_rank() {
        let null = encode_key(&[&Value::Null]);
        let num = encode_key(&[&Value::Int(-999)]);
        let s = encode_key(&[&Value::Str("a".into())]);
        assert!(null < num && num < s);
    }

    fn d(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node)
    }

    #[test]
    fn insert_point_remove() {
        let mut idx = Index::new(IndexSpec::single("node_id"));
        idx.insert(&d(1, 7), 100);
        idx.insert(&d(2, 7), 101);
        idx.insert(&d(3, 8), 102);
        assert_eq!(idx.entries(), 3);
        let mut rids = idx.point(&[&Value::Int(7)]);
        rids.sort_unstable();
        assert_eq!(rids, vec![100, 101]);
        idx.remove(&d(1, 7), 100);
        assert_eq!(idx.point(&[&Value::Int(7)]), vec![101]);
        assert_eq!(idx.entries(), 2);
    }

    #[test]
    fn range_scan_half_open() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        for t in 0..100i64 {
            idx.insert(&d(t, 0), t as u64);
        }
        let mut rids: Vec<RecordId> =
            idx.range(Some(&Value::Int(10)), Some(&Value::Int(20))).collect();
        rids.sort_unstable();
        assert_eq!(rids, (10u64..20).collect::<Vec<_>>());
        // Unbounded sides.
        assert_eq!(idx.range(None, Some(&Value::Int(5))).count(), 5);
        assert_eq!(idx.range(Some(&Value::Int(95)), None).count(), 5);
        assert_eq!(idx.range(None, None).count(), 100);
        // Inverted bounds are an empty scan, not a panic.
        assert_eq!(idx.range(Some(&Value::Int(20)), Some(&Value::Int(10))).count(), 0);
        assert_eq!(
            idx.range_superset(Some(&Value::Int(20)), Some(&Value::Int(10))).count(),
            0
        );
        assert_eq!(idx.range_superset_len(Some(&Value::Int(20)), Some(&Value::Int(10))), 0);
    }

    #[test]
    fn compound_prefix_scan() {
        let mut idx = Index::new(IndexSpec::compound(&["node_id", "ts"]));
        for node in 0..5i64 {
            for t in 0..10i64 {
                idx.insert(&d(t, node), (node * 10 + t) as u64);
            }
        }
        // Prefix range on node_id ∈ [2, 4).
        let rids: Vec<RecordId> =
            idx.range(Some(&Value::Int(2)), Some(&Value::Int(4))).collect();
        assert_eq!(rids.len(), 20);
        assert!(rids.iter().all(|&r| (20..40).contains(&r)));
        // Point on full compound key.
        let rids = idx.point(&[&Value::Int(3), &Value::Int(7)]);
        assert_eq!(rids, vec![37]);
        assert_eq!(idx.point_len(&[&Value::Int(3), &Value::Int(7)]), 1);
        assert_eq!(idx.point_iter(&[&Value::Int(3), &Value::Int(7)]).collect::<Vec<_>>(), vec![37]);
    }

    #[test]
    fn superset_bounds_with_prefix_select_one_nodes_window() {
        use std::collections::VecDeque;
        // The compound planner's access path: prefix = node, next field
        // bounded [lo, hi] inclusive-superset.
        let mut idx = Index::new(IndexSpec::compound(&["node_id", "ts"]));
        for node in 0..4i64 {
            for t in 0..10i64 {
                idx.insert(&d(t, node), (node * 10 + t) as u64);
            }
        }
        let node = Value::Int(2);
        // max = 1: one key per step, so the walk also proves per-key
        // resumption.
        let walk = |range: &EncodedRange| -> Vec<RecordId> {
            let mut out: VecDeque<RecordId> = VecDeque::new();
            let mut resume: Option<Vec<u8>> = None;
            while let Some(k) = idx.pull_range(range, resume.as_deref(), false, 1, &mut out)
            {
                resume = Some(k);
            }
            out.into()
        };
        let range = Index::superset_bounds(
            &[&node],
            Some(&Value::Int(3)),
            Some(&Value::Int(6)),
        );
        // Superset: ts in [3, 6] inclusive, node 2 only, ts order.
        assert_eq!(walk(&range), vec![23, 24, 25, 26]);
        // Whole-prefix scan: lo/hi unbounded inside the prefix.
        assert_eq!(walk(&Index::superset_bounds(&[&node], None, None)).len(), 10);
    }

    #[test]
    fn pull_range_single_steps_walk_both_directions_in_order() {
        use std::collections::VecDeque;
        let mut idx = Index::new(IndexSpec::single("ts"));
        for t in 0..20i64 {
            idx.insert(&d(t, 0), t as u64);
            idx.insert(&d(t, 1), (100 + t) as u64); // duplicate keys
        }
        let range =
            Index::superset_bounds(&[], Some(&Value::Int(5)), Some(&Value::Int(8)));
        // max = 1 consumes exactly one whole key per step: keys 5..=8
        // (superset-inclusive), two rids each, ascending.
        let mut asc: Vec<Vec<RecordId>> = Vec::new();
        let mut resume: Option<Vec<u8>> = None;
        loop {
            let mut out = VecDeque::new();
            match idx.pull_range(&range, resume.as_deref(), false, 1, &mut out) {
                Some(k) => resume = Some(k),
                None => break,
            }
            let mut step: Vec<RecordId> = out.into();
            step.sort_unstable();
            asc.push(step);
        }
        assert_eq!(asc, vec![vec![5, 105], vec![6, 106], vec![7, 107], vec![8, 108]]);
        // Descending walk visits the same keys reversed.
        let mut desc: Vec<Vec<RecordId>> = Vec::new();
        let mut resume: Option<Vec<u8>> = None;
        loop {
            let mut out = VecDeque::new();
            match idx.pull_range(&range, resume.as_deref(), true, 1, &mut out) {
                Some(k) => resume = Some(k),
                None => break,
            }
            let mut step: Vec<RecordId> = out.into();
            step.sort_unstable();
            desc.push(step);
        }
        asc.reverse();
        assert_eq!(desc, asc);
        // Degenerate (inverted) range: both directions yield nothing.
        let inverted =
            Index::superset_bounds(&[], Some(&Value::Int(8)), Some(&Value::Int(5)));
        let mut out = VecDeque::new();
        assert!(idx.pull_range(&inverted, None, false, 1, &mut out).is_none());
        assert!(idx.pull_range(&inverted, None, true, 1, &mut out).is_none());
        assert!(out.is_empty());
    }

    #[test]
    fn pull_range_batches_keys_and_resumes_both_directions() {
        use std::collections::VecDeque;
        let mut idx = Index::new(IndexSpec::single("ts"));
        for t in 0..30i64 {
            idx.insert(&d(t, 0), t as u64);
            idx.insert(&d(t, 1), (100 + t) as u64); // duplicate keys
        }
        let range =
            Index::superset_bounds(&[], Some(&Value::Int(0)), Some(&Value::Int(29)));
        for rev in [false, true] {
            let mut out: VecDeque<RecordId> = VecDeque::new();
            let mut resume: Option<Vec<u8>> = None;
            let mut steps = 0;
            loop {
                match idx.pull_range(&range, resume.as_deref(), rev, 7, &mut out) {
                    Some(k) => resume = Some(k),
                    None => break,
                }
                steps += 1;
            }
            // All 60 rids arrive, whole keys per step (max=7 → 4 keys
            // = 8 rids per step, 8 steps), and the per-key pairs stay
            // adjacent in both directions.
            assert_eq!(out.len(), 60, "rev={rev}");
            assert!(steps <= 8, "rev={rev}: {steps} steps for 30 keys");
            // Each key contributes its pair [t, 100+t] adjacently (keys
            // are never split), so min-of-pair recovers the ts walk.
            let rids: Vec<RecordId> = out.into();
            let mut ts_order: Vec<u64> =
                rids.chunks(2).map(|pair| pair[0].min(pair[1])).collect();
            if rev {
                ts_order.reverse();
            }
            assert!(
                ts_order.windows(2).all(|w| w[0] <= w[1]),
                "rev={rev}: keys out of order: {ts_order:?}"
            );
        }
        // Exhausted range: an immediate None.
        let empty =
            Index::superset_bounds(&[], Some(&Value::Int(50)), Some(&Value::Int(60)));
        let mut out = VecDeque::new();
        assert!(idx.pull_range(&empty, None, false, 7, &mut out).is_none());
        assert!(out.is_empty());
    }

    #[test]
    fn range_superset_len_counts_without_allocating() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        for t in 0..50i64 {
            idx.insert(&d(t, 0), t as u64);
        }
        assert_eq!(
            idx.range_superset_len(Some(&Value::Int(10)), Some(&Value::Int(19))),
            idx.range_superset(Some(&Value::Int(10)), Some(&Value::Int(19))).count()
        );
        assert_eq!(idx.range_superset_len(None, None), 50);
    }

    #[test]
    fn missing_field_indexes_as_null() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        idx.insert(&Document::new().set("other", 1i64), 1);
        assert_eq!(idx.point(&[&Value::Null]), vec![1]);
    }

    #[test]
    fn duplicate_keys_accumulate() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        for rid in 0..50u64 {
            idx.insert(&d(42, rid as i64), rid);
        }
        assert_eq!(idx.distinct_keys(), 1);
        assert_eq!(idx.point(&[&Value::Int(42)]).len(), 50);
    }
}
