"""Layer-2 JAX graphs — the computations the Rust coordinator executes.

Each public function here composes the Layer-1 Pallas kernels with plain
``jnp`` glue and is AOT-lowered by :mod:`compile.aot` into one fused HLO
module per artifact. The shapes are fixed at lowering time (see
``SHAPES``); the Rust side pads batches to these shapes.

The artifact interface (names, dtypes, orderings) is mirrored by
``rust/src/runtime/`` — change in lockstep.
"""

import jax.numpy as jnp

from .kernels.batch_stats import batch_stats
from .kernels.filter_scan import filter_scan
from .kernels.shard_route import shard_route

# Fixed AOT shapes (DESIGN.md §2).
ROUTE_B = 4096  # documents per routing batch
ROUTE_C = 512  # max chunks
ROUTE_S = 64  # max shards (256-node preset has 63)
FILTER_B = 4096  # documents per filter batch
FILTER_W = 1024  # bitmap words -> covers node ids < 32768
STATS_B = 4096  # documents per stats batch
STATS_M = 16  # summarised metric columns


def route_batch(node_id, ts_min, boundaries, chunk_to_shard):
    """insertMany partitioning: shard assignment + per-shard histogram.

    Inputs:  node_id u32[ROUTE_B], ts_min u32[ROUTE_B],
             boundaries u32[ROUTE_C], chunk_to_shard i32[ROUTE_C].
    Outputs: (shard_of i32[ROUTE_B], counts i32[ROUTE_S], hashes u32[ROUTE_B]).

    The histogram feeds the router's sub-batch allocation (exact sizes,
    no realloc) and the balancer's write-load estimate.

    Perf (EXPERIMENTS.md §Perf): lowered with the searchsorted kernel
    variant and full-batch block (68 µs vs 1.19 ms for the original
    compare-count blk1024 on CPU PJRT) and a scatter-add histogram
    (24 µs vs 51 µs one-hot).
    """
    shard_of, hashes = shard_route(
        node_id, ts_min, boundaries, chunk_to_shard, block_b=ROUTE_B
    )
    counts = jnp.zeros(ROUTE_S, jnp.int32).at[shard_of].add(1)
    return shard_of, counts, hashes


def filter_batch(ts_min, node_id, ts_lo, ts_hi, node_bitmap):
    """Conditional-find predicate over a columnar batch.

    Inputs:  ts_min u32[FILTER_B], node_id u32[FILTER_B],
             ts_lo u32[1], ts_hi u32[1], node_bitmap u32[FILTER_W].
    Outputs: (mask i32[FILTER_B], count i32[1]).
    """
    return filter_scan(ts_min, node_id, ts_lo, ts_hi, node_bitmap)


def stats_batch(metrics):
    """Per-column min/max/mean for one ingest batch.

    Inputs:  metrics f32[STATS_B, STATS_M].
    Outputs: (min f32[M], max f32[M], mean f32[M]).
    """
    return batch_stats(metrics)
