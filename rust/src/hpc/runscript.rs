//! The run-script execution model — the paper's contribution (§3.2).
//!
//! "For a typical Blue Waters user to deploy a MongoDB cluster, they
//! must construct a run-script that assigns to each processing element
//! which role it will be taking (config, shard, router) ... The
//! runscript makes available through environment variables or a shared
//! file a list of host names of the MongoDB cluster's router servers."
//!
//! [`RoleMap::assign`] maps the job's allocated hosts onto roles;
//! [`RunScript::deploy`] brings the cluster up with each shard's data
//! directory on its own Lustre path, publishes the router host list to a
//! shared hostfile on Lustre, and hands back a [`DeployedCluster`] whose
//! client is constructed *from that hostfile* — the same discovery path
//! the paper's pymongo scripts use.

use anyhow::{bail, Context, Result};

use super::lustre::Lustre;
use crate::config::{StoreConfig, Topology};
use crate::json::{self, Value};
use crate::metrics::Registry;
use crate::mongo::client::MongoClient;
use crate::mongo::cluster::{Cluster, ClusterSpec};
use crate::mongo::storage::StorageDir;
use crate::runtime::Kernels;
use crate::util::ids::ShardId;

/// Role assignment for one job allocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoleMap {
    pub config_hosts: Vec<u32>,
    pub shard_hosts: Vec<u32>,
    pub router_hosts: Vec<u32>,
    pub client_hosts: Vec<u32>,
}

impl RoleMap {
    /// Assign roles in the paper's order: config servers first, then
    /// shard/router pairs, remaining hosts run the client script.
    pub fn assign(hosts: &[u32], topo: &Topology) -> Result<RoleMap> {
        let need = (topo.config_servers + topo.shards + topo.routers) as usize;
        if hosts.len() < need + 1 {
            bail!(
                "allocation of {} hosts cannot fit {need} service roles + clients",
                hosts.len()
            );
        }
        let mut it = hosts.iter().copied();
        let config_hosts: Vec<u32> = it.by_ref().take(topo.config_servers as usize).collect();
        let shard_hosts: Vec<u32> = it.by_ref().take(topo.shards as usize).collect();
        let router_hosts: Vec<u32> = it.by_ref().take(topo.routers as usize).collect();
        let client_hosts: Vec<u32> = it.collect();
        Ok(RoleMap { config_hosts, shard_hosts, router_hosts, client_hosts })
    }

    /// Client processing elements (paper: 4 per client node).
    pub fn client_pes(&self, pes_per_node: u32) -> usize {
        self.client_hosts.len() * pes_per_node as usize
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("config_hosts", self.config_hosts.clone())
            .set("shard_hosts", self.shard_hosts.clone())
            .set("router_hosts", self.router_hosts.clone())
            .set("client_hosts", self.client_hosts.clone());
        v
    }

    pub fn from_json(v: &Value) -> Result<RoleMap> {
        let get = |k: &str| -> Result<Vec<u32>> {
            v.get(k)
                .and_then(Value::as_array)
                .ok_or_else(|| anyhow::anyhow!("hostfile missing `{k}`"))?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .map(|n| n as u32)
                        .ok_or_else(|| anyhow::anyhow!("non-integer host in `{k}`"))
                })
                .collect()
        };
        Ok(RoleMap {
            config_hosts: get("config_hosts")?,
            shard_hosts: get("shard_hosts")?,
            router_hosts: get("router_hosts")?,
            client_hosts: get("client_hosts")?,
        })
    }
}

/// The run script: topology + store knobs + the shared filesystem.
pub struct RunScript {
    pub topology: Topology,
    pub store: StoreConfig,
    pub lustre: Lustre,
    pub kernels: Kernels,
    /// Lustre path prefix for the store's data ("user scratch").
    pub scratch: String,
    pub metrics: Registry,
}

/// Name of the shared hostfile the run script publishes.
pub const HOSTFILE: &str = "mongo_hosts.json";

impl RunScript {
    pub fn new(
        topology: Topology,
        store: StoreConfig,
        lustre: Lustre,
        kernels: Kernels,
    ) -> Self {
        Self {
            topology,
            store,
            lustre,
            kernels,
            scratch: "scratch/mongo".to_string(),
            metrics: Registry::new(),
        }
    }

    fn shard_dir_path(&self, shard: ShardId) -> String {
        format!("{}/{}", self.scratch, shard)
    }

    /// Bring the cluster up on the allocated hosts (run-script phase 1).
    ///
    /// Each shard gets its own Lustre directory; the router host list is
    /// written to the shared hostfile. Data found in the shard
    /// directories from a previous job is recovered — the store is
    /// transient as a *process*, persistent as *data*.
    pub fn deploy(&self, hosts: &[u32]) -> Result<DeployedCluster> {
        self.topology.validate()?;
        let roles = RoleMap::assign(hosts, &self.topology)?;

        let spec = ClusterSpec {
            shards: self.topology.shards,
            routers: self.topology.routers,
            config_replicas: self.topology.config_servers.max(1),
            chunks_per_shard: 2,
            store: self.store.clone(),
        };
        let lustre = self.lustre.clone();
        let scratch = self.scratch.clone();
        let cluster = Cluster::start(
            spec,
            move |sid| {
                let dir = lustre.dir(&format!("{scratch}/{sid}"))?;
                Ok(Box::new(dir) as Box<dyn StorageDir>)
            },
            self.kernels.clone(),
            self.metrics.clone(),
        )
        .context("starting cluster from run script")?;

        // Publish the hostfile on the shared filesystem.
        let shared = self.lustre.dir(&self.scratch)?;
        let mut hostfile = Value::object();
        hostfile.set("roles", roles.to_json());
        hostfile.set(
            "shard_dirs",
            (0..self.topology.shards)
                .map(|i| self.shard_dir_path(ShardId(i)))
                .collect::<Vec<String>>(),
        );
        shared.write_atomic(HOSTFILE, json::to_string_pretty(&hostfile).as_bytes())?;

        Ok(DeployedCluster {
            cluster,
            roles,
            lustre: self.lustre.clone(),
            scratch: self.scratch.clone(),
            pes_per_client_node: self.topology.pes_per_client_node,
        })
    }
}

/// A cluster brought up by the run script.
pub struct DeployedCluster {
    pub cluster: Cluster,
    pub roles: RoleMap,
    lustre: Lustre,
    scratch: String,
    pes_per_client_node: u32,
}

impl DeployedCluster {
    /// Build a client the way the paper's workload scripts do: read the
    /// router host list back from the shared hostfile.
    pub fn client_from_hostfile(&self) -> Result<MongoClient> {
        let shared = self.lustre.dir(&self.scratch)?;
        let raw = shared.read(HOSTFILE).context("reading shared hostfile")?;
        let v = json::parse(std::str::from_utf8(&raw)?)
            .map_err(|e| anyhow::anyhow!("hostfile: {e}"))?;
        let roles = RoleMap::from_json(
            v.get("roles").ok_or_else(|| anyhow::anyhow!("hostfile missing roles"))?,
        )?;
        if roles.router_hosts.len() != self.cluster.router_mailboxes().len() {
            bail!("hostfile router list does not match deployed routers");
        }
        Ok(self.cluster.client())
    }

    /// Number of client PEs this deployment runs (paper: 4 per node).
    pub fn client_pes(&self) -> usize {
        self.roles.client_pes(self.pes_per_client_node)
    }

    /// Run-script phase 3: checkpoint every shard and stop all
    /// processes. Data stays on Lustre for the next job.
    pub fn teardown(self) -> Result<()> {
        self.cluster.checkpoint_all()?;
        self.cluster.shutdown();
        Ok(())
    }

    /// Abandon without checkpoint (walltime kill).
    pub fn kill(self) {
        self.cluster.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LustreConfig;

    #[test]
    fn role_assignment_matches_paper_32() {
        let topo = Topology::paper_preset(32).unwrap();
        let hosts: Vec<u32> = (100..132).collect();
        let roles = RoleMap::assign(&hosts, &topo).unwrap();
        assert_eq!(roles.config_hosts.len(), 2);
        assert_eq!(roles.shard_hosts.len(), 7);
        assert_eq!(roles.router_hosts.len(), 7);
        assert_eq!(roles.client_hosts.len(), 16);
        assert_eq!(roles.client_pes(4), 64);
        // Disjoint and covering.
        let mut all: Vec<u32> = Vec::new();
        all.extend(&roles.config_hosts);
        all.extend(&roles.shard_hosts);
        all.extend(&roles.router_hosts);
        all.extend(&roles.client_hosts);
        all.sort_unstable();
        assert_eq!(all, hosts);
    }

    #[test]
    fn role_assignment_rejects_small_allocation() {
        let topo = Topology::paper_preset(32).unwrap();
        let hosts: Vec<u32> = (0..10).collect();
        assert!(RoleMap::assign(&hosts, &topo).is_err());
    }

    #[test]
    fn role_map_json_round_trip() {
        let topo = Topology::small(2, 2, 3);
        let hosts: Vec<u32> = (0..10).collect();
        let roles = RoleMap::assign(&hosts, &topo).unwrap();
        let back = RoleMap::from_json(&roles.to_json()).unwrap();
        assert_eq!(back, roles);
    }

    #[test]
    fn deploy_ingest_teardown_redeploy() {
        use crate::mongo::bson::Document;
        use crate::mongo::query::Filter;

        let lustre = Lustre::mount(LustreConfig::default()).unwrap();
        let topo = Topology::small(2, 1, 2);
        let script = RunScript::new(
            topo,
            StoreConfig::default(),
            lustre.clone(),
            Kernels::fallback(),
        );
        let hosts: Vec<u32> = (0..8).collect();

        // Job 1: deploy, ingest through the hostfile-discovered client,
        // teardown with checkpoint.
        {
            let dep = script.deploy(&hosts).unwrap();
            let client = dep.client_from_hostfile().unwrap();
            let docs: Vec<Document> = (0..200)
                .map(|i| Document::new().set("ts", i as i64).set("node_id", (i % 4) as i64))
                .collect();
            assert_eq!(client.insert_many(docs).unwrap().inserted, 200);
            dep.teardown().unwrap();
        }
        assert!(lustre.total_written() > 0);

        // Job 2 (possibly a different allocation): redeploy over the same
        // scratch; data must still be there.
        {
            let hosts2: Vec<u32> = (50..58).collect();
            let dep = script.deploy(&hosts2).unwrap();
            let client = dep.client_from_hostfile().unwrap();
            assert_eq!(client.count_documents(Filter::True).unwrap(), 200);
            dep.teardown().unwrap();
        }
    }

    #[test]
    fn deploy_via_scheduler_job() {
        use super::super::scheduler::{Job, Scheduler};
        let lustre = Lustre::mount(LustreConfig::default()).unwrap();
        let topo = Topology::small(1, 1, 1);
        let script =
            RunScript::new(topo, StoreConfig::default(), lustre, Kernels::fallback());

        let mut sched = Scheduler::new(16);
        let job = sched.submit(Job::new("mongo-deploy", 4, 3600)).unwrap();
        let hosts = sched.hosts_of(job).expect("job should start").to_vec();
        let dep = script.deploy(&hosts).unwrap();
        assert_eq!(dep.client_pes(), 1);
        dep.teardown().unwrap();
        sched.complete(job).unwrap();
    }
}
