//! Cluster-scale simulation of the paper's ingest and query experiments.
//!
//! Structure mirrors the live cluster exactly: client PEs run a closed
//! `insertMany` loop against their pinned router; routers partition
//! batches across shards; shards pay storage-engine and journal-to-OST
//! costs; chunk splits serialize through the config server, whose
//! metadata work grows with chunk count *and* cluster size (map clone +
//! push to every shard and router) — the metadata-churn term that, with
//! the measured constants, leaves 32→128 near-linear and visibly binds
//! at 256 nodes (the paper: "We are still investigating the limitations
//! at 256 nodes").
//!
//! The fabric is a bisection-bandwidth model of the Gemini torus: an
//! N-node allocation has bisection ∝ N^(2/3) links; uniformly-routed
//! traffic charges half its bytes against it.

use crate::config::{Topology, WorkloadConfig, WriteConcern, TABLE1};
use crate::metrics::Histogram;
use crate::workload::ingest::slice_bounds;
use crate::workload::jobs::{generate_jobs, UserJob};

use super::cost::CostModel;
use super::des::EventQueue;
use super::resources::{FlowMeter, Pool, Resource};

/// Simulation specification.
#[derive(Clone, Debug)]
pub struct SimSpec {
    pub topology: Topology,
    /// Monitored compute nodes emitting metrics (paper ~27k; sim-scaled).
    pub monitored_nodes: u32,
    /// Days of data (Table 1).
    pub days: f64,
    /// insertMany batch size per client PE.
    pub batch: usize,
    /// Chunk split threshold (docs per chunk).
    pub max_chunk_docs: u64,
    /// Storage lifecycle: a shard compacts (checkpoint + journal
    /// truncation) after journaling this many bytes. 0 = off, matching
    /// the live `StoreConfig::checkpoint_bytes` = 0 behaviour.
    pub checkpoint_bytes: u64,
    /// Incremental checkpoints: delta generations per chain before a
    /// compaction rebases into a full snapshot (cost ∝ live set instead
    /// of ∝ new writes). 0 = every compaction is full, matching the
    /// live `StoreConfig::full_checkpoint_chain` = 0 behaviour.
    pub full_checkpoint_chain: u32,
    /// OST count backing the store's scratch directories.
    pub osts: u32,
    /// Balancer migration axis: chunk migrations interleaved with the
    /// ingest phase, spread evenly over the corpus (0 = none, matching
    /// a perfectly hashed workload that never rebalances).
    pub migrations: u32,
    /// Streaming migration batch size (documents per `MigrateBatch`
    /// message, the live `--migration-batch-docs` knob): bounds the
    /// donor's longest contiguous stall while paying one group-commit
    /// frame per batch.
    pub migration_batch: usize,
    /// User jobs for the query phase.
    pub query_jobs: u32,
    /// Read-path axis: with the (node_id, ts) compound index the shard
    /// planner serves the canonical query as one bounded range scan per
    /// node — candidates == matches, no ts-window side scan. Without
    /// it, the single-index plan overscans (intersection superset) and
    /// pays a pass over the ts window's rids.
    pub compound_index: bool,
    /// Read-path axis: raw (zero-copy) candidate matching — each
    /// candidate costs a field probe over the encoded bytes instead of
    /// a full document decode. Matches the live `RawDoc` matcher; off
    /// reproduces the pre-overhaul decode-per-candidate path.
    pub raw_match: bool,
    /// CRUD-mix axis: `updateMany` scatters interleaved with ingest,
    /// expressed per 100 client insert batches (0 = ingest-only, the
    /// paper's workload). Each mutation matches `crud_docs_per_op`
    /// documents spread across every shard and pays the calibrated
    /// `update_doc_ns` per document plus one journal frame per shard
    /// (the live engine journals one `OP_UPDATE_MANY` frame per batch).
    pub updates_per_100_batches: u32,
    /// `deleteMany` scatters per 100 insert batches (see above; the
    /// live engine journals rids only, one `OP_DELETE_MANY` frame).
    pub deletes_per_100_batches: u32,
    /// Documents matched by one updateMany/deleteMany scatter.
    pub crud_docs_per_op: u64,
    /// Concurrent-runtime axis: per-shard MVCC reader threads serving
    /// finds from pinned snapshots (the live `--reader-threads` knob).
    /// 0 = reads run inline on the shard's single event loop; N > 0
    /// models the reader pool as N query-phase servers per shard.
    pub reader_threads: usize,
    /// Aggregation axis: `aggregate` scatters appended to the query
    /// phase (0 = off, the paper's workload). Each matches a 30-minute
    /// window over every monitored node and groups it into
    /// `agg_groups` buckets.
    pub aggregations: u32,
    /// Aggregation push-down (the live `--agg-partial` knob): shards
    /// fold matches into per-group partial rows (`agg_doc_ns` each)
    /// and ship one row per group; off ships every matching document
    /// and the router folds centrally — the full-ship baseline.
    pub agg_partial: bool,
    /// Group cardinality of each simulated aggregation.
    pub agg_groups: u32,
    /// Replication axis (the live `--replicas` knob): members per
    /// replica set. 1 = unreplicated. With N > 1 every primary ships
    /// each sub-batch to N-1 oplog-tailing secondaries — each pays the
    /// calibrated apply CPU plus a journal frame on its own OST, and
    /// the primary's journal doubles (the data leg and its `__oplog`
    /// entry share one atomic frame but both hit the disk stream).
    pub replicas: u32,
    /// Write-concern axis (the live `--write-concern` knob): with
    /// `Majority` the batch ack is held until the replication quorum is
    /// durable (secondaries are identical here, so quorum time = the
    /// secondary round-trip); with `One` the ack leaves at the
    /// primary's group commit and replication rides the fabric/OST
    /// meters as background utilization only.
    pub write_concern: WriteConcern,
    pub cost: CostModel,
    pub seed: u64,
}

impl SimSpec {
    /// The paper's preset for an N-node job (Table 1 days), with the
    /// corpus scaled from 27k to `monitored_nodes` monitored hosts.
    pub fn paper_preset(nodes: u32, cost: CostModel) -> anyhow::Result<Self> {
        let days = TABLE1
            .iter()
            .find(|(n, _)| *n == nodes)
            .map(|(_, d)| *d)
            .unwrap_or(3.0);
        let topology = Topology::paper_preset(nodes)?;
        // "each cluster size is servicing more concurrent queries":
        // every client PE issues finds; two user jobs per PE.
        let query_jobs = topology.client_pes() * 2;
        Ok(Self {
            topology,
            monitored_nodes: 2_048,
            days,
            batch: 1_000,
            // MongoDB's 64 MB chunk ≈ 45k of our ~1.4 KB documents.
            max_chunk_docs: 45_000,
            checkpoint_bytes: 0,
            full_checkpoint_chain: 8,
            osts: 64,
            migrations: 0,
            migration_batch: 1_024,
            query_jobs,
            compound_index: true,
            raw_match: true,
            updates_per_100_batches: 0,
            deletes_per_100_batches: 0,
            crud_docs_per_op: 256,
            reader_threads: 0,
            aggregations: 0,
            agg_partial: true,
            agg_groups: 64,
            replicas: 1,
            write_concern: WriteConcern::Majority,
            cost,
            seed: 0x51712,
        })
    }

    pub fn total_docs(&self) -> u64 {
        (self.days * 1440.0).round() as u64 * self.monitored_nodes as u64
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub nodes: u32,
    pub shards: u32,
    pub routers: u32,
    pub client_pes: u32,
    // Ingest phase.
    pub docs: u64,
    pub ingest_virt_ns: u64,
    pub docs_per_sec: f64,
    pub splits: u64,
    /// Storage-lifecycle compactions across all shards (0 when the
    /// lifecycle is off).
    pub checkpoints: u64,
    /// Compactions that rebased the delta chain into a full snapshot
    /// (the only ones whose cost scales with the live set).
    pub rebases: u64,
    /// Chunk migrations executed during ingest (the balancer axis).
    pub migrations: u64,
    /// `updateMany` scatters executed during ingest (CRUD-mix axis).
    pub updates: u64,
    /// `deleteMany` scatters executed during ingest (CRUD-mix axis).
    pub deletes: u64,
    pub docs_updated: u64,
    pub docs_deleted: u64,
    /// Longest single donor-CPU occupancy a migration batch caused —
    /// the co-scheduled request's worst-case wait behind the stream.
    pub migration_stall_ns: u64,
    pub chunks: u64,
    pub util_shard: f64,
    pub util_router: f64,
    pub util_ost: f64,
    pub util_config: f64,
    pub util_fabric: f64,
    // Query phase.
    pub queries: u64,
    pub query_virt_ns: u64,
    pub queries_per_sec: f64,
    pub query_latency: Histogram,
    /// Aggregation scatters executed in the query phase (the axis).
    pub aggregations: u64,
    /// Shard→router reply payload the aggregations put on the fabric —
    /// partial rows or whole documents depending on `agg_partial`.
    pub agg_reply_bytes: u64,
    pub events: u64,
}

impl SimReport {
    pub fn ingest_row(&self) -> Vec<String> {
        vec![
            self.nodes.to_string(),
            self.shards.to_string(),
            self.client_pes.to_string(),
            crate::util::fmt::human_count(self.docs),
            format!("{:.1}", self.ingest_virt_ns as f64 / 1e9),
            crate::util::fmt::human_count(self.docs_per_sec as u64),
            format!("{:.0}%", self.util_shard * 100.0),
            format!("{:.0}%", self.util_config * 100.0),
            self.splits.to_string(),
        ]
    }

    pub fn query_row(&self) -> Vec<String> {
        use crate::util::fmt::human_duration_ns as d;
        vec![
            self.nodes.to_string(),
            self.client_pes.to_string(),
            self.queries.to_string(),
            format!("{:.1}", self.queries_per_sec),
            d(self.query_latency.p50()),
            d(self.query_latency.p95()),
            d(self.query_latency.p99()),
        ]
    }
}

/// Bisection bandwidth of an N-node 3D-torus allocation (bytes/s).
fn bisection_bps(nodes: u32, link_bps: f64) -> f64 {
    let a = (nodes as f64).powf(1.0 / 3.0);
    4.0 * a * a * link_bps
}

/// The simulator.
pub struct ClusterSim {
    spec: SimSpec,
}

enum Ev {
    /// Client PE starts its next insertMany batch.
    PeBatch { pe: u32 },
    /// Query worker issues its next find.
    WorkerFind { worker: u32, job_idx: usize },
}

impl ClusterSim {
    pub fn new(spec: SimSpec) -> Self {
        Self { spec }
    }

    /// Run ingest then queries; returns the combined report.
    pub fn run(&self) -> SimReport {
        let spec = &self.spec;
        let cost = &spec.cost;
        let topo = &spec.topology;
        let s_count = topo.shards as usize;
        let r_count = topo.routers as usize;
        let pes = topo.client_pes();
        let o_count = spec.osts as usize;

        let mut router_cpu = Pool::new("router", topo.routers, 1);
        let mut shard_cpu = Pool::new("shard", topo.shards, 1);
        let mut ost = Pool::new("ost", spec.osts, 1);
        let mut config = Resource::new("config", 1);
        // Map refreshes are reads served concurrently (and arrive out of
        // event order in the analytic pipeline) — account them as offered
        // load rather than FIFO-serializing them; only split *commits*
        // serialize through the config Resource.
        let mut config_reads = FlowMeter::new("config-reads");
        let mut fabric = FlowMeter::new("fabric");
        let bisection = bisection_bps(topo.total_nodes, cost.link_bandwidth_bps);
        let fabric_ns = |bytes: f64| -> u64 { ((bytes / 2.0) / bisection * 1e9) as u64 };
        let ost_ns = |bytes: f64| -> u64 {
            (bytes / (cost.ost_bandwidth_mib_s * 1024.0 * 1024.0) * 1e9) as u64
        };

        // --- Ingest phase -------------------------------------------------
        let total_docs = spec.total_docs();
        let mut remaining: Vec<u64> = (0..pes as usize)
            .map(|pe| {
                let (lo, hi) = slice_bounds(total_docs, pes as usize, pe);
                hi - lo
            })
            .collect();
        // Per-shard chunk accounting (uniform hashed spread).
        let mut shard_docs = vec![0u64; s_count];
        let mut shard_chunks = vec![2u64; s_count]; // pre-split 2/shard
        // Next split point per shard, with deterministic +/-10% jitter on
        // each increment: real auto-split triggers de-synchronize across
        // shards, while exactly-uniform hashing would fire every shard's
        // split in the same instant (a thundering herd the real system
        // does not exhibit at this severity).
        let jitter = |s: usize, generation: u64| -> u64 {
            let h = crate::util::hash::fnv1a_shard_key(s as u32, generation as u32);
            (spec.max_chunk_docs as f64 * (0.9 + 0.2 * (h as f64 / u32::MAX as f64))) as u64
        };
        let mut next_split_at: Vec<u64> =
            (0..s_count).map(|s| 2 * jitter(s, 0)).collect();
        let mut splits = 0u64;
        // Storage lifecycle: journal bytes and docs since each shard's
        // last compaction, compactions performed, and each shard's delta
        // chain length (seeded at the rebase threshold so the first
        // compaction writes a full snapshot — generation 1, as live).
        let mut shard_ckpt_bytes = vec![0u64; s_count];
        let mut shard_delta_docs = vec![0u64; s_count];
        let mut shard_chain = vec![spec.full_checkpoint_chain as u64; s_count];
        let mut checkpoints = 0u64;
        let mut rebases = 0u64;
        // Routers that must refresh + re-route their next batch because
        // a split bumped the map version (the stale-version storm).
        let mut stale_routers = vec![0u32; r_count];
        // Balancer migration axis: one chunk moves after every
        // `mig_every` ingested documents.
        let mig_every = if spec.migrations > 0 {
            (total_docs / (spec.migrations as u64 + 1)).max(1)
        } else {
            u64::MAX
        };
        let mut next_migration_at = mig_every;
        let mut migrations_done = 0u64;
        let mut migration_stall = 0u64;
        // CRUD-mix axis bookkeeping.
        let mut batches_done = 0u64;
        let mut updates_done = 0u64;
        let mut deletes_done = 0u64;
        let mut docs_updated = 0u64;
        let mut docs_deleted = 0u64;

        let mut q: EventQueue<Ev> = EventQueue::new();
        for pe in 0..pes {
            q.push(0, Ev::PeBatch { pe });
        }
        let mut docs_done = 0u64;
        let mut ingest_end = 0u64;

        while let Some((t, ev)) = q.pop() {
            let Ev::PeBatch { pe } = ev else { unreachable!("ingest phase") };
            let left = remaining[pe as usize];
            if left == 0 {
                continue;
            }
            let b = (spec.batch as u64).min(left) as usize;
            remaining[pe as usize] -= b as u64;
            docs_done += b as u64;

            // Client PE synthesizes/parses the batch (serial on the PE).
            let t_gen = t + (b as f64 * cost.gen_doc_ns) as u64;
            // Client → router over the fabric.
            let batch_bytes = b as f64 * cost.doc_bytes;
            let t_net1 = fabric.serve(t_gen, fabric_ns(batch_bytes)) + cost.net_latency_ns as u64;
            // Router partitions (route kernel + dispatch). A router that
            // saw StaleVersion since its last batch first wastes one
            // routing attempt, then refreshes the chunk map from the
            // config server (fixed RPC + per-entry serialize + RTT).
            let r = (pe as usize) % r_count;
            let mut route_svc = (cost.route_batch_fixed_ns
                + b as f64 * (cost.route_doc_ns + cost.dispatch_doc_ns))
                as u64;
            if stale_routers[r] > 0 {
                stale_routers[r] = 0; // one refresh covers all pending bumps
                let chunks_now: u64 = shard_chunks.iter().sum();
                let refresh = config_reads.serve(
                    t_net1,
                    (cost.refresh_fixed_ns + chunks_now as f64 * cost.map_entry_ns) as u64,
                ) - t_net1
                    + 2 * cost.net_latency_ns as u64;
                // Wasted work = re-partitioning the rejected sub-batch
                // (what the live router actually does on StaleVersion).
                let reroute = (cost.route_batch_fixed_ns
                    + (b / s_count) as f64 * (cost.route_doc_ns + cost.dispatch_doc_ns))
                    as u64;
                route_svc += reroute + refresh;
            }
            let t_routed = router_cpu.serve(r, t_net1, route_svc);
            // Router → shards; every shard gets ~b/S (hashed uniform).
            let t_net2 = fabric.serve(t_routed, fabric_ns(batch_bytes)) + cost.net_latency_ns as u64;
            let base = b / s_count;
            let rem = b % s_count;
            let mut t_done = t_net2;
            for s in 0..s_count {
                let b_s = base + usize::from(s < rem);
                if b_s == 0 {
                    continue;
                }
                let insert_svc = (b_s as f64 * cost.insert_doc_ns) as u64;
                let t_ins = shard_cpu.serve(s, t_net2, insert_svc);
                // Journal lands on the shard's OSTs: one group-commit
                // frame per sub-batch (fixed term the batch amortizes)
                // plus the per-byte stream. A replicated primary
                // journals the data leg *and* its oplog entry in one
                // atomic frame — same frame count, double the bytes.
                let j_mult = if spec.replicas > 1 { 2.0 } else { 1.0 };
                let t_j = ost.serve(
                    s % o_count,
                    t_ins,
                    ost_ns(b_s as f64 * cost.journal_bytes_per_doc * j_mult)
                        + cost.journal_frame_ns as u64,
                );
                let mut t_s = t_j;
                // Replication axis: ship the sub-batch to the N-1
                // secondaries. Each is a dedicated member thread whose
                // arrival stream mirrors the primary's, so its apply is
                // charged as service time (no extra queueing beyond the
                // fabric and its own OST). Secondaries are identical,
                // so the majority quorum's slowest member is any one of
                // them — w:majority gates the ack on that round-trip;
                // w:1 leaves the traffic on the meters as background.
                if spec.replicas > 1 {
                    let mut t_repl = t_j;
                    for k in 0..(spec.replicas - 1) as usize {
                        let t_ship = fabric
                            .serve(t_j, fabric_ns(b_s as f64 * cost.doc_bytes))
                            + cost.net_latency_ns as u64;
                        let apply = (b_s as f64 * cost.insert_doc_ns) as u64;
                        let t_dur = ost.serve(
                            (s + (k + 1) * s_count) % o_count,
                            t_ship + apply,
                            ost_ns(b_s as f64 * cost.journal_bytes_per_doc * j_mult)
                                + cost.journal_frame_ns as u64,
                        );
                        // Ack crosses back over the fabric's latency.
                        t_repl = t_repl.max(t_dur + cost.net_latency_ns as u64);
                    }
                    if spec.write_concern == WriteConcern::Majority {
                        t_s = t_s.max(t_repl);
                    }
                }
                shard_docs[s] += b_s as u64;
                // Storage lifecycle: past the journal threshold the
                // shard compacts before acking the triggering batch.
                // Steady state writes a *delta* — serialize and stream
                // only the docs since the last compaction; once the
                // chain reaches `full_checkpoint_chain` it rebases,
                // paying the full live set once per chain.
                if spec.checkpoint_bytes > 0 {
                    shard_ckpt_bytes[s] += (b_s as f64 * cost.journal_bytes_per_doc) as u64;
                    shard_delta_docs[s] += b_s as u64;
                    if shard_ckpt_bytes[s] >= spec.checkpoint_bytes {
                        shard_ckpt_bytes[s] = 0;
                        checkpoints += 1;
                        let full = spec.full_checkpoint_chain == 0
                            || shard_chain[s] >= spec.full_checkpoint_chain as u64;
                        let (docs_serialized, per_doc_ns) = if full {
                            shard_chain[s] = 0;
                            rebases += 1;
                            (shard_docs[s], cost.rebase_doc_ns)
                        } else {
                            shard_chain[s] += 1;
                            (shard_delta_docs[s], cost.checkpoint_doc_ns)
                        };
                        shard_delta_docs[s] = 0;
                        let ckpt_cpu = (docs_serialized as f64 * per_doc_ns) as u64;
                        let t_cpu = shard_cpu.serve(s, t_j, ckpt_cpu);
                        t_s = ost.serve(
                            s % o_count,
                            t_cpu,
                            ost_ns(docs_serialized as f64 * cost.doc_bytes),
                        );
                    }
                }
                // Chunk split when the shard's fullest chunk crosses the
                // threshold (uniform spread over its chunks).
                if shard_docs[s] > next_split_at[s] {
                    let total_chunks: u64 = shard_chunks.iter().sum();
                    // Commit + push the new map to every shard (routers
                    // pull lazily on their next stale batch).
                    let split_svc = (cost.split_base_ns
                        + s_count as f64
                            * (cost.refresh_fixed_ns
                                + total_chunks as f64 * cost.map_entry_ns))
                        as u64;
                    // The triggering batch stalls until the config server
                    // commits the split (stale-version handshake) — and
                    // until any compaction it also triggered finishes.
                    t_s = t_s.max(config.serve(t_j, split_svc));
                    shard_chunks[s] += 1;
                    next_split_at[s] += jitter(s, shard_chunks[s]);
                    splits += 1;
                    for v in stale_routers.iter_mut() {
                        *v += 1;
                    }
                }
                t_done = t_done.max(t_s);
            }
            // Balancer migration axis: the stream is charged in
            // `migration_batch`-sized steps — each batch occupies the
            // donor CPU once (extract), crosses the fabric, and pays
            // the recipient install plus one group-commit frame on its
            // OST. Co-scheduled ingest requests queue behind at most
            // one batch of donor work (invariant IM2 of the live
            // protocol), which is what `migration_stall_ns` records.
            while docs_done >= next_migration_at
                && migrations_done < spec.migrations as u64
            {
                next_migration_at = next_migration_at.saturating_add(mig_every);
                let donor = (migrations_done as usize) % s_count;
                let dst = (donor + 1) % s_count;
                let chunk_docs = (shard_docs[donor] / shard_chunks[donor].max(1)).max(1);
                let mb = spec.migration_batch.max(1) as u64;
                let mut left = chunk_docs;
                let mut tm = t_done;
                while left > 0 {
                    let b_m = left.min(mb);
                    left -= b_m;
                    let extract = (b_m as f64 * cost.migrate_doc_ns / 2.0) as u64;
                    let t_x = shard_cpu.serve(donor, tm, extract);
                    migration_stall = migration_stall.max(extract);
                    let t_net = fabric.serve(t_x, fabric_ns(b_m as f64 * cost.doc_bytes))
                        + cost.net_latency_ns as u64;
                    let install = (b_m as f64 * cost.migrate_doc_ns / 2.0) as u64
                        + cost.journal_frame_ns as u64;
                    let t_i = shard_cpu.serve(dst, t_net, install);
                    tm = ost.serve(
                        dst % o_count,
                        t_i,
                        ost_ns(b_m as f64 * cost.journal_bytes_per_doc),
                    );
                }
                // Source range delete + the triggered post-commit
                // compaction (a delta of the deleted range): the
                // storage hand-back the lifecycle balancer guarantees.
                // One contiguous donor occupancy — an atomic delete
                // frame cannot stream — so it counts toward the stall
                // too (it floors the stall curve at small batch sizes).
                let cleanup = (chunk_docs as f64 * cost.checkpoint_doc_ns) as u64;
                shard_cpu.serve(donor, tm, cleanup);
                migration_stall = migration_stall.max(cleanup);
                let moved = chunk_docs.min(shard_docs[donor]);
                shard_docs[donor] -= moved;
                shard_docs[dst] += moved;
                if shard_chunks[donor] > 1 {
                    shard_chunks[donor] -= 1;
                    shard_chunks[dst] += 1;
                }
                migrations_done += 1;
            }
            // CRUD-mix axis: updateMany / deleteMany scatters issued by
            // the same closed-loop PEs, paced per 100 insert batches.
            // The router broadcasts (mutation filters rarely pin every
            // shard key); each shard walks its share of the matches
            // through the index + raw matcher, rewrites (or kills) the
            // versions, and pays one group-commit journal frame — the
            // live engine journals one OP_UPDATE_MANY / OP_DELETE_MANY
            // frame per batch, full replacement bytes for updates, rids
            // only for deletes.
            batches_done += 1;
            let per_shard = (spec.crud_docs_per_op as f64 / s_count as f64).max(1.0);
            while updates_done < batches_done * spec.updates_per_100_batches as u64 / 100 {
                updates_done += 1;
                docs_updated += spec.crud_docs_per_op;
                let t_r = router_cpu
                    .serve((pe as usize) % r_count, t_done, cost.route_batch_fixed_ns as u64);
                for s in 0..s_count {
                    let svc = (cost.find_fixed_ns
                        + per_shard
                            * (cost.index_candidate_ns
                                + cost.doc_probe_ns
                                + cost.update_doc_ns)) as u64;
                    let t_s = shard_cpu.serve(s, t_r + cost.net_latency_ns as u64, svc);
                    let t_j = ost.serve(
                        s % o_count,
                        t_s,
                        ost_ns(per_shard * cost.journal_bytes_per_doc)
                            + cost.journal_frame_ns as u64,
                    );
                    t_done = t_done.max(t_j + cost.net_latency_ns as u64);
                }
            }
            while deletes_done < batches_done * spec.deletes_per_100_batches as u64 / 100 {
                deletes_done += 1;
                docs_deleted += spec.crud_docs_per_op;
                let t_r = router_cpu
                    .serve((pe as usize) % r_count, t_done, cost.route_batch_fixed_ns as u64);
                for s in 0..s_count {
                    let svc = (cost.find_fixed_ns
                        + per_shard
                            * (cost.index_candidate_ns
                                + cost.doc_probe_ns
                                + cost.delete_doc_ns)) as u64;
                    let t_s = shard_cpu.serve(s, t_r + cost.net_latency_ns as u64, svc);
                    // Rid-only journal frame: 8 bytes per killed doc.
                    let t_j = ost.serve(
                        s % o_count,
                        t_s,
                        ost_ns(per_shard * 8.0) + cost.journal_frame_ns as u64,
                    );
                    t_done = t_done.max(t_j + cost.net_latency_ns as u64);
                    shard_docs[s] -= (per_shard as u64).min(shard_docs[s]);
                }
            }
            // Ack back to the client; next batch.
            let t_ack = t_done + cost.net_latency_ns as u64;
            ingest_end = ingest_end.max(t_ack);
            q.push(t_ack, Ev::PeBatch { pe });
        }
        debug_assert_eq!(docs_done, total_docs);
        let ingest_events = q.processed();

        let dbg_shard_wait = shard_cpu.resources.iter().map(|r| r.mean_wait_ns()).sum::<f64>()
            / shard_cpu.len() as f64;
        let dbg_router_wait = router_cpu.resources.iter().map(|r| r.mean_wait_ns()).sum::<f64>()
            / router_cpu.len() as f64;
        let dbg_config_wait = config.mean_wait_ns();
        if std::env::var("SIM_DEBUG").is_ok() {
            eprintln!(
                "sim waits: shard {dbg_shard_wait:.0}ns router {dbg_router_wait:.0}ns config {dbg_config_wait:.0}ns fabric {:.0}ns gen_first {:.0}ns",
                0.0, cost.gen_doc_ns * spec.batch as f64
            );
        }
        let util_shard = shard_cpu.mean_utilization(ingest_end);
        let util_router = router_cpu.mean_utilization(ingest_end);
        let util_ost = ost.mean_utilization(ingest_end);
        let util_config = config.utilization(ingest_end)
            + config_reads.utilization(ingest_end);
        let util_fabric = fabric.utilization(ingest_end);

        // --- Query phase ---------------------------------------------------
        // Fresh resources: the query experiment runs on the ingested
        // store ("each cluster size is servicing more concurrent
        // queries" — concurrency = client PEs). With reader_threads = 0
        // every shard serves finds on its single event loop; with N > 0
        // the MVCC reader pool gives each shard N concurrent servers
        // (snapshot reads never block on the writer).
        let mut router_cpu = Pool::new("router", topo.routers, 1);
        let mut shard_cpu =
            Pool::new("shard", topo.shards, spec.reader_threads.max(1) as u32);
        let mut fabric = FlowMeter::new("fabric");
        let wl = WorkloadConfig {
            monitored_nodes: spec.monitored_nodes,
            days: spec.days,
            query_jobs: spec.query_jobs,
            seed: spec.seed,
            ..Default::default()
        };
        let jobs: Vec<UserJob> = generate_jobs(&wl);
        let _minutes = wl.minutes();
        let workers = pes;
        let mut latency = Histogram::new();
        let mut q: EventQueue<Ev> = EventQueue::new();
        for w in 0..workers {
            if (w as usize) < jobs.len() {
                q.push(0, Ev::WorkerFind { worker: w, job_idx: w as usize });
            }
        }
        let mut queries = 0u64;
        let mut query_end = 0u64;
        while let Some((t, ev)) = q.pop() {
            let Ev::WorkerFind { worker, job_idx } = ev else { unreachable!("query phase") };
            let job = &jobs[job_idx];
            // Router scatters the find.
            let r = (worker as usize) % r_count;
            let t_r = router_cpu.serve(r, t, cost.route_batch_fixed_ns as u64);
            // Per-shard execution, mirroring the live planner's two
            // regimes. Compound (node_id, ts): one bounded range scan
            // per node — candidates == matches, no ts-window side
            // scan. Single-index fallback: node_id point lookups
            // intersected with the ts-range scan — candidates are a
            // small overscan and the ts leg costs one pass over the
            // window's rids. Per candidate the shard pays an index step
            // + the kernel mask + either a raw field probe (`RawDoc`)
            // or, pre-overhaul, a full decode; each *returned* document
            // still pays fetch + serialize (`result_doc_ns`, measured
            // through the decoding fetch).
            let matches_per_shard = job.expected_docs() as f64 / s_count as f64;
            let window_rids_per_shard = (spec.monitored_nodes as f64
                * job.duration_min as f64
                / s_count as f64)
                .ceil();
            let candidates_per_shard = if spec.compound_index {
                matches_per_shard
            } else {
                matches_per_shard * 1.25 + 64.0
            };
            let ts_leg = if spec.compound_index { 0.0 } else { window_rids_per_shard };
            let per_candidate = cost.index_candidate_ns
                + cost.route_doc_ns // kernel mask
                + if spec.raw_match { cost.doc_probe_ns } else { cost.doc_decode_ns };
            let mut t_done = t_r;
            for s in 0..s_count {
                let svc = (cost.find_fixed_ns
                    + ts_leg * cost.index_candidate_ns
                    + candidates_per_shard * per_candidate
                    + matches_per_shard * cost.result_doc_ns)
                    as u64;
                let t_s = shard_cpu.serve(s, t_r + cost.net_latency_ns as u64, svc);
                // Results stream back over the fabric.
                let t_net =
                    fabric.serve(t_s, fabric_ns(matches_per_shard * cost.doc_bytes));
                t_done = t_done.max(t_net + cost.net_latency_ns as u64);
            }
            // Router merge.
            let merge_svc =
                (job.expected_docs() as f64 * cost.merge_doc_ns) as u64;
            let t_m = router_cpu.serve(r, t_done, merge_svc);
            latency.record(t_m - t);
            queries += 1;
            query_end = query_end.max(t_m);
            let next = job_idx + workers as usize;
            if next < jobs.len() {
                q.push(t_m, Ev::WorkerFind { worker, job_idx: next });
            }
        }

        // Aggregation axis: pipeline scatters appended to the query
        // phase. Each matches a 30-minute window over every monitored
        // node. Push-down: the shard folds each match into its partial
        // table (index step + raw-probe fold, no decode) and ships one
        // ~row per group; the router merges groups × shards rows.
        // Full-ship: each match pays probe + fetch/serialize, crosses
        // the fabric whole, and the router folds centrally at the same
        // per-document cost the shards would have paid.
        let mut aggregations_done = 0u64;
        let mut agg_reply_bytes = 0u64;
        // One accumulator row on the wire: group key + a few tagged
        // (op, f64/u64) accumulator states.
        const AGG_ROW_BYTES: f64 = 48.0;
        for a in 0..spec.aggregations {
            let r = (a as usize) % r_count;
            let t_r = router_cpu.serve(r, query_end, cost.route_batch_fixed_ns as u64);
            let matches_per_shard =
                (spec.monitored_nodes as f64 * 30.0 / s_count as f64).max(1.0);
            let rows_per_shard = (spec.agg_groups.max(1) as f64).min(matches_per_shard);
            let mut t_done = t_r;
            for s in 0..s_count {
                let (svc, reply_bytes) = if spec.agg_partial {
                    (
                        (cost.find_fixed_ns
                            + matches_per_shard
                                * (cost.index_candidate_ns + cost.agg_doc_ns))
                            as u64,
                        rows_per_shard * AGG_ROW_BYTES,
                    )
                } else {
                    (
                        (cost.find_fixed_ns
                            + matches_per_shard
                                * (cost.index_candidate_ns
                                    + cost.doc_probe_ns
                                    + cost.result_doc_ns)) as u64,
                        matches_per_shard * cost.doc_bytes,
                    )
                };
                agg_reply_bytes += reply_bytes as u64;
                let t_s = shard_cpu.serve(s, t_r + cost.net_latency_ns as u64, svc);
                let t_net = fabric.serve(t_s, fabric_ns(reply_bytes));
                t_done = t_done.max(t_net + cost.net_latency_ns as u64);
            }
            let merge_svc = if spec.agg_partial {
                (rows_per_shard * s_count as f64 * cost.agg_merge_group_ns) as u64
            } else {
                (matches_per_shard * s_count as f64 * cost.agg_doc_ns) as u64
            };
            let t_m = router_cpu.serve(r, t_done, merge_svc);
            query_end = query_end.max(t_m);
            aggregations_done += 1;
        }

        SimReport {
            nodes: topo.total_nodes,
            shards: topo.shards,
            routers: topo.routers,
            client_pes: pes,
            docs: total_docs,
            ingest_virt_ns: ingest_end,
            docs_per_sec: total_docs as f64 * 1e9 / ingest_end.max(1) as f64,
            splits,
            checkpoints,
            rebases,
            migrations: migrations_done,
            updates: updates_done,
            deletes: deletes_done,
            docs_updated,
            docs_deleted,
            migration_stall_ns: migration_stall,
            chunks: shard_chunks.iter().sum(),
            util_shard,
            util_router,
            util_ost,
            util_config,
            util_fabric,
            queries,
            query_virt_ns: query_end,
            queries_per_sec: queries as f64 * 1e9 / query_end.max(1) as f64,
            query_latency: latency,
            aggregations: aggregations_done,
            agg_reply_bytes,
            events: ingest_events + q.processed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec(nodes: u32) -> SimSpec {
        let mut s = SimSpec::paper_preset(nodes, CostModel::default()).unwrap();
        // Compress the corpus 8x for test speed; the config-churn /
        // shard-work ratio scales as docs/K², so K compresses by √8 to
        // preserve the full-scale behaviour.
        s.monitored_nodes = 256;
        s.max_chunk_docs = 16_000;
        s
    }

    #[test]
    fn ingest_completes_all_docs() {
        let spec = small_spec(32);
        let total = spec.total_docs();
        let r = ClusterSim::new(spec).run();
        assert_eq!(r.docs, total);
        assert!(r.ingest_virt_ns > 0);
        assert!(r.docs_per_sec > 0.0);
        assert!(r.queries > 0);
        assert!(r.query_latency.count() == r.queries);
    }

    #[test]
    fn shards_are_the_busy_resource_at_small_scale() {
        let r = ClusterSim::new(small_spec(32)).run();
        assert!(
            r.util_shard > r.util_router && r.util_shard > r.util_fabric,
            "shard {:.2} router {:.2} fabric {:.2}",
            r.util_shard,
            r.util_router,
            r.util_fabric
        );
        assert!(r.util_shard > 0.5, "closed loop should keep shards busy");
    }

    #[test]
    fn scaling_is_near_linear_32_to_128() {
        let r32 = ClusterSim::new(small_spec(32)).run();
        let r64 = ClusterSim::new(small_spec(64)).run();
        let r128 = ClusterSim::new(small_spec(128)).run();
        let s64 = r64.docs_per_sec / r32.docs_per_sec;
        let s128 = r128.docs_per_sec / r32.docs_per_sec;
        // Shard count ratios are 15/7 ≈ 2.14 and 31/7 ≈ 4.43.
        assert!(s64 > 1.7 && s64 < 2.5, "64-node speedup {s64}");
        assert!(s128 > 3.3 && s128 < 5.0, "128-node speedup {s128}");
    }

    #[test]
    fn config_pressure_grows_at_256() {
        let r128 = ClusterSim::new(small_spec(128)).run();
        let r256 = ClusterSim::new(small_spec(256)).run();
        assert!(
            r256.util_config > r128.util_config,
            "config util should grow: {} vs {}",
            r256.util_config,
            r128.util_config
        );
        // Efficiency per shard drops at 256.
        let eff128 = r128.docs_per_sec / r128.shards as f64;
        let eff256 = r256.docs_per_sec / r256.shards as f64;
        assert!(
            eff256 < eff128,
            "per-shard efficiency should drop: {eff256} vs {eff128}"
        );
    }

    #[test]
    fn query_latency_roughly_flat_across_sizes() {
        let r32 = ClusterSim::new(small_spec(32)).run();
        let r128 = ClusterSim::new(small_spec(128)).run();
        let p50_32 = r32.query_latency.p50() as f64;
        let p50_128 = r128.query_latency.p50() as f64;
        // "cluster size maintains a similar query performance" — within
        // a small factor despite 4x concurrency.
        let ratio = p50_128 / p50_32.max(1.0);
        assert!(ratio < 3.0 && ratio > 0.2, "latency ratio {ratio}");
    }

    #[test]
    fn compound_and_raw_axes_speed_up_the_query_phase() {
        // Same corpus and concurrency; only the read-path regime moves.
        let base = small_spec(32);
        let mut legacy = base.clone();
        legacy.compound_index = false;
        legacy.raw_match = false;
        let mut raw_only = legacy.clone();
        raw_only.raw_match = true;
        let r_new = ClusterSim::new(base).run();
        let r_raw = ClusterSim::new(raw_only).run();
        let r_old = ClusterSim::new(legacy).run();
        assert_eq!(r_new.queries, r_old.queries);
        assert!(
            r_raw.query_virt_ns <= r_old.query_virt_ns,
            "raw matching cannot be slower than decode-per-candidate"
        );
        assert!(
            r_new.query_virt_ns < r_old.query_virt_ns,
            "compound+raw ({}) must beat the pre-overhaul path ({})",
            r_new.query_virt_ns,
            r_old.query_virt_ns
        );
    }

    #[test]
    fn replication_axis_slows_majority_acks_but_not_w1() {
        // w:majority with 3 members gates every batch ack on a
        // secondary round-trip — ingest must take strictly longer than
        // unreplicated. w:1 keeps replication off the ack path; only
        // background fabric/OST load moves, so the slowdown is far
        // smaller than majority's.
        let base = small_spec(32);
        let mut majority = base.clone();
        majority.replicas = 3;
        majority.write_concern = WriteConcern::Majority;
        let mut w1 = base.clone();
        w1.replicas = 3;
        w1.write_concern = WriteConcern::One;
        let r_base = ClusterSim::new(base).run();
        let r_maj = ClusterSim::new(majority).run();
        let r_w1 = ClusterSim::new(w1).run();
        assert_eq!(r_base.docs, r_maj.docs);
        assert_eq!(r_base.docs, r_w1.docs);
        assert!(
            r_maj.ingest_virt_ns > r_base.ingest_virt_ns,
            "w:majority replication must cost ingest time: {} vs {}",
            r_maj.ingest_virt_ns,
            r_base.ingest_virt_ns
        );
        assert!(
            r_w1.ingest_virt_ns <= r_maj.ingest_virt_ns,
            "w:1 must not be slower than w:majority: {} vs {}",
            r_w1.ingest_virt_ns,
            r_maj.ingest_virt_ns
        );
    }

    #[test]
    fn reader_pool_speeds_up_the_query_phase_only() {
        // The MVCC reader-pool axis: extra query-phase servers per
        // shard cut queueing under concurrent finds, and touch nothing
        // in the ingest phase (writes stay on the event loop).
        let base = small_spec(32);
        let mut pooled = base.clone();
        pooled.reader_threads = 2;
        let r0 = ClusterSim::new(base).run();
        let r2 = ClusterSim::new(pooled).run();
        assert_eq!(r0.docs, r2.docs);
        assert_eq!(r0.queries, r2.queries);
        assert_eq!(
            r0.ingest_virt_ns, r2.ingest_virt_ns,
            "reader threads must not touch the ingest phase"
        );
        assert!(
            r2.query_virt_ns < r0.query_virt_ns,
            "2 readers/shard ({} ns) must beat inline reads ({} ns)",
            r2.query_virt_ns,
            r0.query_virt_ns
        );
        assert!(
            r2.query_latency.p99() <= r0.query_latency.p99(),
            "pooled p99 {} cannot exceed inline p99 {}",
            r2.query_latency.p99(),
            r0.query_latency.p99()
        );
    }

    #[test]
    fn lifecycle_checkpoints_fire_and_preserve_totals() {
        let base_spec = small_spec(32);
        let base = ClusterSim::new(base_spec.clone()).run();
        assert_eq!(base.checkpoints, 0, "lifecycle off by default in the sim");
        assert_eq!(base.rebases, 0);
        let mut spec = base_spec;
        spec.checkpoint_bytes = 8 * 1024 * 1024;
        let r = ClusterSim::new(spec).run();
        assert_eq!(r.docs, base.docs, "compaction must not change the corpus");
        assert!(r.checkpoints > 0, "sustained ingest should compact");
        assert!(r.rebases > 0, "the first compaction per shard is a rebase");
        assert!(r.rebases < r.checkpoints, "steady state must be deltas, not rebases");
        assert!(
            r.ingest_virt_ns >= base.ingest_virt_ns,
            "compaction work cannot make ingest faster"
        );
    }

    #[test]
    fn delta_checkpoints_beat_always_full_compaction() {
        // Same workload, same compaction cadence; the only difference is
        // whether each compaction serializes the delta or the live set.
        let mut delta = small_spec(32);
        delta.checkpoint_bytes = 8 * 1024 * 1024;
        delta.full_checkpoint_chain = 8;
        let mut full = delta.clone();
        full.full_checkpoint_chain = 0;
        let rd = ClusterSim::new(delta).run();
        let rf = ClusterSim::new(full).run();
        assert_eq!(rd.docs, rf.docs);
        assert_eq!(rd.checkpoints, rf.checkpoints, "cadence is byte-driven, not chain-driven");
        assert!(rf.rebases == rf.checkpoints, "chain=0 means every compaction is full");
        assert!(rd.rebases < rd.checkpoints);
        assert!(
            rd.ingest_virt_ns <= rf.ingest_virt_ns,
            "delta compaction ({} ns) cannot be slower than always-full ({} ns)",
            rd.ingest_virt_ns,
            rf.ingest_virt_ns
        );
    }

    #[test]
    fn per_frame_journal_cost_rewards_batching() {
        // With the frame term in the model, tiny batches pay one fixed
        // journal cost per handful of documents and must ingest slower.
        let mut small_batch = small_spec(32);
        small_batch.batch = 8;
        let mut big_batch = small_spec(32);
        big_batch.batch = 1_000;
        let rs = ClusterSim::new(small_batch).run();
        let rb = ClusterSim::new(big_batch).run();
        assert!(
            rb.docs_per_sec > rs.docs_per_sec * 1.2,
            "batch=1000 {} should beat batch=8 {} clearly",
            rb.docs_per_sec,
            rs.docs_per_sec
        );
    }

    #[test]
    fn deterministic_runs() {
        let a = ClusterSim::new(small_spec(32)).run();
        let b = ClusterSim::new(small_spec(32)).run();
        assert_eq!(a.ingest_virt_ns, b.ingest_virt_ns);
        assert_eq!(a.splits, b.splits);
        assert_eq!(a.query_latency.p99(), b.query_latency.p99());
    }

    #[test]
    fn migrations_cost_ingest_time_but_not_documents() {
        let base = ClusterSim::new(small_spec(32)).run();
        assert_eq!(base.migrations, 0, "axis off by default");
        assert_eq!(base.migration_stall_ns, 0);
        let mut spec = small_spec(32);
        spec.migrations = 8;
        let r = ClusterSim::new(spec).run();
        assert_eq!(r.docs, base.docs, "migrations must not change the corpus");
        assert_eq!(r.migrations, 8);
        assert!(r.migration_stall_ns > 0);
        assert!(
            r.ingest_virt_ns >= base.ingest_virt_ns,
            "migration work cannot make ingest faster"
        );
    }

    #[test]
    fn smaller_migration_batches_bound_the_donor_stall() {
        // The whole point of the streaming protocol: the donor's
        // longest contiguous stall scales with the batch size, at the
        // price of more per-batch fixed costs.
        let mut big = small_spec(32);
        big.migrations = 4;
        big.migration_batch = 16_384;
        let mut small = big.clone();
        small.migration_batch = 256;
        let rb = ClusterSim::new(big).run();
        let rs = ClusterSim::new(small).run();
        assert_eq!(rb.docs, rs.docs);
        assert!(
            rs.migration_stall_ns * 4 < rb.migration_stall_ns,
            "batch=256 stall {} must be far below batch=16384 stall {}",
            rs.migration_stall_ns,
            rb.migration_stall_ns
        );
    }

    #[test]
    fn crud_mix_costs_ingest_time_but_not_corpus() {
        let base = ClusterSim::new(small_spec(32)).run();
        assert_eq!(base.updates, 0, "axis off by default");
        assert_eq!(base.deletes, 0);
        let mut spec = small_spec(32);
        spec.updates_per_100_batches = 20;
        spec.deletes_per_100_batches = 10;
        let r = ClusterSim::new(spec).run();
        assert_eq!(r.docs, base.docs, "mutations must not change the ingested corpus");
        assert!(r.updates > 0 && r.deletes > 0);
        assert_eq!(r.docs_updated, r.updates * 256);
        assert_eq!(r.docs_deleted, r.deletes * 256);
        assert!(
            r.ingest_virt_ns > base.ingest_virt_ns,
            "mutation work must cost virtual time"
        );
    }

    #[test]
    fn update_heavy_mix_costs_more_than_delete_heavy() {
        // Same op cadence; updates rewrite full documents (and journal
        // their bytes), deletes journal rids — the calibrated terms
        // must order the two profiles.
        let mut upd = small_spec(32);
        upd.updates_per_100_batches = 30;
        let mut del = small_spec(32);
        del.deletes_per_100_batches = 30;
        let ru = ClusterSim::new(upd).run();
        let rd = ClusterSim::new(del).run();
        assert_eq!(ru.updates, rd.deletes, "same cadence must yield same op count");
        assert!(
            ru.ingest_virt_ns > rd.ingest_virt_ns,
            "update-heavy ({} ns) must cost more than delete-heavy ({} ns)",
            ru.ingest_virt_ns,
            rd.ingest_virt_ns
        );
    }

    #[test]
    fn aggregation_axis_off_by_default_and_costs_query_time() {
        let base = ClusterSim::new(small_spec(32)).run();
        assert_eq!(base.aggregations, 0, "axis off by default");
        assert_eq!(base.agg_reply_bytes, 0);
        let mut spec = small_spec(32);
        spec.aggregations = 16;
        let r = ClusterSim::new(spec).run();
        assert_eq!(r.aggregations, 16);
        assert!(r.agg_reply_bytes > 0);
        assert_eq!(r.docs, base.docs, "aggregations must not change the corpus");
        assert_eq!(
            r.ingest_virt_ns, base.ingest_virt_ns,
            "the axis lives in the query phase only"
        );
        assert!(
            r.query_virt_ns > base.query_virt_ns,
            "aggregation work must cost virtual time"
        );
    }

    #[test]
    fn partial_aggregation_beats_full_ship() {
        // Same scatters, same matches; the only difference is whether
        // shards ship per-group rows or whole matching documents.
        let mut partial = small_spec(32);
        partial.aggregations = 16;
        partial.agg_groups = 8;
        partial.agg_partial = true;
        let mut full = partial.clone();
        full.agg_partial = false;
        let rp = ClusterSim::new(partial).run();
        let rf = ClusterSim::new(full).run();
        assert_eq!(rp.aggregations, rf.aggregations);
        assert!(
            rp.agg_reply_bytes * 10 < rf.agg_reply_bytes,
            "partial replies ({} B) must be far below full-ship ({} B)",
            rp.agg_reply_bytes,
            rf.agg_reply_bytes
        );
        assert!(
            rp.query_virt_ns < rf.query_virt_ns,
            "push-down ({} ns) must beat full-ship ({} ns)",
            rp.query_virt_ns,
            rf.query_virt_ns
        );
    }

    #[test]
    fn bisection_scales_sublinearly() {
        let b32 = bisection_bps(32, 1.0);
        let b256 = bisection_bps(256, 1.0);
        let ratio = b256 / b32;
        assert!(ratio > 3.9 && ratio < 4.1, "2^(2/3 of 3 doublings)=4, got {ratio}");
    }
}
