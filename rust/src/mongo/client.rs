//! Client API — the pymongo analogue the run-script workloads use.
//!
//! A [`MongoClient`] holds the router mailboxes published by the
//! run-script host file ("the run script makes available ... a list of
//! host names of the MongoDB cluster's router servers", paper §3.2) and
//! round-robins requests across them, exactly like the paper's client
//! PEs spreading `insertMany` calls over the routers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::mongo::bson::Document;
use crate::mongo::query::{Filter, FindOptions};
use crate::mongo::server::router::{InsertManyReply, RouterMailbox, RouterRequest};
use crate::mongo::storage::index::IndexSpec;
use crate::mongo::wire::{rpc, WireError};

/// Thread-safe, cloneable client handle.
#[derive(Clone)]
pub struct MongoClient {
    routers: Arc<Vec<RouterMailbox>>,
    next: Arc<AtomicUsize>,
}

impl MongoClient {
    pub fn new(routers: Vec<RouterMailbox>) -> Self {
        assert!(!routers.is_empty(), "client needs at least one router");
        Self { routers: Arc::new(routers), next: Arc::new(AtomicUsize::new(0)) }
    }

    fn pick(&self) -> &RouterMailbox {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        &self.routers[i % self.routers.len()]
    }

    /// A client pinned to one router (a PE talks to "its" router in the
    /// paper's layout: PE index mod router count).
    pub fn pinned(&self, pe: usize) -> MongoClient {
        let router = self.routers[pe % self.routers.len()].clone();
        MongoClient { routers: Arc::new(vec![router]), next: Arc::new(AtomicUsize::new(0)) }
    }

    pub fn num_routers(&self) -> usize {
        self.routers.len()
    }

    /// `insertMany(ordered=False)`.
    pub fn insert_many(&self, docs: Vec<Document>) -> Result<InsertManyReply, WireError> {
        rpc(self.pick(), |reply| RouterRequest::InsertMany { docs, reply })?
    }

    /// `find(filter)` returning a pull cursor.
    pub fn find(&self, filter: Filter, opts: FindOptions) -> Result<ClientCursor, WireError> {
        let router = self.pick().clone();
        let first = rpc(&router, |reply| RouterRequest::Find { filter, opts, reply })??;
        Ok(ClientCursor {
            router,
            buffered: first.docs.into(),
            cursor: first.cursor,
        })
    }

    /// `countDocuments`: scatter-count on the shards — no result set
    /// crosses the wire.
    pub fn count_documents(&self, filter: Filter) -> Result<usize, WireError> {
        let n = rpc(self.pick(), |reply| RouterRequest::Count { filter, reply })??;
        Ok(n as usize)
    }

    pub fn create_index(&self, spec: IndexSpec) -> Result<(), WireError> {
        rpc(self.pick(), |reply| RouterRequest::CreateIndex { spec, reply })?
    }
}

/// Iterates result documents, pulling `getMore` batches on demand.
pub struct ClientCursor {
    router: RouterMailbox,
    buffered: VecDeque<Document>,
    cursor: Option<u64>,
}

impl Iterator for ClientCursor {
    type Item = Document;

    fn next(&mut self) -> Option<Document> {
        loop {
            if let Some(doc) = self.buffered.pop_front() {
                return Some(doc);
            }
            let cursor = self.cursor.take()?;
            match rpc(&self.router, |reply| RouterRequest::GetMore { cursor, reply }) {
                Ok(Ok(rep)) => {
                    self.buffered = rep.docs.into();
                    self.cursor = rep.cursor;
                    if self.buffered.is_empty() && self.cursor.is_none() {
                        return None;
                    }
                }
                _ => return None,
            }
        }
    }
}
