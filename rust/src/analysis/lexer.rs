//! A lightweight Rust lexer — just enough structure for cross-file
//! protocol lints, with no dependency on `syn` or the compiler.
//!
//! The lexer turns a source file into a flat token stream (identifiers,
//! string literals, punctuation) with 1-indexed line numbers, plus the
//! side tables the rules need:
//!
//! * `comments` — every `//` and `/* */` comment with its line, so
//!   `// lint: allow(...)` annotations can be matched against flagged
//!   tokens;
//! * `comment_only` — per-line flag for "nothing but comment /
//!   whitespace", which lets an annotation sit in the comment block
//!   immediately above the code it excuses;
//! * `test_lines` — per-line flag for code inside a `#[cfg(test)]`
//!   item, so rules skip test modules without parsing items.
//!
//! It understands the token-level constructs that would otherwise
//! corrupt a naive scan: nested block comments, string escapes, raw
//! strings (`r#"..."#`), byte strings, and the char-literal vs
//! lifetime ambiguity (`'a'` vs `'a`).

/// Token classes the lint rules distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// String literal; `text` holds the *unquoted* contents.
    Str,
    /// Char literal (contents unparsed).
    Char,
    /// Numeric literal (loosely scanned).
    Num,
    /// Lifetime (`'a`), without the quote.
    Lifetime,
    /// Punctuation. Multi-char operators the rules care about (`::`,
    /// `=>`, `->`) are fused into one token; everything else is one
    /// char per token.
    Punct,
}

/// One lexed token.
#[derive(Clone, Debug)]
pub struct Tok {
    pub text: String,
    pub kind: TokKind,
    /// 1-indexed source line of the token's first character.
    pub line: usize,
}

/// One comment (either style), with leading `//` / `/*` stripped.
#[derive(Clone, Debug)]
pub struct Comment {
    pub text: String,
    /// 1-indexed line of the comment's first character.
    pub line: usize,
}

/// A lexed source file plus the per-line side tables.
pub struct SourceFile {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// Index 0 unused; `comment_only[l]` — line `l` holds only
    /// comments and/or whitespace.
    comment_only: Vec<bool>,
    /// Index 0 unused; `test_lines[l]` — line `l` is inside a
    /// `#[cfg(test)]` item.
    test_lines: Vec<bool>,
}

impl SourceFile {
    /// Lex `src` in full. Never fails: unterminated constructs are
    /// closed at end of file (the real compiler rejects them; the lint
    /// just needs to not misread the rest of the tree).
    pub fn lex(src: &str) -> Self {
        let lines = src.lines().count() + 2;
        let mut lx = Lexer {
            chars: src.chars().collect(),
            pos: 0,
            line: 1,
            tokens: Vec::new(),
            comments: Vec::new(),
            code_on_line: vec![false; lines],
            comment_on_line: vec![false; lines],
        };
        lx.run();
        let comment_only = (0..lines)
            .map(|l| lx.comment_on_line[l] && !lx.code_on_line[l])
            .collect();
        let mut f = SourceFile {
            tokens: lx.tokens,
            comments: lx.comments,
            comment_only,
            test_lines: vec![false; lines],
        };
        f.mask_cfg_test();
        f
    }

    /// Is `line` (1-indexed) comment-and-whitespace only?
    pub fn is_comment_only(&self, line: usize) -> bool {
        self.comment_only.get(line).copied().unwrap_or(false)
    }

    /// Is `line` (1-indexed) inside a `#[cfg(test)]` item?
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_lines.get(line).copied().unwrap_or(false)
    }

    /// All comment text attached to `line` (there can be several
    /// `/* */` on one line, though in practice zero or one).
    pub fn comments_on_line(&self, line: usize) -> impl Iterator<Item = &str> {
        self.comments.iter().filter(move |c| c.line == line).map(|c| c.text.as_str())
    }

    /// Does an annotation containing `needle` cover `line`? True if a
    /// comment on `line` itself matches, or if one matches in the
    /// contiguous block of comment-only lines immediately above.
    pub fn annotated(&self, line: usize, needle: &str) -> bool {
        if self.comments_on_line(line).any(|c| c.contains(needle)) {
            return true;
        }
        let mut l = line;
        while l > 1 && self.is_comment_only(l - 1) {
            l -= 1;
            if self.comments_on_line(l).any(|c| c.contains(needle)) {
                return true;
            }
        }
        false
    }

    /// Mark every line covered by a `#[cfg(test)]` item. The scan
    /// finds the attribute, skips any further attributes, then masks
    /// through the item's `{ ... }` body (or to the terminating `;`
    /// for bodiless items like `#[cfg(test)] use ...;`).
    fn mask_cfg_test(&mut self) {
        let t = &self.tokens;
        let mut i = 0;
        while i + 6 < t.len() {
            let is_cfg_test = t[i].text == "#"
                && t[i + 1].text == "["
                && t[i + 2].text == "cfg"
                && t[i + 3].text == "("
                && t[i + 4].text == "test"
                && t[i + 5].text == ")"
                && t[i + 6].text == "]";
            if !is_cfg_test {
                i += 1;
                continue;
            }
            let start_line = t[i].line;
            let mut j = i + 7;
            // Skip any further attributes on the item.
            while j + 1 < t.len() && t[j].text == "#" && t[j + 1].text == "[" {
                let mut depth = 0usize;
                j += 1;
                while j < t.len() {
                    match t[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
            // Mask to the end of the item: first `{...}` block, or the
            // `;` that ends a bodiless item.
            let mut end_line = start_line;
            let mut depth = 0usize;
            while j < t.len() {
                match t[j].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if depth == 0 {
                            end_line = t[j].line;
                            break;
                        }
                    }
                    ";" if depth == 0 => {
                        end_line = t[j].line;
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            for l in start_line..=end_line.min(self.test_lines.len() - 1) {
                self.test_lines[l] = true;
            }
            i = j.max(i + 1);
        }
    }
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    tokens: Vec<Tok>,
    comments: Vec<Comment>,
    code_on_line: Vec<bool>,
    comment_on_line: Vec<bool>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }

    fn mark_code(&mut self, line: usize) {
        if let Some(slot) = self.code_on_line.get_mut(line) {
            *slot = true;
        }
    }

    fn mark_comment(&mut self, from: usize, to: usize) {
        for l in from..=to {
            if let Some(slot) = self.comment_on_line.get_mut(l) {
                *slot = true;
            }
        }
    }

    fn push(&mut self, text: String, kind: TokKind, line: usize) {
        self.mark_code(line);
        self.tokens.push(Tok { text, kind, line });
    }

    fn run(&mut self) {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => self.string(line),
                'r' | 'b' if self.raw_or_byte_string(line) => {}
                '\'' => self.char_or_lifetime(line),
                c if c.is_alphabetic() || c == '_' => self.ident(line),
                c if c.is_ascii_digit() => self.number(line),
                _ => self.punct(line),
            }
        }
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        self.bump();
        self.bump();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.mark_comment(line, line);
        self.comments.push(Comment { text: text.trim().to_string(), line });
    }

    fn block_comment(&mut self) {
        let start = self.line;
        let mut text = String::new();
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.bump();
                self.bump();
                text.push_str("/*");
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
                text.push_str("*/");
            } else {
                text.push(c);
                self.bump();
            }
        }
        let end = self.line;
        self.mark_comment(start, end);
        self.comments.push(Comment { text: text.trim().to_string(), line: start });
    }

    fn string(&mut self, line: usize) {
        let mut text = String::new();
        self.bump(); // opening quote
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                text.push(c);
                self.bump();
                if let Some(e) = self.bump() {
                    text.push(e);
                }
            } else if c == '"' {
                self.bump();
                break;
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(text, TokKind::Str, line);
    }

    /// Raw (`r"..."`, `r#"..."#`) and byte (`b"..."`, `br#"..."#`)
    /// strings. Returns false (consuming nothing) when the `r`/`b` is
    /// just the start of an identifier.
    fn raw_or_byte_string(&mut self, line: usize) -> bool {
        let mut ahead = 1; // past the r/b
        if self.peek(0) == Some('b') && self.peek(1) == Some('r') {
            ahead = 2;
        }
        let mut hashes = 0usize;
        while self.peek(ahead) == Some('#') {
            ahead += 1;
            hashes += 1;
        }
        if self.peek(ahead) != Some('"') {
            return false;
        }
        // `b"..."` without `r` is a plain byte string: no raw quoting.
        let raw = self.peek(0) == Some('r') || self.peek(1) == Some('r');
        if !raw && hashes > 0 {
            return false;
        }
        for _ in 0..=ahead {
            self.bump(); // prefix + hashes + opening quote
        }
        let mut text = String::new();
        loop {
            let Some(c) = self.peek(0) else { break };
            if !raw && c == '\\' {
                text.push(c);
                self.bump();
                if let Some(e) = self.bump() {
                    text.push(e);
                }
                continue;
            }
            if c == '"' {
                let mut matched = true;
                for h in 0..hashes {
                    if self.peek(1 + h) != Some('#') {
                        matched = false;
                        break;
                    }
                }
                if matched {
                    for _ in 0..=hashes {
                        self.bump();
                    }
                    break;
                }
            }
            text.push(c);
            self.bump();
        }
        self.push(text, TokKind::Str, line);
        true
    }

    fn char_or_lifetime(&mut self, line: usize) {
        // `'a` (lifetime) vs `'a'` (char): a lifetime is `'` + ident
        // char + NOT a closing quote. `'\...'` is always a char.
        let c1 = self.peek(1);
        let is_lifetime = matches!(c1, Some(c) if c.is_alphabetic() || c == '_')
            && self.peek(2) != Some('\'');
        if is_lifetime {
            self.bump(); // quote
            let mut text = String::new();
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
            self.push(text, TokKind::Lifetime, line);
            return;
        }
        // Char literal.
        self.bump(); // opening quote
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                text.push(c);
                self.bump();
                if let Some(e) = self.bump() {
                    text.push(e);
                }
            } else if c == '\'' {
                self.bump();
                break;
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(text, TokKind::Char, line);
    }

    fn ident(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(text, TokKind::Ident, line);
    }

    fn number(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else if c == '.'
                && matches!(self.peek(1), Some(d) if d.is_ascii_digit())
            {
                // `1.5` continues the number; `1..5` does not.
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(text, TokKind::Num, line);
    }

    fn punct(&mut self, line: usize) {
        let c = self.peek(0).unwrap_or(' ');
        // Fuse the multi-char operators the rules match on.
        let fused = match (c, self.peek(1)) {
            (':', Some(':')) => Some("::"),
            ('=', Some('>')) => Some("=>"),
            ('-', Some('>')) => Some("->"),
            _ => None,
        };
        if let Some(op) = fused {
            self.bump();
            self.bump();
            self.push(op.to_string(), TokKind::Punct, line);
        } else {
            self.bump();
            self.push(c.to_string(), TokKind::Punct, line);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_comments_and_lifetimes() {
        let f = SourceFile::lex(
            "let s = \"a // not a comment\"; // real\nlet r = r#\"raw \"x\" body\"#;\nlet c: &'a str = 'b'.into();\n",
        );
        let strs: Vec<&str> = f
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, vec!["a // not a comment", "raw \"x\" body"]);
        assert_eq!(f.comments.len(), 1);
        assert_eq!(f.comments[0].text, "real");
        assert!(f.tokens.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "a"));
        assert!(f.tokens.iter().any(|t| t.kind == TokKind::Char && t.text == "b"));
    }

    #[test]
    fn cfg_test_masking_and_comment_blocks() {
        let src = "fn live() {}\n// above\n// block\nfn lint_target() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let f = SourceFile::lex(src);
        assert!(!f.is_test_line(1));
        assert!(f.is_test_line(6));
        assert!(f.is_test_line(7));
        assert!(f.is_comment_only(2) && f.is_comment_only(3));
        assert!(f.annotated(4, "block"));
        assert!(!f.annotated(1, "block"));
    }

    #[test]
    fn nested_block_comments_do_not_leak() {
        let f = SourceFile::lex("/* a /* nested */ still comment */ fn f() {}\n");
        assert!(f.tokens.iter().any(|t| t.text == "fn"));
        assert!(!f.tokens.iter().any(|t| t.text == "nested"));
    }
}
