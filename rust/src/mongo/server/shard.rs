//! Shard server (`mongod`): owns a storage engine on its assigned
//! filesystem directory, serves inserts for the chunks it owns,
//! triggers chunk splits, and participates in migrations.
//!
//! The event loop is the shard's **single writer**: inserts, index
//! builds, checkpoints, migration staging/publishes, and range deletes
//! all commit here, each under a fresh MVCC epoch. Reads
//! (find/getMore/count) are *dispatched* instead of served inline: the
//! query planner, streaming cursors, and the kernel fast path live in
//! [`super::read`], executing against snapshot-pinned [`ReadView`]s —
//! on this thread with `reader_threads == 0`, or on a [`ReaderPool`]
//! that overlaps query latency with ingest (docs/ARCHITECTURE.md §9).
//! After every group commit the writer reclaims versions no open
//! snapshot can see ([`ShardServer::maybe_compact`]).
//!
//! [`ReadView`]: crate::mongo::storage::ReadView

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::WriteConcern;
use crate::metrics::{names, Registry};
use crate::mongo::bson::{Document, RawDoc, Value};
use crate::mongo::query::Filter;
use crate::mongo::sharding::chunk::ChunkMap;
use crate::mongo::sharding::migration::STAGING_COLLECTION;
use crate::mongo::storage::{AtomicOp, Engine, EngineOptions, RecordId, StorageDir};
use crate::mongo::wire::{
    rpc, ConfigRequest, DeleteChunkReply, DeleteReply, InsertReply, MigrateBatchReply,
    ShardRequest, ShardStatsReply, StagedMigration, UpdateReply, WireError,
};
use crate::runtime::Kernels;
use crate::util::ids::ShardId;

use super::read::{ReadContext, ReadFence, ReadRequest, ReaderPool};
use super::replica::{docs_value, PendingReply, ReplicaConfig, ReplicaState, Role};

/// The sharded collection name (one sharded namespace, like the paper's
/// single OVIS metrics collection).
pub const COLLECTION: &str = "metrics";

/// Shard server state + event loop. Fields are `pub(super)` because
/// the replica-set role engine ([`super::replica`]) extends this type
/// from a sibling module.
pub struct ShardServer {
    pub(super) id: ShardId,
    pub(super) engine: Engine,
    pub(super) map: ChunkMap,
    pub(super) config: mpsc::Sender<ConfigRequest>,
    pub(super) metrics: Registry,
    /// Shared read state: snapshot source, planner, cursor registry.
    /// The event loop serves through it inline when no pool is running.
    pub(super) ctx: Arc<ReadContext>,
    /// Reader threads (`--reader-threads > 0`); `None` keeps reads on
    /// the event loop.
    pub(super) pool: Option<ReaderPool>,
    /// Split a chunk when its (position-histogram) doc count exceeds this.
    pub(super) split_threshold: u64,
    /// Position histogram: key position → docs at that position. Range
    /// sums give per-chunk counts; medians give split points.
    pub(super) positions: std::collections::BTreeMap<u64, u32>,
    /// Migration staging on this destination — `(range, donor,
    /// committed)`, mirroring the durable `__migration` collection
    /// (rebuilt from it after a restart).
    pub(super) staging: Option<((u64, u64), ShardId, bool)>,
    /// Staged data documents (meta records excluded).
    pub(super) staged_docs: u64,
    /// Record-id run a `PublishStaged` made live while this shard's own
    /// map still shows the handoff *unpublished*: until the SetMap that
    /// marks it published arrives, readers here must not serve these
    /// rids (the donor's copies are still what the cluster counts —
    /// both would double-count the range). In-memory only: recovery
    /// publishes before any traffic, so a restart never needs it.
    pub(super) publish_mask: Option<(RecordId, RecordId)>,
    /// Replica-set role engine state; `None` on an unreplicated shard
    /// (`--replicas 1`), which keeps every replication hook a no-op and
    /// the write path byte-identical to the single-member build.
    pub(super) replica: Option<ReplicaState>,
}

impl ShardServer {
    /// Open the shard's engine on `dir` (recovering any persisted
    /// state) and build the server. `engine_opts` carries the storage
    /// lifecycle: journaling, checkpoint compression, the
    /// auto-compaction threshold this server enforces after every group
    /// commit, and the snapshot retention window. `reader_threads > 0`
    /// starts a [`ReaderPool`] so finds/counts overlap with ingest.
    /// `replica` wires this server into its shard's replica set
    /// (`None` on an unreplicated shard).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: ShardId,
        dir: Box<dyn StorageDir>,
        map: ChunkMap,
        config: mpsc::Sender<ConfigRequest>,
        kernels: Kernels,
        metrics: Registry,
        engine_opts: EngineOptions,
        split_threshold: u64,
        default_batch: usize,
        reader_threads: usize,
        replica: Option<ReplicaConfig>,
    ) -> anyhow::Result<Self> {
        let mut engine = Engine::open_with(dir, engine_opts)?;
        engine.create_collection(COLLECTION);
        let ctx = Arc::new(ReadContext::new(
            engine.reader(),
            kernels,
            metrics.clone(),
            default_batch,
        ));
        let pool = (reader_threads > 0)
            .then(|| ReaderPool::start(Arc::clone(&ctx), reader_threads, &format!("{id}")));
        let mut s = Self {
            id,
            engine,
            map,
            config,
            metrics,
            ctx,
            pool,
            split_threshold,
            positions: Default::default(),
            staging: None,
            staged_docs: 0,
            publish_mask: None,
            replica: None,
        };
        // Rebuild the position histogram from recovered records (second
        // job re-attaching to persisted Lustre data) — raw key-field
        // probes under one latest-view guard, no per-record decode and
        // no byte cloning. Staged migration documents are not live and
        // never enter the histogram.
        {
            let reader = s.engine.reader();
            let view = reader.latest();
            let recovered: Vec<u64> = view
                .scan_raw_from(COLLECTION, None)
                .filter_map(|(_, raw)| s.position_of_raw(&RawDoc::new(raw)))
                .collect();
            drop(view);
            for pos in recovered {
                *s.positions.entry(pos).or_insert(0) += 1;
            }
        }
        // Rebuild migration staging state: a killed migration leaves its
        // staging collection behind, and the cluster's reconciliation
        // pass (`sharding::migration::recover`) needs its identity.
        if s.engine.stats(STAGING_COLLECTION).docs > 0 {
            let mut range = (0u64, 0u64);
            let mut from = id;
            let mut committed = false;
            let mut meta_seen = false;
            for (_, d) in s.engine.scan(STAGING_COLLECTION) {
                if d.get_i64("__migmeta").is_some() {
                    meta_seen = true;
                    // Positions are u64; stored as bit-cast i64 (exact
                    // round trip).
                    range = (
                        d.get_i64("lo").unwrap_or(0) as u64,
                        d.get_i64("hi").unwrap_or(0) as u64,
                    );
                    from = ShardId(d.get_i64("from").unwrap_or(0) as u32);
                } else if d.get_i64("__migcommit").is_some() {
                    committed = true;
                } else {
                    s.staged_docs += 1;
                }
            }
            // A meta-less staging is torn pre-commit garbage: surface it
            // uncommitted so reconciliation rolls it back.
            s.staging = Some((range, from, committed && meta_seen));
        }
        s.refresh_fence();
        // Join the replica set last: hard state + oplog recover from
        // the engine (a restarted member rejoins with its term intact),
        // and a fresh bootstrap member may immediately take the primary
        // role and fan out.
        if let Some(cfg) = replica {
            s.replica_init(cfg);
        }
        Ok(s)
    }

    /// Install a new chunk map and derive the readers' orphan fence
    /// from it. Every map change funnels through here so the fence can
    /// never lag the map on this shard.
    fn install_map(&mut self, map: ChunkMap) {
        self.map = map;
        // The publish mask exists to bridge [publish applied, published
        // map processed]; once the map marks the handoff published (or
        // drops it), the donor-side range filter takes over.
        if !matches!(self.map.handoff, Some(h) if !h.published) {
            self.publish_mask = None;
        }
        self.refresh_fence();
    }

    /// Recompute the shared [`ReadFence`] from the current map +
    /// publish mask.
    fn refresh_fence(&self) {
        let mut fence = ReadFence { version: self.map.version, ..ReadFence::default() };
        if let Some(h) = self.map.handoff {
            if h.published && h.from == self.id {
                // This shard donated the range and the destination's
                // copy is live: every remaining local copy is an orphan.
                fence.key = Some(self.map.key);
                fence.exclude_range = Some(h.range);
            }
        }
        fence.mask_rids = self.publish_mask;
        self.ctx.set_fence(fence);
    }

    /// Spawn the event loop thread; returns its mailbox and join handle.
    pub fn spawn(self) -> (mpsc::Sender<ShardRequest>, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let join = self.spawn_with(rx);
        (tx, join)
    }

    /// Spawn on a pre-created channel (the cluster wires mailboxes before
    /// any thread starts).
    pub fn spawn_with(mut self, rx: mpsc::Receiver<ShardRequest>) -> std::thread::JoinHandle<()> {
        let name = format!("{}", self.id);
        std::thread::Builder::new()
            .name(name)
            .spawn(move || self.run(rx))
            // lint: allow(panic, thread spawn fails only on OS resource
            // exhaustion at cluster startup, before any data is live)
            .expect("spawn shard thread")
    }

    /// Hand one read request to the pool, or serve it inline when no
    /// pool is running. Mailbox order is preserved up to the hand-off,
    /// so a find forwarded after an insert batch committed pins an
    /// epoch at or past that commit (read-your-writes).
    fn dispatch_read(&self, req: ReadRequest) {
        match &self.pool {
            Some(pool) => pool.submit(req),
            None => self.ctx.serve(req),
        }
    }

    fn run(&mut self, rx: mpsc::Receiver<ShardRequest>) {
        loop {
            if self.replica.is_some() {
                // Replicated members poll so replication timers
                // (heartbeat fan-out, election timeout) fire even on an
                // idle mailbox.
                match rx.recv_timeout(self.replica_poll()) {
                    Ok(req) => {
                        if self.handle(req) {
                            break;
                        }
                        self.replica_tick();
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => self.replica_tick(),
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            } else {
                // Unreplicated: plain blocking recv, exactly the
                // pre-replication event loop.
                match rx.recv() {
                    Ok(req) => {
                        if self.handle(req) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        }
        // Drain-and-join the reader pool before the event loop returns:
        // queued reads still answer (no client hangs on a dropped reply
        // sender), and no reader thread outlives the shard.
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }

    /// Serve one mailbox request; returns `true` on shutdown.
    fn handle(&mut self, req: ShardRequest) -> bool {
        match req {
            ShardRequest::Shutdown => return true,
            ShardRequest::SetMap { map } => {
                self.install_map(map);
            }
            ShardRequest::InsertBatch { version, docs, wc, reply } => {
                let t = Instant::now();
                let r = self.handle_insert_many(version, docs);
                self.metrics
                    .observe(names::SHARD_INSERT_BATCH_NS, t.elapsed().as_nanos() as u64);
                match r {
                    Ok((value, Some(slot))) if wc == WriteConcern::Majority => {
                        self.park_reply(slot, PendingReply::Insert { reply, value });
                    }
                    Ok((value, _)) => {
                        let _ = reply.send(Ok(value));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            ShardRequest::Find { filter, opts, reply } => {
                self.dispatch_read(ReadRequest::Find { filter, opts, reply });
            }
            ShardRequest::GetMore { cursor, reply } => {
                self.dispatch_read(ReadRequest::GetMore { cursor, reply });
            }
            ShardRequest::Count { filter, reply } => {
                self.dispatch_read(ReadRequest::Count { filter, reply });
            }
            ShardRequest::Aggregate { pipeline, partial, reply } => {
                self.dispatch_read(ReadRequest::Aggregate { pipeline, partial, reply });
            }
            ShardRequest::Update { version, filter, set, wc, reply } => {
                let t = Instant::now();
                let r = self.handle_update(version, &filter, &set);
                self.metrics
                    .observe(names::SHARD_UPDATE_NS, t.elapsed().as_nanos() as u64);
                match r {
                    Ok((value, Some(slot))) if wc == WriteConcern::Majority => {
                        self.park_reply(slot, PendingReply::Update { reply, value });
                    }
                    Ok((value, _)) => {
                        let _ = reply.send(Ok(value));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            ShardRequest::Delete { version, filter, wc, reply } => {
                let t = Instant::now();
                let r = self.handle_delete(version, &filter);
                self.metrics
                    .observe(names::SHARD_DELETE_NS, t.elapsed().as_nanos() as u64);
                match r {
                    Ok((value, Some(slot))) if wc == WriteConcern::Majority => {
                        self.park_reply(slot, PendingReply::Delete { reply, value });
                    }
                    Ok((value, _)) => {
                        let _ = reply.send(Ok(value));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            ShardRequest::CreateIndex { spec, reply } => {
                let r = self
                    .engine
                    .create_index(COLLECTION, spec)
                    .map_err(|e| WireError::Server(e.to_string()));
                let _ = reply.send(r);
            }
            ShardRequest::MigrateBatch { range, after, limit, reply } => {
                let t = Instant::now();
                let r = self.handle_migrate_batch(range, after, limit);
                self.metrics
                    .observe(names::SHARD_MIGRATE_BATCH_NS, t.elapsed().as_nanos() as u64);
                let _ = reply.send(r);
            }
            ShardRequest::StageChunk { range, from, docs, reply } => {
                let r = self.handle_stage_chunk(range, from, docs);
                let _ = reply.send(r);
            }
            ShardRequest::CommitStaged { reply } => {
                let _ = reply.send(self.handle_commit_staged());
            }
            ShardRequest::PublishStaged { reply } => {
                let _ = reply.send(self.handle_publish_staged());
            }
            ShardRequest::AbortStaged { reply } => {
                let _ = reply.send(self.handle_abort_staged());
            }
            ShardRequest::ClearStaged { reply } => {
                let _ = reply.send(self.handle_clear_staged());
            }
            ShardRequest::DeleteChunk { range, compact, reply } => {
                let r = self.delete_range(range, compact);
                let _ = reply.send(r);
            }
            ShardRequest::StagedState { reply } => {
                let _ = reply.send(self.staged_state());
            }
            ShardRequest::Stats { reply } => {
                let _ = reply.send(self.stats());
            }
            ShardRequest::Checkpoint { reply } => {
                let r = self
                    .engine
                    .checkpoint()
                    .map_err(|e| WireError::Server(e.to_string()));
                if r.is_ok() {
                    // Admin-command trigger — one of the three
                    // distinct `shard.checkpoints` sites (see the
                    // constant's docs in `metrics::names`).
                    self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
                }
                let _ = reply.send(r);
            }
            ShardRequest::Replicate {
                term,
                leader,
                prev_term,
                prev_index,
                entries,
                commit,
                reset,
            } => {
                self.handle_replicate(
                    term, leader, prev_term, prev_index, entries, commit, reset,
                );
            }
            ShardRequest::ReplicationAck { member, term, ack_index, success } => {
                self.handle_replication_ack(member, term, ack_index, success);
            }
            ShardRequest::RequestVote { term, candidate, last_term, last_index } => {
                self.handle_request_vote(term, candidate, last_term, last_index);
            }
            ShardRequest::VoteReply { term, from, granted } => {
                self.handle_vote_reply(term, from, granted);
            }
            ShardRequest::RoleInfo { reply } => {
                let _ = reply.send(self.role_reply());
            }
        }
        false
    }

    /// Background maintenance hook, run after every group commit:
    ///
    /// * **Reclamation** — expire snapshots past the retention window
    ///   and physically drop every dead version no open snapshot can
    ///   see, publishing the `shard.snapshots_open` /
    ///   `shard.reclaim_lag` gauges.
    /// * **Compaction** — once the engine has journaled past its
    ///   configured threshold, write a checkpoint and rotate/truncate
    ///   the journal so the shard's on-disk footprint on the shared
    ///   filesystem stays bounded.
    ///
    /// A compaction failure must not fail the triggering write — the
    /// batch is already durable in the journal — so errors are counted
    /// and logged, and the next group commit retries (the byte counter
    /// keeps growing until a checkpoint succeeds).
    fn maybe_compact(&mut self) {
        self.engine.reclaim();
        self.metrics
            .gauge(names::SHARD_SNAPSHOTS_OPEN)
            .set(self.engine.snapshots_open() as i64);
        self.metrics.gauge(names::SHARD_RECLAIM_LAG).set(
            self.engine.epoch().saturating_sub(self.engine.snapshot_floor()) as i64,
        );
        match self.engine.maybe_checkpoint() {
            Ok(Some(ck)) => {
                // Threshold trigger — one of the three distinct
                // `shard.checkpoints` sites (see `metrics::names`).
                self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
                if ck.full {
                    // Generation 1 or a chain rebase: the one compaction
                    // whose cost scales with the live set.
                    self.metrics.counter(names::SHARD_REBASES).inc();
                }
                self.metrics.counter(names::SHARD_DELTA_BYTES).add(ck.delta_bytes);
                self.metrics
                    .counter(names::SHARD_SEGMENTS_TRUNCATED)
                    .add(ck.segments_truncated);
                self.metrics
                    .counter(names::SHARD_JOURNAL_BYTES_TRUNCATED)
                    .add(ck.journal_bytes_truncated);
            }
            Ok(None) => {}
            Err(e) => {
                self.metrics.counter(names::SHARD_CHECKPOINT_ERRORS).inc();
                eprintln!("warn: {}: background checkpoint failed: {e:#}", self.id);
            }
        }
    }

    /// Shard-key position of a document (`None` if key fields missing).
    /// Out-of-domain (negative) values clamp through
    /// [`crate::mongo::sharding::chunk::ShardKey::position_i64`] — the
    /// shared convention, so placement, migration, the read fences, and
    /// the router's orphan filter all classify a document identically.
    pub(super) fn position_of(&self, doc: &Document) -> Option<u64> {
        Some(self.map.key.position_i64(doc.get_i64("node_id")?, doc.get_i64("ts")?))
    }

    /// [`Self::position_of`] read straight from encoded record bytes —
    /// the scans that only need positions (histogram rebuild, range
    /// deletes, migration batching) never decode whole documents.
    fn position_of_raw(&self, doc: &RawDoc) -> Option<u64> {
        Some(self.map.key.position_i64(doc.get_i64("node_id")?, doc.get_i64("ts")?))
    }

    /// Bulk-ingest leg on the shard: version handshake, owner filtering,
    /// then the owned run is indexed and journaled as a whole batch with
    /// a single group commit.
    ///
    /// On a replica-set primary the owned run and its oplog entry
    /// journal as **one** atomic frame ([`Self::primary_append`]); the
    /// returned `(term, index)` slot lets the caller park the reply
    /// until the entry commits (`w:majority`). An unreplicated shard
    /// (or an empty owned run) returns `None` — the reply releases
    /// immediately.
    fn handle_insert_many(
        &mut self,
        version: u64,
        docs: Vec<Document>,
    ) -> Result<(InsertReply, Option<(u64, u64)>), WireError> {
        if self.rejects_writes() {
            return Err(self.not_primary());
        }
        self.check_version(version)?;

        // Split the batch into owned documents and wrong-owner rejects,
        // then index + journal the owned run as ONE multi-record frame.
        let mut wrong_owner = Vec::new();
        let mut touched_chunks: Vec<usize> = Vec::new();
        let mut owned_docs: Vec<Document> = Vec::with_capacity(docs.len());
        let mut owned_pos: Vec<u64> = Vec::with_capacity(docs.len());
        for (i, doc) in docs.into_iter().enumerate() {
            let Some(pos) = self.position_of(&doc) else {
                wrong_owner.push(i);
                continue;
            };
            let chunk = self.map.chunk_of(pos);
            if self.map.owners[chunk] != self.id {
                wrong_owner.push(i);
                continue;
            }
            if !touched_chunks.contains(&chunk) {
                touched_chunks.push(chunk);
            }
            owned_docs.push(doc);
            owned_pos.push(pos);
        }
        let inserted = owned_docs.len();
        let slot = if self.replica.is_some() {
            if owned_docs.is_empty() {
                None // nothing applied ⇒ no oplog entry to replicate
            } else {
                let entry_docs = docs_value(&owned_docs);
                let data = AtomicOp::Insert { coll: COLLECTION.to_string(), docs: owned_docs };
                Some(self.primary_append(Some(data), "i", vec![("docs", entry_docs)])?)
            }
        } else {
            self.engine
                .insert_many(COLLECTION, &owned_docs)
                .map_err(|e| WireError::Server(e.to_string()))?;
            // Group commit once per batch: one journal frame, one sync.
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
            self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
            None
        };
        for pos in owned_pos {
            *self.positions.entry(pos).or_insert(0) += 1;
        }
        self.metrics.counter(names::SHARD_DOCS_INSERTED).add(inserted as u64);
        self.maybe_compact();

        // Split any chunk that crossed the threshold.
        for chunk in touched_chunks {
            self.maybe_split(chunk);
        }
        Ok((InsertReply { inserted, wrong_owner }, slot))
    }

    /// Version handshake shared by every routed write: if the router is
    /// ahead, catch up from the config server; if the router is behind,
    /// tell it to refresh.
    fn check_version(&mut self, version: u64) -> Result<(), WireError> {
        if version > self.map.version {
            if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                self.install_map(map);
            }
        }
        if version != self.map.version {
            self.metrics.counter(names::SHARD_STALE_VERSION).inc();
            return Err(WireError::StaleVersion { current: self.map.version });
        }
        Ok(())
    }

    /// Filter-driven `$set` update of this shard's matching documents.
    /// Matching is raw (no decode for non-matches); matched documents
    /// decode once, merge the `$set` fields, and the changed subset
    /// commits as **one** `update_many` journal frame + group commit —
    /// MVCC batch-atomic, so a snapshot pinned before the batch reads
    /// only pre-update versions.
    ///
    /// Shard-key fields are immutable (a key change would relocate the
    /// document across chunks — that is a delete + insert, not an
    /// update), which also keeps the position histogram exact.
    fn handle_update(
        &mut self,
        version: u64,
        filter: &Filter,
        set: &Document,
    ) -> Result<(UpdateReply, Option<(u64, u64)>), WireError> {
        if self.rejects_writes() {
            return Err(self.not_primary());
        }
        self.check_version(version)?;
        if set.get("node_id").is_some() || set.get("ts").is_some() {
            return Err(WireError::Server(
                "shard-key fields (node_id, ts) are immutable under update".into(),
            ));
        }
        if set.is_empty() {
            return Err(WireError::Server("empty $set document".into()));
        }
        let matched = self.match_for_write(filter)?;
        let matched_n = matched.len() as u64;
        let mut updates: Vec<(RecordId, Document)> = Vec::with_capacity(matched.len());
        // Oplog form: `(old, new)` pairs — secondaries hold different
        // record ids, so they re-resolve each old document by content.
        let mut pairs: Vec<Document> = Vec::with_capacity(matched.len());
        for (rid, doc, _) in matched {
            let mut merged = doc.clone();
            for (k, v) in &set.fields {
                merged.put(k, v.clone());
            }
            if merged != doc {
                pairs.push(
                    Document::new()
                        .set("old", Value::Doc(doc))
                        .set("new", Value::Doc(merged.clone())),
                );
                updates.push((rid, merged));
            }
        }
        let modified = updates.len() as u64;
        let mut slot = None;
        if !updates.is_empty() {
            if self.replica.is_some() {
                let entry_pairs = docs_value(&pairs);
                let data = AtomicOp::Update { coll: COLLECTION.to_string(), updates };
                slot = Some(self.primary_append(Some(data), "u", vec![("pairs", entry_pairs)])?);
            } else {
                self.engine
                    .update_many(COLLECTION, &updates)
                    .map_err(|e| WireError::Server(e.to_string()))?;
                // Group commit once per batch: one journal frame, one sync.
                self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
                self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
            }
            self.metrics.counter(names::SHARD_DOCS_UPDATED).add(modified);
        }
        self.maybe_compact();
        Ok((UpdateReply { matched: matched_n, modified }, slot))
    }

    /// Filter-driven delete: matched documents leave as **one**
    /// `delete_many` journal frame + group commit, and the position
    /// histogram decrements so chunk counts stay exact.
    fn handle_delete(
        &mut self,
        version: u64,
        filter: &Filter,
    ) -> Result<(DeleteReply, Option<(u64, u64)>), WireError> {
        if self.rejects_writes() {
            return Err(self.not_primary());
        }
        self.check_version(version)?;
        let matched = self.match_for_write(filter)?;
        let deleted = matched.len() as u64;
        let mut slot = None;
        if !matched.is_empty() {
            let rids: Vec<RecordId> = matched.iter().map(|(r, _, _)| *r).collect();
            if self.replica.is_some() {
                // Oplog form: the deleted documents by content —
                // secondaries resolve their own record ids from them.
                let olds: Vec<Document> = matched.iter().map(|(_, d, _)| d.clone()).collect();
                let entry_olds = docs_value(&olds);
                let data = AtomicOp::Remove { coll: COLLECTION.to_string(), rids };
                slot = Some(self.primary_append(Some(data), "d", vec![("olds", entry_olds)])?);
            } else {
                self.engine
                    .delete_many(COLLECTION, &rids)
                    .map_err(|e| WireError::Server(e.to_string()))?;
                self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
                self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
            }
            for (_, _, pos) in &matched {
                if let Some(pos) = pos {
                    if let Some(c) = self.positions.get_mut(pos) {
                        *c -= 1;
                        if *c == 0 {
                            self.positions.remove(pos);
                        }
                    }
                }
            }
            self.metrics.counter(names::SHARD_DOCS_DELETED).add(deleted);
        }
        self.maybe_compact();
        Ok((DeleteReply { deleted }, slot))
    }

    /// Collect the live documents a mutating filter matches — rid,
    /// decoded document, shard-key position — under one scoped
    /// latest-view guard (dropped before the caller takes the write
    /// lock).
    ///
    /// **Migration fence:** a match inside an active handoff range is
    /// refused with the retryable [`WireError::MigrationInFlight`]. The
    /// migration's rid-cursor copy stream cannot see an update (the new
    /// version gets a rid *behind* nothing — it escapes the cursor, so
    /// the destination would publish the stale copy) nor a delete (the
    /// already-streamed copy would resurrect on the destination), so
    /// range writes wait out the handoff; the router retries with
    /// backoff. Inserts stay allowed — new rids land *ahead* of the
    /// cursor and are picked up by later batches or catch-up.
    ///
    /// The check is deliberately role-agnostic: the *destination* of a
    /// published handoff rejects in-range matches too, until the
    /// handoff clears from its map. That double-sided refusal is what
    /// lets the router re-broadcast a write after a mid-retry map
    /// change without ever applying it to both copies of the range —
    /// and guarantees exactly one side eventually accepts it.
    #[allow(clippy::type_complexity)]
    fn match_for_write(
        &self,
        filter: &Filter,
    ) -> Result<Vec<(RecordId, Document, Option<u64>)>, WireError> {
        let handoff = self.map.handoff;
        let mut matched: Vec<(RecordId, Document, Option<u64>)> = Vec::new();
        let reader = self.engine.reader();
        let view = reader.latest();
        for (rid, raw) in view.scan_raw_from(COLLECTION, None) {
            let rd = RawDoc::new(raw);
            if !filter.matches_raw(&rd) {
                continue;
            }
            let pos = self.position_of_raw(&rd);
            if let (Some(h), Some(p)) = (&handoff, pos) {
                if h.covers(p) {
                    self.metrics.counter(names::SHARD_WRITE_CONFLICTS).inc();
                    return Err(WireError::MigrationInFlight { range: h.range });
                }
            }
            let doc = rd
                .decode()
                .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?;
            matched.push((rid, doc, pos));
        }
        Ok(matched)
    }

    fn chunk_doc_count(&self, chunk: usize) -> u64 {
        let (lo, hi) = self.map.chunk_range(chunk);
        self.positions.range(lo..=hi).map(|(_, c)| *c as u64).sum()
    }

    /// Median position within a chunk (split point).
    fn chunk_median(&self, chunk: usize) -> Option<u64> {
        let (lo, hi) = self.map.chunk_range(chunk);
        let total: u64 = self.chunk_doc_count(chunk);
        if total < 2 {
            return None;
        }
        let mut seen = 0u64;
        for (&pos, &c) in self.positions.range(lo..=hi) {
            seen += c as u64;
            if seen >= total / 2 {
                // Split point must be < hi and >= lo.
                if pos >= hi {
                    return None;
                }
                return Some(pos);
            }
        }
        None
    }

    fn maybe_split(&mut self, chunk: usize) {
        // Only a primary reports splits: a secondary's histogram moves
        // while tailing the oplog, but the set speaks to the config
        // server with one voice (the map change would race otherwise).
        if matches!(&self.replica, Some(r) if r.role != Role::Primary) {
            return;
        }
        if self.chunk_doc_count(chunk) <= self.split_threshold {
            return;
        }
        let Some(at) = self.chunk_median(chunk) else { return };
        let seen = self.map.version;
        if let Ok(Ok(check)) = rpc(&self.config, |reply| ConfigRequest::ReportSplit {
            seen_version: seen,
            chunk,
            at,
            reply,
        }) {
            use crate::mongo::sharding::config_server::VersionCheck;
            match check {
                VersionCheck::Ok => {
                    self.metrics.counter(names::SHARD_SPLITS).inc();
                    // Config pushes SetMap to everyone (including us); we
                    // may process it on the next loop turn. Update our
                    // local copy eagerly to keep counting accurate.
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.install_map(map);
                    }
                }
                VersionCheck::Stale { .. } => {
                    self.metrics.counter(names::SHARD_SPLIT_STALE).inc();
                    if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
                        self.install_map(map);
                    }
                }
            }
        }
    }

    /// Migration source: one bounded batch of the range, resuming from
    /// the record-id cursor `after`. The scan itself is capped (not
    /// only the match count), so even a sparse range never holds the
    /// event loop for more than a bounded walk — invariant IM2. The
    /// walk borrows raw bytes under one latest-view guard: only records
    /// actually inside the migrating range decode; the (typically much
    /// larger) out-of-range remainder is probed for its key fields and
    /// skipped without cloning.
    fn handle_migrate_batch(
        &self,
        range: (u64, u64),
        after: Option<u64>,
        limit: usize,
    ) -> Result<MigrateBatchReply, WireError> {
        let limit = limit.max(1);
        let scan_cap = limit.saturating_mul(8).max(4096);
        let mut docs = Vec::new();
        let mut last = None;
        let mut scanned = 0usize;
        let mut done = true;
        let reader = self.engine.reader();
        let view = reader.latest();
        for (rid, raw) in view.scan_raw_from(COLLECTION, after) {
            scanned += 1;
            last = Some(rid);
            let rd = RawDoc::new(raw);
            if let Some(pos) = self.position_of_raw(&rd) {
                if range.0 <= pos && pos <= range.1 {
                    docs.push(
                        rd.decode()
                            .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?,
                    );
                }
            }
            if docs.len() >= limit || scanned >= scan_cap {
                done = false;
                break;
            }
        }
        Ok(MigrateBatchReply { docs, last, done })
    }

    /// Migration destination: stage one copied batch in the
    /// `__migration` collection — durable via the same group-committed
    /// `insert_many` path as ingest, but invisible to queries until
    /// published. The first batch pins the migration identity (range +
    /// donor) in a meta record, journaled ahead of any data.
    fn handle_stage_chunk(
        &mut self,
        range: (u64, u64),
        from: ShardId,
        docs: Vec<Document>,
    ) -> Result<usize, WireError> {
        self.engine.create_collection(STAGING_COLLECTION);
        match self.staging {
            Some((_, _, true)) => {
                return Err(WireError::Server(
                    "a committed migration awaits publish".into(),
                ));
            }
            Some((r, f, false)) if r != range || f != from => {
                return Err(WireError::Server("another migration is staged".into()));
            }
            Some(_) => {}
            None => {
                let meta = Document::new()
                    .set("__migmeta", 1i64)
                    .set("lo", range.0 as i64)
                    .set("hi", range.1 as i64)
                    .set("from", from.0 as i64);
                self.engine
                    .insert_many(STAGING_COLLECTION, &[meta])
                    .map_err(|e| WireError::Server(e.to_string()))?;
                self.staging = Some((range, from, false));
            }
        }
        let n = docs.len();
        self.engine
            .insert_many(STAGING_COLLECTION, &docs)
            .map_err(|e| WireError::Server(e.to_string()))?;
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        self.staged_docs += n as u64;
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_IN).add(n as u64);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: durably write the commit marker — one
    /// journal frame plus a sync. From the moment this replies, the
    /// migration can only roll forward (M3). Idempotent.
    fn handle_commit_staged(&mut self) -> Result<u64, WireError> {
        let Some((range, from, committed)) = self.staging else {
            return Err(WireError::Server("nothing staged".into()));
        };
        if !committed {
            let marker = Document::new().set("__migcommit", 1i64);
            self.engine
                .insert_many(STAGING_COLLECTION, &[marker])
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
            self.staging = Some((range, from, true));
        }
        Ok(self.staged_docs)
    }

    /// Migration destination: publish the staged documents into the
    /// live collection as **one atomic move frame** (replay never sees
    /// them in both collections or in neither). The staging *meta*
    /// records survive — they are the durable marker that keeps a crash
    /// after this publish on the committed (roll-forward) recovery
    /// path; [`Self::handle_clear_staged`] drops them once the donor's
    /// copy is deleted. Idempotent: a drained or empty staging
    /// publishes 0 documents.
    ///
    /// Until this shard processes the map version that marks the
    /// handoff published, the freshly moved rid run is masked from
    /// local reads (`publish_mask` → [`ReadFence`]): the donor's copies
    /// are still what the cluster counts during that bridge.
    ///
    /// A cursor pinned *before* this publish still drains the
    /// pre-publish state (staged docs invisible); one pinned after sees
    /// the moved documents exactly once — the regression battery in
    /// `tests/cluster_live.rs` holds migrations to that.
    fn handle_publish_staged(&mut self) -> Result<u64, WireError> {
        if self.staging.is_none() && self.engine.stats(STAGING_COLLECTION).docs == 0 {
            return Ok(0);
        }
        // Raw pass: the publish needs rids and key positions only —
        // staged documents move as encoded bytes, never decoding here.
        // The view is scoped: it must drop before `move_many` takes the
        // store's write lock on this same thread.
        let mut data: Vec<(RecordId, Option<u64>)> = Vec::new();
        {
            let reader = self.engine.reader();
            let view = reader.latest();
            for (rid, raw) in view.scan_raw_from(STAGING_COLLECTION, None) {
                let rd = RawDoc::new(raw);
                if rd.get_i64("__migmeta").is_none() && rd.get_i64("__migcommit").is_none() {
                    data.push((rid, self.position_of_raw(&rd)));
                }
            }
        }
        let rids: Vec<RecordId> = data.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        // Mask the about-to-be-published run from local reads while our
        // own map still shows the handoff unpublished (the bridge
        // between the publish applying here and the published map
        // arriving). The mask must be installed **before** `move_many`
        // commits: a reader pairs its fence copy with its snapshot via
        // a seqlock re-check (`ReadContext::pin_with_fence`), and that
        // check is only airtight if no snapshot can contain the
        // published run while the fence predates the mask. The run's
        // exact rids don't exist yet, so the pre-mask is open-ended
        // from the collection's next rid; this event loop is the only
        // writer, so nothing else can allocate into that run before the
        // mask is tightened to the moved rids right after the move.
        let premask = matches!(self.map.handoff, Some(h) if !h.published);
        if premask {
            self.publish_mask =
                Some((self.engine.next_record_id(COLLECTION), RecordId::MAX));
            self.refresh_fence();
        }
        let fresh = match self.engine.move_many(STAGING_COLLECTION, COLLECTION, &rids) {
            Ok(fresh) => fresh,
            Err(e) => {
                // Nothing moved: the open-ended pre-mask must not
                // outlive the attempt (it would swallow future inserts).
                if premask {
                    self.publish_mask = None;
                    self.refresh_fence();
                }
                return Err(WireError::Server(e.to_string()));
            }
        };
        if premask {
            // Tighten to the rids actually moved (the move is committed
            // and visible, so the mask stays even if the sync below
            // fails); an empty move needs no mask at all.
            self.publish_mask = match (fresh.iter().min(), fresh.iter().max()) {
                (Some(&lo), Some(&hi)) => Some((lo, hi)),
                _ => None,
            };
            self.refresh_fence();
        }
        self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        for (_, pos) in &data {
            if let Some(pos) = pos {
                *self.positions.entry(*pos).or_insert(0) += 1;
            }
        }
        // Keep the staging identity: committed, fully drained. A repeat
        // publish is a 0-document no-op; ClearStaged retires it.
        if let Some((range, from, _)) = self.staging {
            self.staging = Some((range, from, true));
        }
        self.staged_docs = 0;
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_PUBLISHED).add(n);
        self.maybe_compact();
        Ok(n)
    }

    /// Migration destination: drop an *uncommitted* staged range — the
    /// awaited abort path that used to orphan these documents. Refuses
    /// to drop a committed staging (that one must roll forward).
    fn handle_abort_staged(&mut self) -> Result<u64, WireError> {
        if let Some((_, _, true)) = self.staging {
            return Err(WireError::Server(
                "staged migration is committed; cannot abort".into(),
            ));
        }
        let rids = self.engine.record_ids(STAGING_COLLECTION);
        let dropped = self.staged_docs;
        if !rids.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.staging = None;
        self.staged_docs = 0;
        self.metrics.counter(names::SHARD_MIGRATION_ABORTS).inc();
        self.maybe_compact();
        Ok(dropped)
    }

    /// Migration destination: retire the drained staging meta left by
    /// [`Self::handle_publish_staged`] — the migration's final durable
    /// step, after the donor's range delete confirmed. Idempotent: with
    /// nothing staged this is a no-op.
    fn handle_clear_staged(&mut self) -> Result<(), WireError> {
        if self.staged_docs > 0 {
            return Err(WireError::Server(
                "staging still holds data documents; publish or abort first".into(),
            ));
        }
        let rids = self.engine.record_ids(STAGING_COLLECTION);
        if !rids.is_empty() {
            self.engine
                .remove_many(STAGING_COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.staging = None;
        self.staged_docs = 0;
        self.maybe_compact();
        Ok(())
    }

    fn staged_state(&self) -> Option<StagedMigration> {
        self.staging.map(|(range, from, committed)| StagedMigration {
            range,
            from,
            committed,
            docs: self.staged_docs,
        })
    }

    /// Migration source: delete the committed-away range as **one**
    /// atomic `remove_many` frame (a kill can never half-delete the
    /// chunk), then — when `compact` — checkpoint immediately so the
    /// moved-away documents leave this shard's journal and delta chain
    /// instead of occupying the shared filesystem until the next
    /// threshold crossing. Snapshots pinned before the delete keep
    /// reading the doomed versions until reclamation catches up.
    fn delete_range(
        &mut self,
        range: (u64, u64),
        compact: bool,
    ) -> Result<DeleteChunkReply, WireError> {
        // Scoped view: the doomed-set scan borrows raw bytes, and the
        // guard must drop before `remove_many` takes the write lock.
        let doomed: Vec<(RecordId, u64)> = {
            let reader = self.engine.reader();
            let view = reader.latest();
            view.scan_raw_from(COLLECTION, None)
                .filter_map(|(rid, raw)| {
                    let pos = self.position_of_raw(&RawDoc::new(raw))?;
                    (range.0 <= pos && pos <= range.1).then_some((rid, pos))
                })
                .collect()
        };
        let rids: Vec<RecordId> = doomed.iter().map(|(r, _)| *r).collect();
        let n = rids.len() as u64;
        if !rids.is_empty() {
            self.engine
                .remove_many(COLLECTION, &rids)
                .map_err(|e| WireError::Server(e.to_string()))?;
            for (_, pos) in doomed {
                if let Some(c) = self.positions.get_mut(&pos) {
                    *c -= 1;
                    if *c == 0 {
                        self.positions.remove(&pos);
                    }
                }
            }
            self.engine.sync().map_err(|e| WireError::Server(e.to_string()))?;
        }
        self.metrics.counter(names::SHARD_MIGRATION_DOCS_OUT).add(n);
        let compacted = if compact && n > 0 {
            let ck = self
                .engine
                .checkpoint()
                .map_err(|e| WireError::Server(e.to_string()))?;
            // Post-migration source compaction — one of the three
            // distinct `shard.checkpoints` sites (see `metrics::names`).
            self.metrics.counter(names::SHARD_CHECKPOINTS).inc();
            self.metrics
                .counter(names::SHARD_JOURNAL_BYTES_TRUNCATED)
                .add(ck.journal_bytes_truncated);
            Some(ck)
        } else {
            self.maybe_compact();
            None
        };
        Ok(DeleteChunkReply { removed: n, compacted })
    }

    fn stats(&self) -> ShardStatsReply {
        let chunks_owned = self
            .map
            .owners
            .iter()
            .filter(|o| **o == self.id)
            .count() as u32;
        ShardStatsReply {
            collection: self.engine.stats(COLLECTION),
            chunks_owned,
            map_version: self.map.version,
            journal_bytes: self.engine.pending_journal_bytes() as u64,
            journal_disk_bytes: self.engine.journal_disk_bytes(),
            checkpoint_generation: self.engine.generation(),
            checkpoint_chain_len: self.engine.chain_len(),
            delta_disk_bytes: self.engine.chain_disk_bytes(),
            staged_docs: self.staged_docs,
        }
    }
}
