//! Shard keys and the chunk map.
//!
//! A chunk owns a half-open interval of the *key position space*:
//! hashed keys live on the u32 FNV ring (positions computed by the AOT
//! route kernel), ranged keys on the u64 `(node_id << 32) | ts` line
//! (ablation A5's hot-chunk pathology). The map stores inclusive upper
//! bounds per chunk plus the owning shard, and carries a version bumped
//! on every mutation — routers cache the map and retry on
//! `StaleVersion`, exactly like mongos.

use anyhow::{bail, Result};

use crate::config::ShardKeyKind;
use crate::util::hash::fnv1a_shard_key;
use crate::util::ids::ShardId;

/// Shard-key definition: the paper's collection is keyed on
/// `(node_id, ts)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardKey {
    pub kind: ShardKeyKind,
}

impl ShardKey {
    pub fn hashed() -> Self {
        Self { kind: ShardKeyKind::Hashed }
    }

    pub fn ranged() -> Self {
        Self { kind: ShardKeyKind::Ranged }
    }

    /// Position of a key on the partition line.
    #[inline]
    pub fn position(&self, node_id: u32, ts_min: u32) -> u64 {
        match self.kind {
            ShardKeyKind::Hashed => fnv1a_shard_key(node_id, ts_min) as u64,
            ShardKeyKind::Ranged => ((node_id as u64) << 32) | ts_min as u64,
        }
    }

    /// Position from raw `i64` key fields, clamping negatives to 0 —
    /// the **single** out-of-domain convention shared by ingest
    /// placement, migration batching, the shard-side read fence, the
    /// router's orphan filter, and the kernel column extraction. Any
    /// two layers that classified an out-of-domain document differently
    /// (wrapping cast here, clamp there) would disagree on whether it
    /// is an orphan, and a migration could lose or double-serve it.
    #[inline]
    pub fn position_i64(&self, node_id: i64, ts_min: i64) -> u64 {
        self.position(
            node_id.clamp(0, u32::MAX as i64) as u32,
            ts_min.clamp(0, u32::MAX as i64) as u32,
        )
    }

    /// Top of the position space.
    pub fn max_position(&self) -> u64 {
        match self.kind {
            ShardKeyKind::Hashed => u32::MAX as u64,
            ShardKeyKind::Ranged => u64::MAX,
        }
    }
}

/// The in-flight migration handoff descriptor, carried *inside* the
/// chunk map so it propagates atomically with the version through
/// `GetMap`/`SetMap` pushes.
///
/// While a handoff is active, the map's `owners` row alone cannot tell
/// a reader which shard's copy of the range is authoritative: between
/// the flip and the destination's publish the donor still holds the
/// only live copy, and between the publish and the donor's range delete
/// both shards hold one. The `published` flag splits those phases:
///
/// * `published == false` — the destination has not made its staged
///   copy live; the donor's copy is the one readers must see.
/// * `published == true` — the destination's copy is live; the donor's
///   leftover copy (until its range delete lands) is an **orphan** and
///   every read on the donor must filter it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MigrationHandoff {
    /// Inclusive position range `[lo, hi]` being handed off.
    pub range: (u64, u64),
    /// Donor shard (the pre-flip owner).
    pub from: ShardId,
    /// Set by the config server after the destination published the
    /// staged copy (and before the donor's range delete is issued).
    pub published: bool,
}

impl MigrationHandoff {
    /// Whether `position` falls inside the handed-off range.
    #[inline]
    pub fn covers(&self, position: u64) -> bool {
        self.range.0 <= position && position <= self.range.1
    }
}

/// The versioned chunk table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkMap {
    pub key: ShardKey,
    pub version: u64,
    /// Inclusive upper bound per chunk, strictly increasing, last =
    /// `key.max_position()`.
    pub bounds: Vec<u64>,
    /// Owning shard per chunk.
    pub owners: Vec<ShardId>,
    /// In-flight migration handoff, if any (at most one at a time —
    /// the config server serializes migrations).
    pub handoff: Option<MigrationHandoff>,
}

impl ChunkMap {
    /// Pre-split: `chunks_per_shard * num_shards` equal chunks assigned
    /// round-robin (MongoDB's hashed pre-split).
    pub fn pre_split(key: ShardKey, num_shards: u32, chunks_per_shard: u32) -> Self {
        let n = (num_shards * chunks_per_shard).max(1) as u64;
        let top = key.max_position();
        let mut bounds = Vec::with_capacity(n as usize);
        let mut owners = Vec::with_capacity(n as usize);
        for i in 0..n {
            // Equal partition of [0, top]; final bound exactly top.
            let b = if i == n - 1 { top } else { (top / n) * (i + 1) };
            bounds.push(b);
            owners.push(ShardId((i % num_shards as u64) as u32));
        }
        Self { key, version: 1, bounds, owners, handoff: None }
    }

    /// The shard whose copy of `position` readers must treat as
    /// authoritative under this map — the `owners` row, except while an
    /// unpublished handoff covers the position (the destination owns it
    /// on paper but has not made its copy live yet, so the donor's copy
    /// is still the one to read).
    #[inline]
    pub fn effective_read_owner(&self, position: u64) -> ShardId {
        match &self.handoff {
            Some(h) if !h.published && h.covers(position) => h.from,
            _ => self.owner_of(position),
        }
    }

    pub fn num_chunks(&self) -> usize {
        self.bounds.len()
    }

    /// Chunk index owning `position`.
    #[inline]
    pub fn chunk_of(&self, position: u64) -> usize {
        self.bounds.partition_point(|&b| b < position)
    }

    /// Shard owning `position`.
    #[inline]
    pub fn owner_of(&self, position: u64) -> ShardId {
        self.owners[self.chunk_of(position)]
    }

    /// Half-open position interval `[lo, hi_inclusive]` of chunk `idx`.
    pub fn chunk_range(&self, idx: usize) -> (u64, u64) {
        let lo = if idx == 0 { 0 } else { self.bounds[idx - 1] + 1 };
        (lo, self.bounds[idx])
    }

    /// Split chunk `idx` at `at` (which becomes the upper bound of the
    /// left half). Both halves keep the owner. Bumps the version.
    pub fn split(&mut self, idx: usize, at: u64) -> Result<()> {
        if idx >= self.bounds.len() {
            bail!("split: no chunk {idx}");
        }
        let (lo, hi) = self.chunk_range(idx);
        if at < lo || at >= hi {
            bail!("split point {at} outside chunk {idx} range [{lo}, {hi}]");
        }
        self.bounds.insert(idx, at);
        self.owners.insert(idx, self.owners[idx]);
        self.version += 1;
        Ok(())
    }

    /// Reassign chunk `idx` to `to`. Bumps the version.
    pub fn move_chunk(&mut self, idx: usize, to: ShardId) -> Result<()> {
        if idx >= self.owners.len() {
            bail!("move: no chunk {idx}");
        }
        self.owners[idx] = to;
        self.version += 1;
        Ok(())
    }

    /// Chunks per shard (balancer input).
    pub fn chunk_counts(&self, num_shards: usize) -> Vec<u32> {
        let mut counts = vec![0u32; num_shards];
        for o in &self.owners {
            counts[o.index()] += 1;
        }
        counts
    }

    /// Chunk table in the AOT route-kernel's format (u32 ring only).
    ///
    /// Panics if called on a ranged map — the router uses scalar routing
    /// for ranged keys.
    pub fn kernel_tables(&self) -> (Vec<u32>, Vec<i32>) {
        assert_eq!(
            self.key.kind,
            ShardKeyKind::Hashed,
            "kernel routing requires hashed keys"
        );
        let bounds: Vec<u32> = self.bounds.iter().map(|&b| b as u32).collect();
        let owners: Vec<i32> = self.owners.iter().map(|o| o.0 as i32).collect();
        (bounds, owners)
    }

    /// Structural invariants (checked after every mutation in tests and
    /// by the config server in debug builds).
    pub fn validate(&self) -> Result<()> {
        if self.bounds.is_empty() {
            bail!("empty chunk map");
        }
        if self.bounds.len() != self.owners.len() {
            bail!("bounds/owners length mismatch");
        }
        // lint: allow(panic, the is_empty bail above guarantees a last element)
        if *self.bounds.last().unwrap() != self.key.max_position() {
            bail!("last bound must be the top of the position space");
        }
        if !self.bounds.windows(2).all(|w| w[0] < w[1]) {
            bail!("bounds not strictly increasing");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn pre_split_covers_ring_evenly() {
        let m = ChunkMap::pre_split(ShardKey::hashed(), 7, 2);
        m.validate().unwrap();
        assert_eq!(m.num_chunks(), 14);
        assert_eq!(*m.bounds.last().unwrap(), u32::MAX as u64);
        let counts = m.chunk_counts(7);
        assert!(counts.iter().all(|&c| c == 2));
    }

    #[test]
    fn chunk_of_boundary_semantics() {
        let m = ChunkMap {
            key: ShardKey::hashed(),
            version: 1,
            bounds: vec![100, 200, u32::MAX as u64],
            owners: vec![ShardId(0), ShardId(1), ShardId(2)],
            handoff: None,
        };
        m.validate().unwrap();
        assert_eq!(m.chunk_of(0), 0);
        assert_eq!(m.chunk_of(100), 0); // inclusive upper bound
        assert_eq!(m.chunk_of(101), 1);
        assert_eq!(m.chunk_of(200), 1);
        assert_eq!(m.chunk_of(u32::MAX as u64), 2);
        assert_eq!(m.owner_of(150), ShardId(1));
    }

    #[test]
    fn split_keeps_coverage() {
        let mut m = ChunkMap::pre_split(ShardKey::hashed(), 2, 1);
        let v0 = m.version;
        let (lo, hi) = m.chunk_range(0);
        let mid = lo + (hi - lo) / 2;
        m.split(0, mid).unwrap();
        m.validate().unwrap();
        assert_eq!(m.num_chunks(), 3);
        assert_eq!(m.version, v0 + 1);
        assert_eq!(m.owners[0], m.owners[1]); // both halves keep owner
        // Positions re-resolve consistently.
        assert_eq!(m.chunk_of(mid), 0);
        assert_eq!(m.chunk_of(mid + 1), 1);
    }

    #[test]
    fn split_rejects_out_of_range_points() {
        let mut m = ChunkMap::pre_split(ShardKey::hashed(), 2, 1);
        let (lo, hi) = m.chunk_range(1);
        assert!(m.split(1, hi).is_err()); // at == hi would make empty right half
        assert!(m.split(1, lo - 1).is_err());
        assert!(m.split(9, lo).is_err());
    }

    #[test]
    fn move_chunk_changes_owner_and_version() {
        let mut m = ChunkMap::pre_split(ShardKey::hashed(), 3, 1);
        m.move_chunk(0, ShardId(2)).unwrap();
        assert_eq!(m.owners[0], ShardId(2));
        assert_eq!(m.chunk_counts(3), vec![0, 1, 2]);
    }

    #[test]
    fn ranged_positions_are_monotonic_in_key() {
        let k = ShardKey::ranged();
        assert!(k.position(1, 100) < k.position(1, 101));
        assert!(k.position(1, u32::MAX) < k.position(2, 0));
        assert_eq!(k.max_position(), u64::MAX);
    }

    #[test]
    fn hashed_positions_match_route_kernel_hash() {
        let k = ShardKey::hashed();
        assert_eq!(k.position(123, 456), fnv1a_shard_key(123, 456) as u64);
    }

    #[test]
    fn kernel_tables_round_trip() {
        let m = ChunkMap::pre_split(ShardKey::hashed(), 7, 2);
        let (bounds, owners) = m.kernel_tables();
        assert_eq!(bounds.len(), 14);
        assert_eq!(*bounds.last().unwrap(), u32::MAX);
        assert_eq!(owners[0], 0);
        // Scalar fallback on these tables must agree with owner_of.
        for pos in [0u64, 1 << 20, 1 << 31, u32::MAX as u64] {
            let via_fallback = crate::runtime::fallback::chunk_of_hash(pos as u32, &bounds);
            assert_eq!(via_fallback, m.chunk_of(pos), "pos={pos}");
        }
    }

    #[test]
    #[should_panic(expected = "hashed keys")]
    fn kernel_tables_reject_ranged() {
        ChunkMap::pre_split(ShardKey::ranged(), 2, 1).kernel_tables();
    }

    #[test]
    fn effective_read_owner_tracks_handoff_phases() {
        let mut m = ChunkMap::pre_split(ShardKey::ranged(), 2, 1);
        let (lo, hi) = m.chunk_range(0);
        let donor = m.owners[0];
        let dest = ShardId(1);
        m.move_chunk(0, dest).unwrap(); // the flip
        // Unpublished handoff: the donor's copy is authoritative even
        // though the owners row says the destination owns the range.
        m.handoff = Some(MigrationHandoff { range: (lo, hi), from: donor, published: false });
        assert_eq!(m.effective_read_owner(lo), donor);
        assert_eq!(m.effective_read_owner(hi), donor);
        assert_eq!(m.effective_read_owner(hi + 1), m.owner_of(hi + 1));
        // Published: ownership follows the map; the donor's leftover
        // copy is an orphan.
        m.handoff = Some(MigrationHandoff { range: (lo, hi), from: donor, published: true });
        assert_eq!(m.effective_read_owner(lo), dest);
        // No handoff: plain owners row.
        m.handoff = None;
        assert_eq!(m.effective_read_owner(lo), dest);
    }

    #[test]
    fn property_random_split_sequences_stay_valid() {
        check(
            "chunkmap-splits",
            &(|rng: &mut Pcg32| {
                let shards = 1 + rng.next_bounded(8);
                let ops = rng.next_bounded(40);
                (shards, ops, rng.next_u64())
            }),
            |&(shards, ops, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let mut m = ChunkMap::pre_split(ShardKey::hashed(), shards, 1);
                for _ in 0..ops {
                    let idx = rng.next_bounded(m.num_chunks() as u32) as usize;
                    let (lo, hi) = m.chunk_range(idx);
                    if hi > lo {
                        let at = lo + rng.next_u64() % (hi - lo);
                        m.split(idx, at).map_err(|e| e.to_string())?;
                    }
                    if rng.next_bounded(3) == 0 {
                        let idx = rng.next_bounded(m.num_chunks() as u32) as usize;
                        m.move_chunk(idx, ShardId(rng.next_bounded(shards)))
                            .map_err(|e| e.to_string())?;
                    }
                    m.validate().map_err(|e| e.to_string())?;
                }
                // Every position resolves to a unique chunk.
                for _ in 0..50 {
                    let p = rng.next_u64() % (u32::MAX as u64 + 1);
                    let c = m.chunk_of(p);
                    let (lo, hi) = m.chunk_range(c);
                    if !(lo <= p && p <= hi) {
                        return Err(format!("pos {p} not in chunk {c} [{lo},{hi}]"));
                    }
                }
                Ok(())
            },
        );
    }
}
