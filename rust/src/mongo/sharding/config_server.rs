//! Config-server state: the sharded cluster's metadata authority.
//!
//! "Config servers store the metadata for a sharded cluster ... the list
//! of chunks on every shard and the ranges that define the chunks"
//! (paper §3.1). [`ConfigState`] is the pure, testable state machine; the
//! live cluster hosts it on the config-server thread(s) behind the wire
//! layer. A small CSRS-style replica set is modeled: every mutation is
//! applied to the primary and synchronously acked by the mirrors, and
//! reads may be served by any member.

use anyhow::{bail, Result};

use super::chunk::{ChunkMap, ShardKey};
use crate::util::ids::ShardId;

/// Outcome of a version-guarded mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VersionCheck {
    Ok,
    /// Caller's cached map is stale; it must refresh before retrying.
    Stale { current: u64 },
}

/// A chunk migration in flight.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Migration {
    pub chunk: usize,
    pub from: ShardId,
    pub to: ShardId,
}

/// The metadata state machine.
#[derive(Clone, Debug)]
pub struct ConfigState {
    shards: Vec<ShardId>,
    map: ChunkMap,
    /// Synchronous mirrors (replica count - 1). Kept bit-identical to
    /// the primary map; a read may be served from any of them.
    mirrors: Vec<ChunkMap>,
    migration: Option<Migration>,
    /// Mutation log length (diagnostics; equals number of committed
    /// metadata changes).
    pub oplog_len: u64,
}

impl ConfigState {
    /// Initialize with `num_shards` registered shards and a pre-split
    /// chunk table (`chunks_per_shard` chunks each).
    pub fn new(key: ShardKey, num_shards: u32, chunks_per_shard: u32, replicas: u32) -> Self {
        let map = ChunkMap::pre_split(key, num_shards, chunks_per_shard);
        let mirrors = vec![map.clone(); replicas.saturating_sub(1) as usize];
        Self {
            shards: (0..num_shards).map(ShardId).collect(),
            map,
            mirrors,
            migration: None,
            oplog_len: 0,
        }
    }

    pub fn shards(&self) -> &[ShardId] {
        &self.shards
    }

    /// Current map (primary read).
    pub fn map(&self) -> &ChunkMap {
        &self.map
    }

    /// Read from mirror `i` (tests assert replica consistency).
    pub fn mirror(&self, i: usize) -> Option<&ChunkMap> {
        self.mirrors.get(i)
    }

    pub fn version(&self) -> u64 {
        self.map.version
    }

    fn replicate(&mut self) {
        for m in &mut self.mirrors {
            *m = self.map.clone();
        }
        self.oplog_len += 1;
    }

    /// Version-guarded chunk split requested by a shard that saw a chunk
    /// exceed the split threshold. Idempotent under stale versions: a
    /// stale requester gets `Stale` and refreshes.
    pub fn split_chunk(
        &mut self,
        seen_version: u64,
        chunk: usize,
        at: u64,
    ) -> Result<VersionCheck> {
        if seen_version != self.map.version {
            return Ok(VersionCheck::Stale { current: self.map.version });
        }
        self.map.split(chunk, at)?;
        debug_assert!(self.map.validate().is_ok());
        self.replicate();
        Ok(VersionCheck::Ok)
    }

    /// Begin migrating `chunk` to `to`. Only one migration at a time
    /// (MongoDB serializes per-collection migrations through the config
    /// server — this serialization is one of the scaling costs the DES
    /// models).
    pub fn begin_migration(&mut self, chunk: usize, to: ShardId) -> Result<Migration> {
        if self.migration.is_some() {
            bail!("a migration is already in flight");
        }
        if chunk >= self.map.num_chunks() {
            bail!("no chunk {chunk}");
        }
        if !self.shards.contains(&to) {
            bail!("unknown destination {to}");
        }
        let from = self.map.owners[chunk];
        if from == to {
            bail!("chunk {chunk} already on {to}");
        }
        let m = Migration { chunk, from, to };
        self.migration = Some(m.clone());
        Ok(m)
    }

    /// Commit the in-flight migration: flips ownership, bumps version.
    pub fn commit_migration(&mut self) -> Result<u64> {
        let m = self
            .migration
            .take()
            .ok_or_else(|| anyhow::anyhow!("no migration in flight"))?;
        self.map.move_chunk(m.chunk, m.to)?;
        debug_assert!(self.map.validate().is_ok());
        self.replicate();
        Ok(self.map.version)
    }

    /// Abort the in-flight migration (destination failed).
    pub fn abort_migration(&mut self) {
        self.migration = None;
    }

    pub fn migration(&self) -> Option<&Migration> {
        self.migration.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> ConfigState {
        ConfigState::new(ShardKey::hashed(), 4, 2, 3)
    }

    #[test]
    fn initial_state_is_pre_split() {
        let s = state();
        assert_eq!(s.shards().len(), 4);
        assert_eq!(s.map().num_chunks(), 8);
        assert_eq!(s.version(), 1);
        assert_eq!(s.mirror(0).unwrap(), s.map());
        assert_eq!(s.mirror(1).unwrap(), s.map());
        assert!(s.mirror(2).is_none()); // replicas=3 → 2 mirrors
    }

    #[test]
    fn split_bumps_version_and_replicates() {
        let mut s = state();
        let (lo, hi) = s.map().chunk_range(0);
        let r = s.split_chunk(1, 0, lo + (hi - lo) / 2).unwrap();
        assert_eq!(r, VersionCheck::Ok);
        assert_eq!(s.version(), 2);
        assert_eq!(s.map().num_chunks(), 9);
        assert_eq!(s.mirror(0).unwrap().num_chunks(), 9);
        assert_eq!(s.oplog_len, 1);
    }

    #[test]
    fn stale_split_is_rejected_without_mutation() {
        let mut s = state();
        let (lo, hi) = s.map().chunk_range(0);
        s.split_chunk(1, 0, lo + (hi - lo) / 2).unwrap();
        // Second requester still thinks version is 1.
        let r = s.split_chunk(1, 1, 0).unwrap();
        assert_eq!(r, VersionCheck::Stale { current: 2 });
        assert_eq!(s.map().num_chunks(), 9); // unchanged
    }

    #[test]
    fn migration_lifecycle() {
        let mut s = state();
        let from = s.map().owners[0];
        let to = ShardId((from.0 + 1) % 4);
        let m = s.begin_migration(0, to).unwrap();
        assert_eq!(m.from, from);
        // Only one at a time.
        assert!(s.begin_migration(1, to).is_err());
        let v = s.commit_migration().unwrap();
        assert_eq!(v, 2);
        assert_eq!(s.map().owners[0], to);
        assert_eq!(s.mirror(1).unwrap().owners[0], to);
        assert!(s.migration().is_none());
    }

    #[test]
    fn migration_abort_releases_lock() {
        let mut s = state();
        let to = ShardId((s.map().owners[0].0 + 1) % 4);
        s.begin_migration(0, to).unwrap();
        s.abort_migration();
        assert!(s.begin_migration(0, to).is_ok());
    }

    #[test]
    fn migration_validations() {
        let mut s = state();
        let owner = s.map().owners[0];
        assert!(s.begin_migration(0, owner).is_err()); // same shard
        assert!(s.begin_migration(99, ShardId(1)).is_err()); // no chunk
        assert!(s.begin_migration(0, ShardId(99)).is_err()); // no shard
        assert!(s.commit_migration().is_err()); // nothing in flight
    }
}
