//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this crate provides
//! the subset of anyhow's API the workspace actually uses — `Error`,
//! `Result`, the `anyhow!`/`bail!`/`ensure!` macros, and the `Context`
//! extension trait — with matching semantics:
//!
//! * `Display` shows the outermost message (the most recent context, or
//!   the root error when no context was attached).
//! * The alternate form `{:#}` shows the whole chain, outermost first,
//!   joined with `": "`.
//! * `Debug` (what `unwrap()` prints) shows the outermost message plus a
//!   `Caused by:` list.
//! * Any `std::error::Error + Send + Sync + 'static` converts into
//!   `Error` via `?`.

use std::error::Error as StdError;
use std::fmt;

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    Msg(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
}

/// A dynamic error with an optional stack of context messages.
pub struct Error {
    /// Context messages, innermost first (last entry is outermost).
    context: Vec<String>,
    repr: Repr,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Self {
        Error { context: Vec::new(), repr: Repr::Msg(message.to_string()) }
    }

    /// Attach an outer context message (most recent wins for `Display`).
    pub fn context<C: fmt::Display + Send + Sync + 'static>(mut self, context: C) -> Self {
        self.context.push(context.to_string());
        self
    }

    fn root_msg(&self) -> String {
        match &self.repr {
            Repr::Msg(m) => m.clone(),
            Repr::Boxed(e) => e.to_string(),
        }
    }

    /// Messages from outermost to root.
    fn chain_msgs(&self) -> Vec<String> {
        let mut msgs: Vec<String> = self.context.iter().rev().cloned().collect();
        msgs.push(self.root_msg());
        if let Repr::Boxed(e) = &self.repr {
            let mut src = e.source();
            while let Some(s) = src {
                msgs.push(s.to_string());
                src = s.source();
            }
        }
        msgs
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain_msgs().join(": "))
        } else {
            match self.context.last() {
                Some(outer) => write!(f, "{outer}"),
                None => write!(f, "{}", self.root_msg()),
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_msgs();
        write!(f, "{}", msgs[0])?;
        if msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { context: Vec::new(), repr: Repr::Boxed(Box::new(e)) }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "missing file");
        let e = e.context("opening config");
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn debug_lists_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        // Context on an already-anyhow error.
        let inner: Error = Error::msg("inner");
        let r: Result<()> = Err(inner);
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
