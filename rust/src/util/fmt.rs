//! Human-readable formatting helpers for reports and logs.

/// Format a byte count with binary units (`1.5 GiB`).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Format a count with SI-ish suffixes (`1.2M`, `3.4k`).
pub fn human_count(n: u64) -> String {
    let v = n as f64;
    if v >= 1e9 {
        format!("{:.2}B", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{n}")
    }
}

/// Format nanoseconds adaptively (`250 ns`, `1.25 µs`, `3.2 ms`, `1.5 s`).
pub fn human_duration_ns(ns: u64) -> String {
    let v = ns as f64;
    if v < 1e3 {
        format!("{ns} ns")
    } else if v < 1e6 {
        format!("{:.2} µs", v / 1e3)
    } else if v < 1e9 {
        format!("{:.2} ms", v / 1e6)
    } else {
        format!("{:.2} s", v / 1e9)
    }
}

/// Render a markdown table: header row + aligned separator + rows.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        format!("| {} |\n", padded.join(" | "))
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(&sep, &widths));
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(human_bytes(u64::MAX).contains("PiB"), true);
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1500), "1.5k");
        assert_eq!(human_count(2_500_000), "2.50M");
        assert_eq!(human_count(70_000_000_000), "70.00B");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration_ns(250), "250 ns");
        assert_eq!(human_duration_ns(1_250), "1.25 µs");
        assert_eq!(human_duration_ns(3_200_000), "3.20 ms");
        assert_eq!(human_duration_ns(1_500_000_000), "1.50 s");
    }

    #[test]
    fn table_alignment() {
        let t = markdown_table(
            &["name", "v"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "22".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("| name"));
        assert!(lines.iter().all(|l| l.starts_with('|') && l.ends_with('|')));
    }
}
