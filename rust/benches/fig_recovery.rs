//! FR — recovery time vs journal size, before and after checkpoint
//! compaction.
//!
//! The lifecycle claim this bench measures: without compaction a killed
//! job replays its *entire* write history on the next deployment
//! (recovery is O(total writes)); with a checkpoint the next job loads
//! the snapshot and replays only the post-checkpoint tail. Rows sweep
//! the ingested volume; for each volume the same store is recovered
//! twice — once from the full journal, once after `checkpoint()` — and
//! the replayed frame/byte counts come from the engine's own
//! `RecoveryReport`.
//!
//! Run: `cargo bench --bench fig_recovery` (add `--quick` for a small
//! sweep). See `docs/EXPERIMENTS.md` for the recorded-results template.

use std::time::Instant;

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::storage::{Engine, LocalDir, StorageDir};
use hpcstore::util::fmt::human_count;

fn doc(i: u64) -> Document {
    Document::new()
        .set("ts", i as i64)
        .set("node_id", (i % 256) as i64)
        .set("m0", i as f64 * 0.5)
        .set("m1", (i * 7) as f64)
        .set("m2", (i * 13) as f64)
}

fn main() {
    let sizes: &[u64] = if quick_mode() {
        &[2_000, 8_000]
    } else {
        &[2_000, 8_000, 32_000, 64_000]
    };

    let mut report = Report::new(
        "Recovery — replay cost vs ingested volume, before/after checkpoint compaction",
    );
    report.set_custom(
        [
            "docs",
            "journal",
            "recover (full replay)",
            "frames replayed",
            "recover (post-ckpt)",
            "tail frames",
            "speedup",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &n in sizes {
        // Build a journaled store of n synced documents, never
        // checkpointed — the walltime-kill worst case.
        let dir = LocalDir::temp(&format!("figrec-{n}")).unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            let mut i = 0u64;
            while i < n {
                let batch: Vec<Document> = (i..(i + 512).min(n)).map(doc).collect();
                i += batch.len() as u64;
                eng.insert_many("metrics", &batch).unwrap();
                eng.sync().unwrap();
            }
        }

        // (a) Recover from the full journal.
        let t = Instant::now();
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        let full_ns = t.elapsed().as_nanos() as u64;
        assert_eq!(eng.stats("metrics").docs, n);
        let full = eng.recovery_report().clone();
        drop(eng);

        // (b) Compact, add a small tail, then recover again: replay is
        // tail-only.
        {
            let mut eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            eng.checkpoint().unwrap();
            let tail: Vec<Document> = (n..n + 64).map(doc).collect();
            eng.insert_many("metrics", &tail).unwrap();
            eng.sync().unwrap();
        }
        let t = Instant::now();
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        let ckpt_ns = t.elapsed().as_nanos() as u64;
        assert_eq!(eng.stats("metrics").docs, n + 64);
        let tail = eng.recovery_report().clone();
        assert!(
            tail.bytes_replayed < full.bytes_replayed,
            "compaction must shrink the replay"
        );

        report.add_row(vec![
            human_count(n),
            format!("{} B", human_count(full.bytes_replayed)),
            format!("{:.2} ms", full_ns as f64 / 1e6),
            full.frames_replayed.to_string(),
            format!("{:.2} ms", ckpt_ns as f64 / 1e6),
            tail.frames_replayed.to_string(),
            format!("{:.1}x", full_ns as f64 / ckpt_ns.max(1) as f64),
        ]);
    }
    report.print();
    println!(
        "\nclaim: with compaction, recovery replays only the post-checkpoint tail \
         (frames column) instead of the full write history\n"
    );
}
