//! FIFO resources with analytic service horizons.
//!
//! A [`Resource`] with capacity `c` keeps the next-free time of each of
//! its `c` servers; `serve(arrival, service)` assigns the earliest free
//! server and returns the completion time. Utilization and queue-wait
//! statistics accumulate for the report.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A FIFO multi-server resource.
pub struct Resource {
    pub name: String,
    free_at: BinaryHeap<Reverse<u64>>,
    busy_ns: u64,
    wait_ns: u64,
    served: u64,
    horizon_ns: u64,
}

impl Resource {
    pub fn new(name: impl Into<String>, capacity: u32) -> Self {
        let mut free_at = BinaryHeap::new();
        for _ in 0..capacity.max(1) {
            free_at.push(Reverse(0));
        }
        Self {
            name: name.into(),
            free_at,
            busy_ns: 0,
            wait_ns: 0,
            served: 0,
            horizon_ns: 0,
        }
    }

    /// Serve a request arriving at `arrival_ns` needing `service_ns`;
    /// returns completion time.
    pub fn serve(&mut self, arrival_ns: u64, service_ns: u64) -> u64 {
        let Reverse(free) = self.free_at.pop().expect("resource has capacity");
        let start = arrival_ns.max(free);
        let done = start + service_ns;
        self.free_at.push(Reverse(done));
        self.busy_ns += service_ns;
        self.wait_ns += start - arrival_ns;
        self.served += 1;
        self.horizon_ns = self.horizon_ns.max(done);
        done
    }

    pub fn served(&self) -> u64 {
        self.served
    }

    pub fn mean_wait_ns(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.wait_ns as f64 / self.served as f64
        }
    }

    /// Busy fraction over `[0, horizon]`.
    pub fn utilization(&self, horizon_ns: u64) -> f64 {
        let cap = self.free_at.len() as u64;
        self.busy_ns as f64 / (cap * horizon_ns.max(1)) as f64
    }

    pub fn busy_ns(&self) -> u64 {
        self.busy_ns
    }
}

/// A bandwidth-accounted *delay* element (no queueing): transfers take
/// `service_ns` regardless of concurrency, and utilization is tracked so
/// reports can flag when the no-queue assumption stops holding (ρ close
/// to 1). Used for the torus fabric, whose per-transfer times are µs
/// while the analytic-pipeline events arrive out of order — a FIFO there
/// manufactures phantom waits; a delay + load meter does not.
pub struct FlowMeter {
    pub name: String,
    busy_ns: u64,
    served: u64,
}

impl FlowMeter {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), busy_ns: 0, served: 0 }
    }

    /// Account the transfer; completion is simply `arrival + service`.
    pub fn serve(&mut self, arrival_ns: u64, service_ns: u64) -> u64 {
        self.busy_ns += service_ns;
        self.served += 1;
        arrival_ns + service_ns
    }

    /// Offered load over `[0, horizon]`.
    pub fn utilization(&self, horizon_ns: u64) -> f64 {
        self.busy_ns as f64 / horizon_ns.max(1) as f64
    }

    pub fn served(&self) -> u64 {
        self.served
    }
}

/// A pool of identical resources indexed by id (shard CPUs, OSTs...).
pub struct Pool {
    pub resources: Vec<Resource>,
}

impl Pool {
    pub fn new(name: &str, count: u32, capacity: u32) -> Self {
        Self {
            resources: (0..count)
                .map(|i| Resource::new(format!("{name}-{i}"), capacity))
                .collect(),
        }
    }

    pub fn serve(&mut self, idx: usize, arrival_ns: u64, service_ns: u64) -> u64 {
        self.resources[idx].serve(arrival_ns, service_ns)
    }

    pub fn len(&self) -> usize {
        self.resources.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resources.is_empty()
    }

    pub fn total_busy_ns(&self) -> u64 {
        self.resources.iter().map(Resource::busy_ns).sum()
    }

    pub fn max_utilization(&self, horizon_ns: u64) -> f64 {
        self.resources
            .iter()
            .map(|r| r.utilization(horizon_ns))
            .fold(0.0, f64::max)
    }

    pub fn mean_utilization(&self, horizon_ns: u64) -> f64 {
        if self.resources.is_empty() {
            return 0.0;
        }
        self.resources
            .iter()
            .map(|r| r.utilization(horizon_ns))
            .sum::<f64>()
            / self.resources.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_server_queues_fifo() {
        let mut r = Resource::new("cpu", 1);
        assert_eq!(r.serve(0, 10), 10);
        assert_eq!(r.serve(0, 10), 20); // queued behind the first
        assert_eq!(r.serve(50, 10), 60); // idle gap
        assert_eq!(r.served(), 3);
        assert!((r.utilization(60) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn multi_server_parallelism() {
        let mut r = Resource::new("cpu", 2);
        assert_eq!(r.serve(0, 10), 10);
        assert_eq!(r.serve(0, 10), 10); // second server
        assert_eq!(r.serve(0, 10), 20); // queued
    }

    #[test]
    fn wait_accounting() {
        let mut r = Resource::new("x", 1);
        r.serve(0, 100);
        r.serve(0, 100); // waits 100
        assert!((r.mean_wait_ns() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn pool_independent_lanes() {
        let mut p = Pool::new("ost", 4, 1);
        assert_eq!(p.serve(0, 0, 10), 10);
        assert_eq!(p.serve(1, 0, 10), 10);
        assert_eq!(p.serve(0, 0, 10), 20);
        assert_eq!(p.total_busy_ns(), 30);
        assert!(p.max_utilization(20) > p.mean_utilization(20));
    }
}
