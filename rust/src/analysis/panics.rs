//! Rule: **panic paths** (invariants I4–I7 stay reachable).
//!
//! A shard thread that panics takes its mailbox with it: every client
//! blocked on a reply channel hangs, and the scheduler sees a stuck —
//! not failed — job. So server-side code must propagate errors, and
//! the rare provably-unreachable `unwrap`/`expect` must say *why* it
//! is unreachable where the next editor will read it. In non-test
//! code under `rust/src/mongo/{server,storage,sharding}`:
//!
//! 1. `unwrap()`, `expect(...)`, `panic!`, and `unreachable!` are
//!    flagged unless covered by a `// lint: allow(panic, <reason>)`
//!    annotation on the same line or in the comment block immediately
//!    above;
//! 2. a mutex guard bound by `let g = ....lock()...;` that is still
//!    live (not `drop(g)`-ed, block not closed) at a channel
//!    `send`/`recv` call is flagged — holding a lock across a
//!    blocking channel op in event-loop code is a deadlock waiting
//!    for its schedule (`// lint: allow(lock, <reason>)` to override).

use super::lexer::{SourceFile, TokKind};
use super::{SourceTree, Violation};

const RULE: &str = "panic-path";
const SCOPES: &[&str] = &[
    "rust/src/mongo/server/",
    "rust/src/mongo/storage/",
    "rust/src/mongo/sharding/",
];

pub fn check(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    for &scope in SCOPES {
        for path in tree.paths_under(scope, ".rs") {
            let f = tree.lexed(path).expect("listed path is present");
            check_panics(&f, path, &mut out);
            check_lock_discipline(&f, path, &mut out);
        }
    }
    out
}

fn check_panics(f: &SourceFile, path: &str, out: &mut Vec<Violation>) {
    let t = &f.tokens;
    for i in 0..t.len() {
        if f.is_test_line(t[i].line) {
            continue;
        }
        let site = match t[i].text.as_str() {
            "unwrap"
                if i > 0
                    && t[i - 1].text == "."
                    && t.get(i + 1).is_some_and(|p| p.text == "(")
                    && t.get(i + 2).is_some_and(|p| p.text == ")") =>
            {
                Some("unwrap()")
            }
            "expect"
                if i > 0
                    && t[i - 1].text == "."
                    && t.get(i + 1).is_some_and(|p| p.text == "(") =>
            {
                Some("expect(..)")
            }
            "panic" | "unreachable"
                if t.get(i + 1).is_some_and(|b| b.text == "!") =>
            {
                Some("panic-style macro")
            }
            _ => None,
        };
        let Some(what) = site else { continue };
        if !f.annotated(t[i].line, "lint: allow(panic") {
            out.push(Violation {
                file: path.to_string(),
                line: t[i].line,
                rule: RULE,
                message: format!(
                    "{what} in server-side code — propagate the error, or annotate `// lint: allow(panic, <reason>)` with why it cannot fire"
                ),
            });
        }
    }
}

/// Flag a `let`-bound lock guard still live at a channel send/recv.
fn check_lock_discipline(f: &SourceFile, path: &str, out: &mut Vec<Violation>) {
    let t = &f.tokens;
    for i in 0..t.len() {
        if t[i].text != "let" || f.is_test_line(t[i].line) {
            continue;
        }
        let mut g = i + 1;
        if t.get(g).is_some_and(|m| m.text == "mut") {
            g += 1;
        }
        let Some(guard) = t.get(g).filter(|n| n.kind == TokKind::Ident) else {
            continue;
        };
        if t.get(g + 1).map(|e| e.text.as_str()) != Some("=") {
            continue;
        }
        // Does the initializer (up to `;`) take a lock?
        let mut j = g + 2;
        let mut takes_lock = false;
        while j < t.len() && t[j].text != ";" {
            if t[j].text == "."
                && t.get(j + 1).is_some_and(|m| m.text == "lock")
                && t.get(j + 2).is_some_and(|p| p.text == "(")
            {
                takes_lock = true;
            }
            j += 1;
        }
        if !takes_lock {
            continue;
        }
        // Guard is live from the `;` until `drop(guard)` or the end of
        // the enclosing block.
        let mut k = j + 1;
        let mut depth = 0i32;
        while k < t.len() {
            match t[k].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth < 0 {
                        break; // enclosing block closed: guard dropped
                    }
                }
                "drop"
                    if t.get(k + 1).is_some_and(|p| p.text == "(")
                        && t.get(k + 2).is_some_and(|n| n.text == guard.text) =>
                {
                    break;
                }
                "." if t.get(k + 1).is_some_and(|m| {
                    matches!(
                        m.text.as_str(),
                        "send" | "try_send" | "recv" | "try_recv" | "recv_timeout"
                    )
                }) && t.get(k + 2).is_some_and(|p| p.text == "(") =>
                {
                    let line = t[k + 1].line;
                    if !f.annotated(line, "lint: allow(lock") {
                        out.push(Violation {
                            file: path.to_string(),
                            line,
                            rule: RULE,
                            message: format!(
                                "mutex guard `{}` (locked at line {}) is held across a channel {} — drop it first or annotate `// lint: allow(lock, <reason>)`",
                                guard.text,
                                guard.line,
                                t[k + 1].text
                            ),
                        });
                    }
                    break; // one finding per guard is enough
                }
                _ => {}
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(src: &str) -> SourceTree {
        let mut t = SourceTree::new();
        t.add("rust/src/mongo/server/shard.rs", src);
        t
    }

    #[test]
    fn annotated_panics_pass() {
        let t = tree(
            "fn f(x: Option<u8>) -> u8 {\n    // lint: allow(panic, x is checked by the caller)\n    x.unwrap()\n}\n",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn unannotated_unwrap_expect_and_macros_are_flagged() {
        let t = tree(
            "fn f(x: Option<u8>) -> u8 { x.unwrap() }\nfn g(x: Option<u8>) -> u8 { x.expect(\"always\") }\nfn h() { panic!(\"boom\") }\nfn i() { unreachable!() }\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 4, "{v:?}");
        assert_eq!(v.iter().map(|x| x.line).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn test_module_panics_pass() {
        let t = tree(
            "fn f() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { None::<u8>.unwrap(); }\n}\n",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn out_of_scope_files_are_not_linted() {
        let mut t = SourceTree::new();
        // bson.rs sits directly under mongo/, outside the server scope.
        t.add("rust/src/mongo/bson.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }");
        assert!(check(&t).is_empty());
    }

    #[test]
    fn guard_across_send_is_flagged() {
        let t = tree(
            "fn f(&self) {\n    // lint: allow(panic, fixture)\n    let g = self.state.lock().unwrap();\n    self.tx.send(1);\n}\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("held across a channel send"));
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn dropped_guard_before_send_passes() {
        let t = tree(
            "fn f(&self) {\n    // lint: allow(panic, fixture)\n    let g = self.state.lock().unwrap();\n    drop(g);\n    self.tx.send(1);\n}\n",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn block_scoped_guard_passes() {
        let t = tree(
            "fn f(&self) {\n    {\n        // lint: allow(panic, fixture)\n        let g = self.state.lock().unwrap();\n    }\n    self.tx.send(1);\n}\n",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }
}
