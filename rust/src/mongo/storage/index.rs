//! Ordered secondary indexes.
//!
//! Keys are encoded into order-preserving byte strings (type tag +
//! big-endian payloads with sign/NaN handling), so a `BTreeMap` range
//! scan over encoded bounds is a correct index range scan under the
//! total value order of [`Value::cmp_total`].

use std::collections::BTreeMap;
use std::ops::Bound;

use super::engine::RecordId;
use crate::mongo::bson::{Document, Value};

/// Index definition: one or more fields, ascending (the workload indexes
/// `ts` and `node_id`; compound (`node_id`, `ts`) is supported and used
/// by ablation A2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexSpec {
    pub name: String,
    pub fields: Vec<String>,
}

impl IndexSpec {
    pub fn single(field: &str) -> Self {
        Self { name: format!("{field}_1"), fields: vec![field.to_string()] }
    }

    pub fn compound(fields: &[&str]) -> Self {
        Self {
            name: fields.join("_1_") + "_1",
            fields: fields.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Encode one value into an order-preserving byte string.
///
/// Layout: type-rank byte, then payload:
/// * numbers: f64 bits with sign-flip trick (order-preserving across
///   Int/F64 since comparison is numeric)
/// * strings: bytes + 0x00 terminator (no embedded NULs in our corpus)
/// * bool: 0/1
pub fn encode_value(v: &Value, out: &mut Vec<u8>) {
    out.push(v.type_rank());
    match v {
        Value::Null => {}
        Value::Bool(b) => out.push(*b as u8),
        Value::Int(_) | Value::F64(_) => {
            let f = v.as_f64().unwrap();
            let bits = f.to_bits();
            // Flip sign bit for positives, all bits for negatives: total
            // order matches numeric order.
            let ordered = if bits >> 63 == 0 { bits ^ (1 << 63) } else { !bits };
            out.extend_from_slice(&ordered.to_be_bytes());
        }
        Value::Str(s) => {
            debug_assert!(!s.as_bytes().contains(&0), "NUL in index key");
            out.extend_from_slice(s.as_bytes());
            out.push(0);
        }
        Value::Array(items) => {
            for item in items {
                encode_value(item, out);
            }
            out.push(0xFF); // terminator above any element tag? see note
        }
        Value::Doc(d) => {
            for (k, val) in &d.fields {
                out.extend_from_slice(k.as_bytes());
                out.push(0);
                encode_value(val, out);
            }
            out.push(0xFF);
        }
    }
}

/// Encode a (possibly compound) key from `values`.
pub fn encode_key(values: &[&Value]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 10);
    for v in values {
        encode_value(v, &mut out);
    }
    out
}

/// An in-memory ordered index.
pub struct Index {
    pub spec: IndexSpec,
    /// encoded key → record ids (duplicates common: same ts across all
    /// monitored nodes).
    map: BTreeMap<Vec<u8>, Vec<RecordId>>,
    entries: u64,
}

impl Index {
    pub fn new(spec: IndexSpec) -> Self {
        Self { spec, map: BTreeMap::new(), entries: 0 }
    }

    /// Extract this index's key from a document (missing fields encode
    /// as Null, as MongoDB does).
    pub fn key_of(&self, doc: &Document) -> Vec<u8> {
        let null = Value::Null;
        let vals: Vec<&Value> = self
            .spec
            .fields
            .iter()
            .map(|f| doc.get(f).unwrap_or(&null))
            .collect();
        encode_key(&vals)
    }

    pub fn insert(&mut self, doc: &Document, rid: RecordId) {
        self.map.entry(self.key_of(doc)).or_default().push(rid);
        self.entries += 1;
    }

    pub fn remove(&mut self, doc: &Document, rid: RecordId) {
        let key = self.key_of(doc);
        if let Some(rids) = self.map.get_mut(&key) {
            if let Some(pos) = rids.iter().position(|r| *r == rid) {
                rids.swap_remove(pos);
                self.entries -= 1;
            }
            if rids.is_empty() {
                self.map.remove(&key);
            }
        }
    }

    /// Record ids whose key equals `values`.
    pub fn point(&self, values: &[&Value]) -> Vec<RecordId> {
        self.map.get(&encode_key(values)).cloned().unwrap_or_default()
    }

    /// Record ids in `[lo, hi)` on the first key field (prefix scan).
    /// `None` bound = unbounded.
    pub fn range(&self, lo: Option<&Value>, hi: Option<&Value>) -> Vec<RecordId> {
        let lo_b: Bound<Vec<u8>> = match lo {
            Some(v) => Bound::Included(encode_key(&[v])),
            None => Bound::Unbounded,
        };
        let hi_b: Bound<Vec<u8>> = match hi {
            Some(v) => Bound::Excluded(prefix_upper(encode_key(&[v]))),
            None => Bound::Unbounded,
        };
        let mut out = Vec::new();
        for (_, rids) in self.map.range((lo_b, hi_b)) {
            out.extend_from_slice(rids);
        }
        out
    }

    /// Superset scan with *inclusive* bounds on the first key field —
    /// the planner's access path. The caller always applies a residual
    /// filter (kernel or matcher), so including `hi` (and its compound
    /// extensions) is correct for every operator mix ($lte, $eq, ...).
    pub fn range_superset(&self, lo: Option<&Value>, hi: Option<&Value>) -> Vec<RecordId> {
        let lo_b: Bound<Vec<u8>> = match lo {
            Some(v) => Bound::Included(encode_key(&[v])),
            None => Bound::Unbounded,
        };
        let hi_b: Bound<Vec<u8>> = match hi {
            // Prefix-inclusive upper bound: every extension of encode(hi)
            // continues with a type-rank byte <= 6, so appending 0x07
            // excludes nothing that starts with the hi prefix.
            Some(v) => {
                let mut enc = encode_key(&[v]);
                enc.push(0x07);
                Bound::Excluded(enc)
            }
            None => Bound::Unbounded,
        };
        let mut out = Vec::new();
        for (_, rids) in self.map.range((lo_b, hi_b)) {
            out.extend_from_slice(rids);
        }
        out
    }

    pub fn entries(&self) -> u64 {
        self.entries
    }

    pub fn distinct_keys(&self) -> usize {
        self.map.len()
    }

    /// Approximate memory footprint in bytes.
    pub fn approx_bytes(&self) -> u64 {
        self.map
            .iter()
            .map(|(k, v)| (k.len() + v.len() * 8 + 32) as u64)
            .sum()
    }
}

/// For an exclusive upper bound on a *prefix* scan we must exclude every
/// key beginning with the hi prefix... but a half-open `[lo, hi)` range
/// over the first field wants keys with first-field < hi, i.e. strictly
/// before `encode(hi)` as a prefix. Any compound key starting with
/// encode(hi) must be excluded, so the exclusive bound is exactly
/// `encode(hi)` — except we must NOT exclude nothing more. Returning the
/// encoding itself excludes `hi` and all its compound extensions.
fn prefix_upper(enc: Vec<u8>) -> Vec<u8> {
    enc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn encoding_preserves_numeric_order() {
        let vals = [
            Value::F64(f64::NEG_INFINITY),
            Value::F64(-1e300),
            Value::Int(-5),
            Value::F64(-0.5),
            Value::Int(0),
            Value::F64(0.5),
            Value::Int(3),
            Value::F64(3.5),
            Value::Int(i64::MAX),
            Value::F64(f64::INFINITY),
        ];
        for w in vals.windows(2) {
            let a = encode_key(&[&w[0]]);
            let b = encode_key(&[&w[1]]);
            assert!(a < b, "{:?} !< {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn encoding_property_matches_cmp_total() {
        check(
            "index-order",
            &(|rng: &mut Pcg32| {
                let v = |rng: &mut Pcg32| match rng.next_bounded(3) {
                    0 => Value::Int(rng.next_u64() as i64),
                    1 => Value::F64((rng.next_f64() - 0.5) * 1e6),
                    _ => Value::Int(rng.next_bounded(100) as i64),
                };
                (v(rng), v(rng))
            }),
            |(a, b)| {
                let ord_enc = encode_key(&[a]).cmp(&encode_key(&[b]));
                let ord_val = a.cmp_total(b);
                if ord_enc == ord_val {
                    Ok(())
                } else {
                    Err(format!("{a:?} vs {b:?}: enc {ord_enc:?} val {ord_val:?}"))
                }
            },
        );
    }

    #[test]
    fn type_classes_sort_by_rank() {
        let null = encode_key(&[&Value::Null]);
        let num = encode_key(&[&Value::Int(-999)]);
        let s = encode_key(&[&Value::Str("a".into())]);
        assert!(null < num && num < s);
    }

    fn d(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node)
    }

    #[test]
    fn insert_point_remove() {
        let mut idx = Index::new(IndexSpec::single("node_id"));
        idx.insert(&d(1, 7), 100);
        idx.insert(&d(2, 7), 101);
        idx.insert(&d(3, 8), 102);
        assert_eq!(idx.entries(), 3);
        let mut rids = idx.point(&[&Value::Int(7)]);
        rids.sort_unstable();
        assert_eq!(rids, vec![100, 101]);
        idx.remove(&d(1, 7), 100);
        assert_eq!(idx.point(&[&Value::Int(7)]), vec![101]);
        assert_eq!(idx.entries(), 2);
    }

    #[test]
    fn range_scan_half_open() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        for t in 0..100i64 {
            idx.insert(&d(t, 0), t as u64);
        }
        let mut rids = idx.range(Some(&Value::Int(10)), Some(&Value::Int(20)));
        rids.sort_unstable();
        assert_eq!(rids, (10u64..20).collect::<Vec<_>>());
        // Unbounded sides.
        assert_eq!(idx.range(None, Some(&Value::Int(5))).len(), 5);
        assert_eq!(idx.range(Some(&Value::Int(95)), None).len(), 5);
        assert_eq!(idx.range(None, None).len(), 100);
    }

    #[test]
    fn compound_prefix_scan() {
        let mut idx = Index::new(IndexSpec::compound(&["node_id", "ts"]));
        for node in 0..5i64 {
            for t in 0..10i64 {
                idx.insert(&d(t, node), (node * 10 + t) as u64);
            }
        }
        // Prefix range on node_id ∈ [2, 4).
        let rids = idx.range(Some(&Value::Int(2)), Some(&Value::Int(4)));
        assert_eq!(rids.len(), 20);
        assert!(rids.iter().all(|&r| (20..40).contains(&r)));
        // Point on full compound key.
        let rids = idx.point(&[&Value::Int(3), &Value::Int(7)]);
        assert_eq!(rids, vec![37]);
    }

    #[test]
    fn missing_field_indexes_as_null() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        idx.insert(&Document::new().set("other", 1i64), 1);
        assert_eq!(idx.point(&[&Value::Null]), vec![1]);
    }

    #[test]
    fn duplicate_keys_accumulate() {
        let mut idx = Index::new(IndexSpec::single("ts"));
        for rid in 0..50u64 {
            idx.insert(&d(42, rid as i64), rid);
        }
        assert_eq!(idx.distinct_keys(), 1);
        assert_eq!(idx.point(&[&Value::Int(42)]).len(), 50);
    }
}
