"""Layer-1 Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
and interchange path; real-TPU efficiency is estimated structurally in
DESIGN.md §Perf.

Kernels:

* :mod:`shard_route`  — FNV-1a shard-key hashing + data-parallel chunk
  lookup (the ``mongos`` insertMany partitioning hot spot).
* :mod:`filter_scan`  — columnar conditional-find predicate evaluation
  (timestamp range x node-id bitmap membership).
* :mod:`batch_stats`  — per-column min/max/mean over a metric batch
  (collection statistics maintained at ingest).

:mod:`ref` holds the pure-``jnp`` oracles the pytest suite checks the
kernels against, bit-exactly for the integer kernels.
"""
