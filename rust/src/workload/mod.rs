//! The paper's workload: OVIS node-metric time series, the flat-CSV
//! corpus, user-job metadata, and the ingest / conditional-find drivers.

pub mod csvstore;
pub mod ingest;
pub mod jobs;
pub mod mixed;
pub mod ovis;
pub mod queries;

pub use ingest::{IngestDriver, IngestReport};
pub use jobs::UserJob;
pub use mixed::{MixProfile, MixedDriver, MixedReport, OpMix};
pub use ovis::OvisGenerator;
pub use queries::{QueryDriver, QueryReport};
