//! Typed configuration for the cluster, workload, HPC substrate and DES.
//!
//! Configs load from JSON (see `examples/configs/`), can be overridden by
//! CLI flags, and expose the paper's preset topologies (Table 1 plus the
//! §4 role-assignment rule: an N-node job runs 2 config servers, N/4-1
//! shards, N/4-1 routers, and N/2 client nodes with 4 PEs each).

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};

/// How documents are partitioned across chunks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKeyKind {
    /// FNV-1a hash of (node_id, ts_min) on the hash ring (default; the
    /// route kernel computes this).
    Hashed,
    /// Range partitioning directly on (node_id, ts_min) — exhibits the
    /// hot-chunk pathology for time-ordered ingest (ablation A5).
    Ranged,
}

impl ShardKeyKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "hashed" => Ok(Self::Hashed),
            "ranged" => Ok(Self::Ranged),
            _ => bail!("unknown shard key kind `{s}` (hashed|ranged)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Hashed => "hashed",
            Self::Ranged => "ranged",
        }
    }
}

/// Durability contract a write acknowledgement promises (replica sets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteConcern {
    /// Acknowledge once the primary has durably applied (its own group
    /// commit). Survives a primary restart, not a primary loss.
    One,
    /// Acknowledge once a majority of the replica set has durably
    /// applied the entry — the write survives any minority loss,
    /// including the primary itself (the failover guarantee the crash
    /// harness proves). With `replicas = 1` this degenerates to `One`.
    Majority,
}

impl WriteConcern {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "1" | "one" => Ok(Self::One),
            "majority" => Ok(Self::Majority),
            _ => bail!("unknown write concern `{s}` (1|majority)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::One => "1",
            Self::Majority => "majority",
        }
    }
}

/// Which replica-set member the router targets for reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPreference {
    /// Read from the primary (read-your-writes under `w:majority`).
    Primary,
    /// Prefer secondaries (read scaling); served from pinned MVCC
    /// snapshots, falling back to the primary when no secondary is
    /// reachable. A secondary may lag the primary by uncommitted tail
    /// entries — reads are snapshot-consistent, not linearizable.
    Secondary,
}

impl ReadPreference {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "primary" => Ok(Self::Primary),
            "secondary" => Ok(Self::Secondary),
            _ => bail!("unknown read preference `{s}` (primary|secondary)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Primary => "primary",
            Self::Secondary => "secondary",
        }
    }
}

/// Cluster topology: how job nodes are assigned to roles (paper §3.2/§4).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub total_nodes: u32,
    pub config_servers: u32,
    pub shards: u32,
    pub routers: u32,
    pub client_nodes: u32,
    pub pes_per_client_node: u32,
}

impl Topology {
    /// The paper's role-assignment rule for an N-node job.
    pub fn paper_preset(total_nodes: u32) -> Result<Self> {
        if total_nodes < 8 || total_nodes % 4 != 0 {
            bail!("paper presets need total_nodes >= 8 and divisible by 4, got {total_nodes}");
        }
        let client_nodes = total_nodes / 2;
        let shards = total_nodes / 4 - 1;
        Ok(Self {
            total_nodes,
            config_servers: 2,
            shards,
            routers: shards,
            client_nodes,
            pes_per_client_node: 4,
        })
    }

    /// Small custom topology (tests/examples on one machine).
    pub fn small(shards: u32, routers: u32, client_pes: u32) -> Self {
        Self {
            total_nodes: 2 + shards + routers + client_pes.max(1),
            config_servers: 1,
            shards,
            routers,
            client_nodes: client_pes.max(1),
            pes_per_client_node: 1,
        }
    }

    pub fn client_pes(&self) -> u32 {
        self.client_nodes * self.pes_per_client_node
    }

    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            bail!("topology needs at least one shard");
        }
        if self.routers == 0 {
            bail!("topology needs at least one router");
        }
        if self.config_servers == 0 {
            bail!("topology needs a config server");
        }
        let used = self.config_servers + self.shards + self.routers + self.client_nodes;
        if used > self.total_nodes && self.pes_per_client_node == 4 {
            bail!(
                "role assignment exceeds job size: {used} roles > {} nodes",
                self.total_nodes
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("total_nodes", self.total_nodes)
            .set("config_servers", self.config_servers)
            .set("shards", self.shards)
            .set("routers", self.routers)
            .set("client_nodes", self.client_nodes)
            .set("pes_per_client_node", self.pes_per_client_node);
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            total_nodes: v.require_u64("total_nodes")? as u32,
            config_servers: v.require_u64("config_servers")? as u32,
            shards: v.require_u64("shards")? as u32,
            routers: v.require_u64("routers")? as u32,
            client_nodes: v.require_u64("client_nodes")? as u32,
            pes_per_client_node: v.require_u64("pes_per_client_node")? as u32,
        })
    }
}

/// Store behaviour knobs.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    pub shard_key: ShardKeyKind,
    /// Split a chunk once it holds this many documents.
    pub max_chunk_docs: u64,
    /// Write-ahead journaling on shard servers.
    // lint: knob(no-journal)
    pub journal: bool,
    /// Compress checkpoint blocks (in-tree LZSS codec).
    pub compress_checkpoints: bool,
    /// Storage lifecycle: auto-compact a shard engine (checkpoint +
    /// journal rotation/truncation) once this many journal bytes are
    /// durable since its last checkpoint. 0 disables auto-compaction
    /// (checkpoints only at teardown / on the admin command).
    pub checkpoint_bytes: u64,
    /// Storage lifecycle: target journal segments per checkpoint
    /// interval (segment size = `checkpoint_bytes / journal_segments`).
    pub journal_segments: u32,
    /// Incremental checkpoints: maximum delta generations per chain
    /// before a checkpoint rebases into a fresh full snapshot. 0 makes
    /// every checkpoint a full snapshot (the pre-delta behaviour).
    // lint: knob(checkpoint-chain)
    pub full_checkpoint_chain: u32,
    /// insertMany sub-batch size the client uses.
    // lint: knob(batch-size)
    pub insert_batch: usize,
    /// Router-side ingest buffer: flush to the shards once this many
    /// documents are buffered (buffered-ingest path).
    pub router_flush_docs: usize,
    /// Router-side ingest buffer: flush at this deadline even if the
    /// buffer is below `router_flush_docs` (0 = flush immediately).
    pub flush_interval_ms: u64,
    /// find cursor batch size.
    pub cursor_batch: usize,
    /// Run the chunk balancer.
    // lint: knob(no-balancer)
    pub balancer: bool,
    /// Streaming chunk migration: documents per `MigrateBatch` message.
    /// Bounds the donor shard's per-message stall — ingest and queries
    /// interleave with the stream between batches.
    pub migration_batch_docs: usize,
    /// Byte-aware balancer: also move chunks while the per-shard byte
    /// spread (live docs + on-disk journal/delta bytes) exceeds this
    /// (0 = chunk-count-only planning).
    pub balancer_bytes: u64,
    /// Per-shard reader pool: threads serving finds/counts against MVCC
    /// snapshots while the shard's event loop keeps ingesting. 0 keeps
    /// reads on the event loop (still snapshot-isolated).
    pub reader_threads: usize,
    /// Snapshot retention window, in commits: a snapshot (open cursor)
    /// may lag the writer by at most this many epochs before it expires
    /// with a retryable error and its versions reclaim. 0 = unbounded
    /// (versions are held as long as any snapshot is open).
    pub snapshot_retention: u64,
    /// Aggregation push-down: shards fold `aggregate` matches into
    /// per-group partial accumulator tables and ship those (default).
    /// Off = shards ship every matching document and the router folds
    /// centrally — the full-ship bench baseline.
    pub agg_partial: bool,
    /// Replica-set size per logical shard. 1 = no replication (the
    /// single-member seed behaviour: no oplog, no elections, no ticks).
    /// >1 runs one primary plus `replicas - 1` oplog-tailing
    /// secondaries per shard; requires the balancer off (the oplog does
    /// not carry migration ops).
    pub replicas: u32,
    /// Write concern the routers attach to writes (`1` | `majority`).
    pub write_concern: WriteConcern,
    /// Read preference the routers use (`primary` | `secondary`).
    pub read_preference: ReadPreference,
    /// Router write-retry deadline, ms: how long a router keeps
    /// retrying a write past `StaleVersion` / `MigrationInFlight` /
    /// `NotPrimary` (with jittered exponential backoff) before giving
    /// up.
    pub write_retry_ms: u64,
    /// Election timeout base, ms: a secondary that hears nothing from a
    /// primary for a randomized interval in `[t, 2t)` stands for
    /// election. Must comfortably exceed `heartbeat_ms`.
    pub election_timeout_ms: u64,
    /// Primary heartbeat interval, ms (empty `Replicate` keep-alives).
    pub heartbeat_ms: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            shard_key: ShardKeyKind::Hashed,
            max_chunk_docs: 100_000,
            journal: true,
            compress_checkpoints: false,
            checkpoint_bytes: 64 * 1024 * 1024,
            journal_segments: 4,
            full_checkpoint_chain: 8,
            insert_batch: 1_000,
            router_flush_docs: 4_096,
            flush_interval_ms: 2,
            cursor_batch: 1_000,
            balancer: true,
            migration_batch_docs: 1_024,
            balancer_bytes: 256 * 1024 * 1024,
            reader_threads: 0,
            snapshot_retention: 0,
            agg_partial: true,
            replicas: 1,
            write_concern: WriteConcern::Majority,
            read_preference: ReadPreference::Primary,
            write_retry_ms: 2_000,
            election_timeout_ms: 150,
            heartbeat_ms: 50,
        }
    }
}

impl StoreConfig {
    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("shard_key", self.shard_key.name())
            .set("max_chunk_docs", self.max_chunk_docs)
            .set("journal", self.journal)
            .set("compress_checkpoints", self.compress_checkpoints)
            .set("checkpoint_bytes", self.checkpoint_bytes)
            .set("journal_segments", self.journal_segments)
            .set("full_checkpoint_chain", self.full_checkpoint_chain)
            .set("insert_batch", self.insert_batch)
            .set("router_flush_docs", self.router_flush_docs)
            .set("flush_interval_ms", self.flush_interval_ms)
            .set("cursor_batch", self.cursor_batch)
            .set("balancer", self.balancer)
            .set("migration_batch_docs", self.migration_batch_docs)
            .set("balancer_bytes", self.balancer_bytes)
            .set("reader_threads", self.reader_threads)
            .set("snapshot_retention", self.snapshot_retention)
            .set("agg_partial", self.agg_partial)
            .set("replicas", self.replicas)
            .set("write_concern", self.write_concern.name())
            .set("read_preference", self.read_preference.name())
            .set("write_retry_ms", self.write_retry_ms)
            .set("election_timeout_ms", self.election_timeout_ms)
            .set("heartbeat_ms", self.heartbeat_ms);
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            shard_key: match v.get("shard_key").and_then(Value::as_str) {
                Some(s) => ShardKeyKind::parse(s)?,
                None => d.shard_key,
            },
            max_chunk_docs: v
                .get("max_chunk_docs")
                .and_then(Value::as_u64)
                .unwrap_or(d.max_chunk_docs),
            journal: v.get("journal").and_then(Value::as_bool).unwrap_or(d.journal),
            compress_checkpoints: v
                .get("compress_checkpoints")
                .and_then(Value::as_bool)
                .unwrap_or(d.compress_checkpoints),
            checkpoint_bytes: v
                .get("checkpoint_bytes")
                .and_then(Value::as_u64)
                .unwrap_or(d.checkpoint_bytes),
            journal_segments: v
                .get("journal_segments")
                .and_then(Value::as_u64)
                .unwrap_or(d.journal_segments as u64) as u32,
            full_checkpoint_chain: v
                .get("full_checkpoint_chain")
                .and_then(Value::as_u64)
                .unwrap_or(d.full_checkpoint_chain as u64) as u32,
            insert_batch: v
                .get("insert_batch")
                .and_then(Value::as_usize)
                .unwrap_or(d.insert_batch),
            router_flush_docs: v
                .get("router_flush_docs")
                .and_then(Value::as_usize)
                .unwrap_or(d.router_flush_docs),
            flush_interval_ms: v
                .get("flush_interval_ms")
                .and_then(Value::as_u64)
                .unwrap_or(d.flush_interval_ms),
            cursor_batch: v
                .get("cursor_batch")
                .and_then(Value::as_usize)
                .unwrap_or(d.cursor_batch),
            balancer: v.get("balancer").and_then(Value::as_bool).unwrap_or(d.balancer),
            migration_batch_docs: v
                .get("migration_batch_docs")
                .and_then(Value::as_usize)
                .unwrap_or(d.migration_batch_docs),
            balancer_bytes: v
                .get("balancer_bytes")
                .and_then(Value::as_u64)
                .unwrap_or(d.balancer_bytes),
            reader_threads: v
                .get("reader_threads")
                .and_then(Value::as_usize)
                .unwrap_or(d.reader_threads),
            snapshot_retention: v
                .get("snapshot_retention")
                .and_then(Value::as_u64)
                .unwrap_or(d.snapshot_retention),
            agg_partial: v
                .get("agg_partial")
                .and_then(Value::as_bool)
                .unwrap_or(d.agg_partial),
            replicas: v
                .get("replicas")
                .and_then(Value::as_u64)
                .unwrap_or(d.replicas as u64) as u32,
            write_concern: match v.get("write_concern").and_then(Value::as_str) {
                Some(s) => WriteConcern::parse(s)?,
                None => d.write_concern,
            },
            read_preference: match v.get("read_preference").and_then(Value::as_str) {
                Some(s) => ReadPreference::parse(s)?,
                None => d.read_preference,
            },
            write_retry_ms: v
                .get("write_retry_ms")
                .and_then(Value::as_u64)
                .unwrap_or(d.write_retry_ms),
            election_timeout_ms: v
                .get("election_timeout_ms")
                .and_then(Value::as_u64)
                .unwrap_or(d.election_timeout_ms),
            heartbeat_ms: v
                .get("heartbeat_ms")
                .and_then(Value::as_u64)
                .unwrap_or(d.heartbeat_ms),
        })
    }
}

/// OVIS-style corpus parameters (paper §4).
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Number of monitored compute nodes emitting metrics (Blue Waters:
    /// ~27k; scaled default for a single machine).
    pub monitored_nodes: u32,
    /// Distinct metrics per sample document (paper: ~75).
    pub metrics_per_doc: u32,
    /// Days of data to ingest (Table 1).
    pub days: f64,
    /// Epoch-minute at which the corpus starts (2018-01-01 00:00 UTC).
    pub start_epoch_min: u32,
    /// RNG seed for corpus synthesis.
    pub seed: u64,
    /// Number of synthetic user jobs used to build the query workload.
    pub query_jobs: u32,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            monitored_nodes: 256,
            metrics_per_doc: 75,
            days: 0.05, // ~72 minutes — quick live runs
            start_epoch_min: 25_246_080, // 2018-01-01T00:00Z in epoch minutes
            seed: 0x0515_CA5E,
            query_jobs: 32,
        }
    }
}

impl WorkloadConfig {
    /// Total documents this workload ingests.
    pub fn total_docs(&self) -> u64 {
        let minutes = (self.days * 1440.0).round() as u64;
        minutes * self.monitored_nodes as u64
    }

    pub fn minutes(&self) -> u32 {
        (self.days * 1440.0).round() as u32
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("monitored_nodes", self.monitored_nodes)
            .set("metrics_per_doc", self.metrics_per_doc)
            .set("days", self.days)
            .set("start_epoch_min", self.start_epoch_min)
            .set("seed", self.seed)
            .set("query_jobs", self.query_jobs);
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            monitored_nodes: v
                .get("monitored_nodes")
                .and_then(Value::as_u64)
                .unwrap_or(d.monitored_nodes as u64) as u32,
            metrics_per_doc: v
                .get("metrics_per_doc")
                .and_then(Value::as_u64)
                .unwrap_or(d.metrics_per_doc as u64) as u32,
            days: v.get("days").and_then(Value::as_f64).unwrap_or(d.days),
            start_epoch_min: v
                .get("start_epoch_min")
                .and_then(Value::as_u64)
                .unwrap_or(d.start_epoch_min as u64) as u32,
            seed: v.get("seed").and_then(Value::as_u64).unwrap_or(d.seed),
            query_jobs: v
                .get("query_jobs")
                .and_then(Value::as_u64)
                .unwrap_or(d.query_jobs as u64) as u32,
        })
    }
}

/// Lustre substrate parameters.
#[derive(Clone, Debug)]
pub struct LustreConfig {
    pub osts: u32,
    pub default_stripe_count: u32,
    pub stripe_size_kib: u32,
    /// Modeled per-OST streaming bandwidth (DES; Sonexion-class OSTs).
    pub ost_bandwidth_mib_s: f64,
    /// Live mode: host directory backing the simulated filesystem.
    pub backing_dir: String,
}

impl Default for LustreConfig {
    fn default() -> Self {
        Self {
            osts: 8,
            default_stripe_count: 2,
            stripe_size_kib: 1024,
            ost_bandwidth_mib_s: 500.0,
            backing_dir: String::new(), // empty → temp dir
        }
    }
}

impl LustreConfig {
    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        v.set("osts", self.osts)
            .set("default_stripe_count", self.default_stripe_count)
            .set("stripe_size_kib", self.stripe_size_kib)
            .set("ost_bandwidth_mib_s", self.ost_bandwidth_mib_s)
            .set("backing_dir", self.backing_dir.as_str());
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            osts: v.get("osts").and_then(Value::as_u64).unwrap_or(d.osts as u64) as u32,
            default_stripe_count: v
                .get("default_stripe_count")
                .and_then(Value::as_u64)
                .unwrap_or(d.default_stripe_count as u64) as u32,
            stripe_size_kib: v
                .get("stripe_size_kib")
                .and_then(Value::as_u64)
                .unwrap_or(d.stripe_size_kib as u64) as u32,
            ost_bandwidth_mib_s: v
                .get("ost_bandwidth_mib_s")
                .and_then(Value::as_f64)
                .unwrap_or(d.ost_bandwidth_mib_s),
            backing_dir: v
                .get("backing_dir")
                .and_then(Value::as_str)
                .unwrap_or("")
                .to_string(),
        })
    }
}

/// Top-level configuration bundle.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub topology: Option<Topology>,
    pub store: StoreConfig,
    pub workload: WorkloadConfig,
    pub lustre: LustreConfig,
    /// Directory holding AOT artifacts.
    pub artifact_dir: String,
}

impl Config {
    pub fn new() -> Self {
        Self {
            artifact_dir: "artifacts".to_string(),
            ..Default::default()
        }
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let v = json::from_file(path)?;
        Self::from_json(&v).with_context(|| format!("in config {}", path.display()))
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            topology: match v.get("topology") {
                Some(t) => Some(Topology::from_json(t)?),
                None => None,
            },
            store: match v.get("store") {
                Some(s) => StoreConfig::from_json(s)?,
                None => StoreConfig::default(),
            },
            workload: match v.get("workload") {
                Some(w) => WorkloadConfig::from_json(w)?,
                None => WorkloadConfig::default(),
            },
            lustre: match v.get("lustre") {
                Some(l) => LustreConfig::from_json(l)?,
                None => LustreConfig::default(),
            },
            artifact_dir: v
                .get("artifact_dir")
                .and_then(Value::as_str)
                .unwrap_or("artifacts")
                .to_string(),
        })
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::object();
        if let Some(t) = &self.topology {
            v.set("topology", t.to_json());
        }
        v.set("store", self.store.to_json())
            .set("workload", self.workload.to_json())
            .set("lustre", self.lustre.to_json())
            .set("artifact_dir", self.artifact_dir.as_str());
        v
    }
}

/// The paper's Table 1: cluster size → days of ingested data.
pub const TABLE1: [(u32, f64); 4] = [(32, 3.0), (64, 7.0), (128, 14.0), (256, 14.0)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_presets_match_section4() {
        // "a job of 32 nodes ... 2 config, 7 shards, 7 routers, 16 ingest"
        let t = Topology::paper_preset(32).unwrap();
        assert_eq!(
            t,
            Topology {
                total_nodes: 32,
                config_servers: 2,
                shards: 7,
                routers: 7,
                client_nodes: 16,
                pes_per_client_node: 4
            }
        );
        assert_eq!(t.client_pes(), 64); // "64 insertMany concurrently"
        // "A job of 64 nodes would have 2 for configuration, 15 shards, 15
        // router servers and so on."
        let t = Topology::paper_preset(64).unwrap();
        assert_eq!((t.shards, t.routers, t.client_nodes), (15, 15, 32));
        let t = Topology::paper_preset(128).unwrap();
        assert_eq!((t.shards, t.routers, t.client_nodes), (31, 31, 64));
        let t = Topology::paper_preset(256).unwrap();
        assert_eq!((t.shards, t.routers, t.client_nodes), (63, 63, 128));
    }

    #[test]
    fn preset_rejects_bad_sizes() {
        assert!(Topology::paper_preset(6).is_err());
        assert!(Topology::paper_preset(33).is_err());
    }

    #[test]
    fn topology_validation() {
        let mut t = Topology::paper_preset(32).unwrap();
        t.validate().unwrap();
        t.shards = 0;
        assert!(t.validate().is_err());
    }

    #[test]
    fn topology_json_round_trip() {
        let t = Topology::paper_preset(64).unwrap();
        assert_eq!(Topology::from_json(&t.to_json()).unwrap(), t);
    }

    #[test]
    fn workload_doc_count() {
        let w = WorkloadConfig {
            monitored_nodes: 100,
            days: 1.0,
            ..Default::default()
        };
        assert_eq!(w.total_docs(), 144_000);
    }

    #[test]
    fn config_round_trip_defaults() {
        let c = Config::new();
        let v = c.to_json();
        let c2 = Config::from_json(&v).unwrap();
        assert_eq!(c2.store.insert_batch, c.store.insert_batch);
        assert_eq!(c2.store.router_flush_docs, c.store.router_flush_docs);
        assert_eq!(c2.store.flush_interval_ms, c.store.flush_interval_ms);
        assert_eq!(c2.store.checkpoint_bytes, c.store.checkpoint_bytes);
        assert_eq!(c2.store.journal_segments, c.store.journal_segments);
        assert_eq!(c2.store.full_checkpoint_chain, c.store.full_checkpoint_chain);
        assert_eq!(c2.store.migration_batch_docs, c.store.migration_batch_docs);
        assert_eq!(c2.store.balancer_bytes, c.store.balancer_bytes);
        assert_eq!(c2.store.reader_threads, c.store.reader_threads);
        assert_eq!(c2.store.snapshot_retention, c.store.snapshot_retention);
        assert_eq!(c2.store.agg_partial, c.store.agg_partial);
        assert_eq!(c2.store.replicas, c.store.replicas);
        assert_eq!(c2.store.write_concern, c.store.write_concern);
        assert_eq!(c2.store.read_preference, c.store.read_preference);
        assert_eq!(c2.store.write_retry_ms, c.store.write_retry_ms);
        assert_eq!(c2.store.election_timeout_ms, c.store.election_timeout_ms);
        assert_eq!(c2.store.heartbeat_ms, c.store.heartbeat_ms);
        assert_eq!(c2.workload.monitored_nodes, c.workload.monitored_nodes);
        assert_eq!(c2.lustre.osts, c.lustre.osts);
    }

    #[test]
    fn shard_key_parse() {
        assert_eq!(ShardKeyKind::parse("hashed").unwrap(), ShardKeyKind::Hashed);
        assert_eq!(ShardKeyKind::parse("ranged").unwrap(), ShardKeyKind::Ranged);
        assert!(ShardKeyKind::parse("zoned").is_err());
    }

    #[test]
    fn write_concern_and_read_preference_parse() {
        assert_eq!(WriteConcern::parse("1").unwrap(), WriteConcern::One);
        assert_eq!(WriteConcern::parse("one").unwrap(), WriteConcern::One);
        assert_eq!(WriteConcern::parse("majority").unwrap(), WriteConcern::Majority);
        assert!(WriteConcern::parse("all").is_err());
        assert_eq!(ReadPreference::parse("primary").unwrap(), ReadPreference::Primary);
        assert_eq!(ReadPreference::parse("secondary").unwrap(), ReadPreference::Secondary);
        assert!(ReadPreference::parse("nearest").is_err());
    }

    #[test]
    fn table1_matches_paper() {
        assert_eq!(TABLE1[0], (32, 3.0));
        assert_eq!(TABLE1[3], (256, 14.0));
    }
}
