//! MVCC snapshot-consistency battery — the concurrent-runtime seal.
//!
//! Two randomized differential harnesses (ARCHITECTURE.md §9.4), both
//! seed-swept like `crash_fuzz`:
//!
//! * **Engine level** — reader threads pin snapshots and answer
//!   queries while the writer ingests, removes, checkpoints, and
//!   reclaims. Every concurrent answer is re-run *quiesced* through
//!   the same pinned snapshot after the writer joins; the two answers
//!   must be identical (IS1: with retention 0 an open snapshot's
//!   versions survive any amount of writer churn).
//! * **Reader-pool level** — the real `ReadContext`/`ReaderPool`
//!   dispatch path serves canonical finds/counts while the writer
//!   commits; results must be exact for the pinned epoch (bounded by
//!   the commit counter at submit/reply time), duplicate-free, and a
//!   cursor drained long after its `find` must stay frozen at its
//!   snapshot instead of chasing the growing table.
//! * **Aggregation level** — `ReadRequest::Aggregate` (both the
//!   partial-accumulator push-down and the full-ship baseline) runs
//!   against a corpus whose every document is atomically rewritten
//!   (`rev` bumped, key fields preserved) wave after wave; every reply
//!   must come from exactly one epoch — static per-group count and
//!   ts-checksum, and one single `rev` across the whole result.
//!
//! Knobs (documented in docs/EXPERIMENTS.md §6): `SNAPSHOT_FUZZ_SEEDS`
//! is either a count ("32" sweeps seeds 0..32) or a comma list
//! ("7,19" replays those seeds). Default: 8 seeds (CI crash job: 16).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use hpcstore::metrics::{names, Registry};
use hpcstore::mongo::bson::{Document, Value};
use hpcstore::mongo::query::{CmpOp, Filter, FindOptions};
use hpcstore::mongo::server::{ReadContext, ReadRequest, ReaderPool};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::{
    Engine, EngineOptions, LocalDir, RecordId, ReadView, Snapshot, StoreReader,
};
use hpcstore::mongo::wire::{CountReply, WireError};
use hpcstore::runtime::Kernels;
use hpcstore::util::rng::Pcg32;

type CountRx = mpsc::Receiver<Result<CountReply, WireError>>;

fn seeds() -> Vec<u64> {
    match std::env::var("SNAPSHOT_FUZZ_SEEDS") {
        Ok(s) if s.contains(',') => s
            .split(',')
            .map(|t| t.trim().parse().expect("SNAPSHOT_FUZZ_SEEDS: bad seed"))
            .collect(),
        Ok(s) => {
            let n: u64 = s.trim().parse().expect("SNAPSHOT_FUZZ_SEEDS: bad count");
            (0..n).collect()
        }
        Err(_) => (0..8).collect(),
    }
}

fn doc(ts: i64, node: i64) -> Document {
    Document::new().set("ts", ts).set("node_id", node).set("m0", ts as f64 * 0.5)
}

fn open_engine(tag: &str) -> Engine {
    let dir = LocalDir::temp(tag).unwrap();
    let mut eng = Engine::open_with(
        Box::new(dir),
        EngineOptions { journal: true, ..EngineOptions::default() },
    )
    .unwrap();
    eng.create_collection("metrics");
    eng.create_index("metrics", IndexSpec::compound(&["node_id", "ts"])).unwrap();
    eng.create_index("metrics", IndexSpec::single("ts")).unwrap();
    eng
}

/// Scan-and-filter at one view: (match count, ts checksum). Decodes
/// every record so the answer is independent of any index state — the
/// oracle side of the differential.
fn scan_query(view: &ReadView<'_>, node: i64, lo: i64, hi: i64) -> (u64, i64) {
    let mut count = 0u64;
    let mut sum = 0i64;
    for (_rid, bytes) in view.scan_raw_from("metrics", None) {
        let d = Document::decode(bytes).expect("engine stores encoder output");
        let ts = d.get("ts").and_then(Value::as_i64).unwrap();
        let n = d.get("node_id").and_then(Value::as_i64).unwrap();
        if n == node && ts >= lo && ts < hi {
            count += 1;
            sum += ts;
        }
    }
    (count, sum)
}

/// One recorded concurrent read: the pinned snapshot, the query
/// parameters, and the answer computed live.
struct Recorded {
    snap: Snapshot,
    node: i64,
    lo: i64,
    hi: i64,
    answer: (u64, i64),
}

fn reader_thread(
    reader: StoreReader,
    stop: Arc<AtomicBool>,
    seed: u64,
    stream: u64,
) -> Vec<Recorded> {
    let mut rng = Pcg32::new(seed ^ 0x9e37_79b9_7f4a_7c15, stream);
    let mut out = Vec::new();
    let mut queries = 0u32;
    // Guarantee coverage even if the writer finishes first: every
    // reader answers at least 16 queries before honoring `stop`.
    while queries < 16 || !stop.load(Ordering::Relaxed) {
        let snap = reader.snapshot();
        let view = reader
            .view(&snap)
            .expect("retention 0: a just-pinned snapshot cannot be expired");
        let node = rng.next_bounded(8) as i64;
        let lo = rng.next_bounded(4_000) as i64;
        let hi = lo + 1 + rng.next_bounded(4_000) as i64;
        let answer = scan_query(&view, node, lo, hi);
        // Two passes over one view must agree — a torn iterator here
        // would mean the view observes concurrent mutation.
        assert_eq!(
            scan_query(&view, node, lo, hi),
            answer,
            "seed {seed}: two scans of one snapshot view disagree"
        );
        drop(view);
        queries += 1;
        if out.len() < 48 {
            out.push(Recorded { snap, node, lo, hi, answer });
        }
        if queries >= 4096 {
            break; // runaway guard if the writer stalls
        }
    }
    out
}

/// Engine-level battery for one seed: concurrent answers must equal a
/// quiesced re-run through the same pinned snapshot.
fn engine_battery(seed: u64) {
    let mut eng = open_engine(&format!("snapfuzz-{seed}"));
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..3)
        .map(|r| {
            let reader = eng.reader();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || reader_thread(reader, stop, seed, r + 1))
        })
        .collect();

    // Writer: deterministic op stream. Unique, monotone timestamps so
    // every document is distinguishable in checksums. `live` remembers
    // each record's (rid, ts, node) so updates can rebuild the exact
    // key fields of the document they overwrite.
    let mut rng = Pcg32::seeded(seed);
    let mut next_ts = 0i64;
    let mut live: Vec<(RecordId, i64, i64)> = Vec::new();
    for _step in 0..150 {
        match rng.next_bounded(12) {
            0..=6 => {
                let n = 1 + rng.next_bounded(24) as usize;
                let mut meta = Vec::with_capacity(n);
                let batch: Vec<Document> = (0..n)
                    .map(|_| {
                        let node = rng.next_bounded(8) as i64;
                        let d = doc(next_ts, node);
                        meta.push((next_ts, node));
                        next_ts += 1;
                        d
                    })
                    .collect();
                let rids = eng.insert_many("metrics", &batch).unwrap();
                live.extend(
                    rids.into_iter().zip(meta).map(|(r, (ts, node))| (r, ts, node)),
                );
            }
            7 | 8 => {
                for _ in 0..rng.next_bounded(8) {
                    if live.is_empty() {
                        break;
                    }
                    let i = rng.next_bounded(live.len() as u32) as usize;
                    let (rid, _, _) = live.swap_remove(i);
                    eng.remove("metrics", rid).unwrap();
                }
            }
            9 | 10 => {
                // Overwrite a few live documents (same ts/node_id, new
                // payload): the engine kills the old rid and inserts the
                // replacement at one epoch. A snapshot pinned on either
                // side must serve each updated document exactly once —
                // never zero (lost to the kill) nor twice (old version
                // plus its replacement) — which the count/checksum
                // differential below would catch.
                let mut picked = std::collections::HashSet::new();
                let mut targets = Vec::new();
                for _ in 0..rng.next_bounded(6) {
                    if live.is_empty() {
                        break;
                    }
                    let i = rng.next_bounded(live.len() as u32) as usize;
                    if picked.insert(i) {
                        targets.push(i);
                    }
                }
                if !targets.is_empty() {
                    let updates: Vec<(RecordId, Document)> = targets
                        .iter()
                        .map(|&i| {
                            let (rid, ts, node) = live[i];
                            (rid, doc(ts, node).set("rev", next_ts))
                        })
                        .collect();
                    let new_rids = eng.update_many("metrics", &updates).unwrap();
                    for (&i, &new_rid) in targets.iter().zip(&new_rids) {
                        live[i].0 = new_rid;
                    }
                }
            }
            _ => {
                eng.checkpoint().unwrap();
            }
        }
        eng.sync().unwrap();
        eng.reclaim();
    }
    stop.store(true, Ordering::Relaxed);
    let recorded: Vec<Recorded> =
        handles.into_iter().flat_map(|h| h.join().expect("reader thread")).collect();
    assert!(!recorded.is_empty(), "seed {seed}: no concurrent reads recorded");

    // Quiesced: the writer is done; every recorded snapshot is still
    // pinned, so its epoch's versions must all have survived reclaim.
    eng.reclaim();
    let reader = eng.reader();
    for r in &recorded {
        let view = reader
            .view(&r.snap)
            .expect("retention 0: pinned snapshots never expire");
        assert_eq!(view.at(), r.snap.at());
        assert_eq!(
            scan_query(&view, r.node, r.lo, r.hi),
            r.answer,
            "seed {seed}: quiesced re-run at epoch {} disagrees with the concurrent read",
            r.snap.at(),
        );
    }

    // Dropping the pins must let reclamation drain everything.
    drop(recorded);
    eng.reclaim();
    assert_eq!(eng.snapshots_open(), 0, "seed {seed}: leaked snapshot pins");
    assert_eq!(eng.garbage_len(), 0, "seed {seed}: unpinned garbage not reclaimed");
}

fn canonical_filter(nodes: &[i64], lo: i64, hi: i64) -> Filter {
    Filter::And(vec![
        Filter::is_in("node_id", nodes.iter().map(|&n| Value::Int(n)).collect()),
        Filter::Cmp { field: "ts".into(), op: CmpOp::Gte, value: Value::Int(lo) },
        Filter::Cmp { field: "ts".into(), op: CmpOp::Lt, value: Value::Int(hi) },
    ])
}

/// Reader-pool battery for one seed: the real dispatch path under live
/// ingest. Insert-only, so per-filter counts are monotone in the epoch
/// and every reply can be sandwiched between the commit counter at
/// submit and at receive.
fn pool_battery(seed: u64) {
    let mut eng = open_engine(&format!("snappool-{seed}"));
    let metrics = Registry::new();
    let ctx = Arc::new(ReadContext::new(
        eng.reader(),
        Kernels::fallback(),
        metrics.clone(),
        64,
    ));
    let pool = ReaderPool::start(Arc::clone(&ctx), 3, "snapfuzz");
    let committed = Arc::new(AtomicU64::new(0));

    let mut rng = Pcg32::seeded(seed ^ 0x5eed);
    let mut next_ts = 0i64;
    // In-flight counts: (reply receiver, lower bound at submit).
    let mut counts: Vec<(CountRx, u64)> = Vec::new();
    // One cursor opened early and drained only after the corpus has
    // grown far past its snapshot.
    let mut frozen: Option<(u64, usize, u64)> = None; // (cursor, first batch len, hi bound)
    let all_nodes: Vec<i64> = (0..8).collect();

    for step in 0..120 {
        let n = 1 + rng.next_bounded(24) as usize;
        let batch: Vec<Document> = (0..n)
            .map(|_| {
                let d = doc(next_ts, rng.next_bounded(8) as i64);
                next_ts += 1;
                d
            })
            .collect();
        eng.insert_many("metrics", &batch).unwrap();
        eng.sync().unwrap();
        eng.reclaim();
        committed.store(next_ts as u64, Ordering::SeqCst);

        if step % 5 == 0 {
            // Count over the whole corpus: the reply must equal the
            // corpus size at some epoch between submit and receive.
            let (tx, rx) = mpsc::channel();
            let lo_bound = committed.load(Ordering::SeqCst);
            pool.submit(ReadRequest::Count {
                filter: canonical_filter(&all_nodes, 0, i64::MAX),
                reply: tx,
            });
            counts.push((rx, lo_bound));
        }
        if step == 20 {
            // Open the frozen cursor: small first batch, then let the
            // writer run far ahead before draining.
            let (tx, rx) = mpsc::channel();
            let lo_bound = committed.load(Ordering::SeqCst);
            pool.submit(ReadRequest::Find {
                filter: canonical_filter(&all_nodes, 0, i64::MAX),
                opts: FindOptions::default().batch_size(8),
                reply: tx,
            });
            let reply = rx.recv().expect("pool dropped a find reply").expect("find failed");
            let hi_bound = committed.load(Ordering::SeqCst);
            assert!(lo_bound >= 8, "corpus too small for the frozen-cursor check");
            let cursor = reply.cursor.expect("batch 8 over >8 docs must leave a cursor");
            assert_eq!(reply.docs.len(), 8);
            frozen = Some((cursor, reply.docs.len(), hi_bound));
        }
    }

    // Collect the in-flight counts: each executed at one epoch between
    // its submit bound and now, and the corpus only ever grew.
    let final_count = committed.load(Ordering::SeqCst);
    for (rx, lo_bound) in counts {
        let got = rx.recv().expect("pool dropped a count reply").expect("count failed").n;
        assert!(
            got >= lo_bound && got <= final_count,
            "seed {seed}: count {got} outside its epoch window [{lo_bound}, {final_count}]"
        );
    }

    // Drain the frozen cursor: the writer has long since moved on, but
    // the pinned snapshot must keep the result set at its epoch — no
    // new documents (count ≤ hi bound), no duplicates, no losses
    // (count ≥ lo bound implied by ts uniqueness + bound below).
    let (cursor, first_len, hi_bound) = frozen.expect("step 20 always runs");
    let mut seen = std::collections::HashSet::new();
    let mut total = first_len as u64;
    let mut cur = Some(cursor);
    while let Some(c) = cur {
        let (tx, rx) = mpsc::channel();
        pool.submit(ReadRequest::GetMore { cursor: c, reply: tx });
        let reply = rx.recv().expect("pool dropped a getMore reply").expect("getMore failed");
        for d in &reply.docs {
            let ts = d.get("ts").and_then(Value::as_i64).unwrap();
            assert!(seen.insert(ts), "seed {seed}: document ts={ts} served twice");
        }
        total += reply.docs.len() as u64;
        cur = reply.cursor;
    }
    assert!(
        total <= hi_bound,
        "seed {seed}: cursor returned {total} docs but only {hi_bound} existed when it \
         pinned its snapshot — the drain chased the live table"
    );
    assert!(
        total >= 8,
        "seed {seed}: frozen cursor lost documents (drained {total})"
    );
    assert_eq!(ctx.open_cursors(), 0, "seed {seed}: drained cursor not closed");

    assert!(
        metrics.counter(names::SHARD_SNAPSHOT_READS).get() > 0,
        "seed {seed}: pool reads did not count as snapshot reads"
    );
    pool.shutdown();
    eng.reclaim();
    assert_eq!(eng.snapshots_open(), 0, "seed {seed}: pool leaked snapshot pins");
}

/// Aggregation battery for one seed: `ReadRequest::Aggregate` replies
/// under churn must each reflect exactly one snapshot. The corpus is
/// fixed (512 docs, 8 node groups, unique ts) and the writer rewrites
/// *every* document in one batch-atomic `update_many` per wave (same
/// ts/node_id, `rev` bumped to the wave number). Group structure is
/// therefore an invariant — per-group count and ts checksum never
/// change — while `rev` is a perfect epoch dye: a reply mixing two
/// epochs would show two different `rev` values, and min(rev) ==
/// max(rev) across the whole result proves snapshot uniformity.
fn aggregation_battery(seed: u64) {
    use hpcstore::mongo::aggregate::{AggPipeline, PartialTable};
    use hpcstore::mongo::wire::AggregateReply;

    type AggRx = mpsc::Receiver<Result<AggregateReply, WireError>>;

    let mut eng = open_engine(&format!("snapagg-{seed}"));
    let metrics = Registry::new();
    let ctx = Arc::new(ReadContext::new(
        eng.reader(),
        Kernels::fallback(),
        metrics.clone(),
        64,
    ));
    let pool = ReaderPool::start(Arc::clone(&ctx), 3, "snapagg");

    let groups = 8i64;
    let per_group = 64i64;
    let corpus: Vec<Document> = (0..groups * per_group)
        .map(|i| doc(i, i % groups).set("rev", 0i64))
        .collect();
    let mut rids = eng.insert_many("metrics", &corpus).unwrap();
    eng.sync().unwrap();

    let pipeline = AggPipeline::new()
        .group_by("node_id")
        .count("n")
        .sum("ts_sum", "ts")
        .min("rlo", "rev")
        .max("rhi", "rev");
    // Node n owns ts ∈ {n, n+8, …}: 64 terms of an arithmetic series.
    let ts_sum = |n: i64| (per_group * n + groups * (per_group * (per_group - 1) / 2)) as f64;

    let mut rng = Pcg32::seeded(seed ^ 0xa66);
    let waves = 40 + rng.next_bounded(40) as i64;
    let mut inflight: Vec<(AggRx, bool)> = Vec::new();
    for wave in 1..=waves {
        // Every wave rewrites the whole corpus at one epoch.
        let updates: Vec<(RecordId, Document)> = rids
            .iter()
            .enumerate()
            .map(|(i, &rid)| {
                let i = i as i64;
                (rid, doc(i, i % groups).set("rev", wave))
            })
            .collect();
        rids = eng.update_many("metrics", &updates).unwrap();
        eng.sync().unwrap();
        eng.reclaim();

        let partial = rng.next_bounded(2) == 0;
        let (tx, rx) = mpsc::channel();
        pool.submit(ReadRequest::Aggregate {
            pipeline: pipeline.clone(),
            partial,
            reply: tx,
        });
        inflight.push((rx, partial));
    }

    for (rx, partial) in inflight {
        let rep = rx
            .recv()
            .expect("pool dropped an aggregate reply")
            .expect("aggregate failed");
        // Merge exactly as the router does for a one-shard scatter.
        let rows = if partial {
            assert!(rep.docs.is_empty(), "seed {seed}: push-down shipped documents");
            let mut table = PartialTable::new();
            table.merge_rows(&pipeline, rep.rows);
            pipeline.finalize(table)
        } else {
            assert!(rep.rows.is_empty(), "seed {seed}: full ship sent partial rows");
            pipeline.execute_docs(&rep.docs)
        };
        assert_eq!(rows.len(), groups as usize, "seed {seed}: group structure broke");
        let mut revs = std::collections::HashSet::new();
        for row in &rows {
            let node = row.get_i64("_id").unwrap();
            assert_eq!(row.get_i64("n"), Some(per_group), "seed {seed}: node {node}");
            assert_eq!(
                row.get_f64("ts_sum"),
                Some(ts_sum(node)),
                "seed {seed}: node {node} ts checksum moved — mixed-epoch read"
            );
            let (rlo, rhi) = (row.get_i64("rlo").unwrap(), row.get_i64("rhi").unwrap());
            assert_eq!(
                rlo, rhi,
                "seed {seed}: node {node} saw two revs in one group — torn snapshot"
            );
            revs.insert(rlo);
        }
        // One epoch across the *whole* scatter leg, not just per group.
        assert_eq!(
            revs.len(),
            1,
            "seed {seed}: one reply mixed epochs across groups: {revs:?}"
        );
        let rev = *revs.iter().next().unwrap();
        assert!((0..=waves).contains(&rev), "seed {seed}: impossible rev {rev}");
    }

    pool.shutdown();
    eng.reclaim();
    assert_eq!(eng.snapshots_open(), 0, "seed {seed}: aggregate leaked snapshot pins");
}

/// Overwrite visibility, pinned explicitly: a snapshot opened *before*
/// an update batch serves only pre-update versions — all of them,
/// exactly once — while a snapshot opened after serves only the
/// replacements.
#[test]
fn pinned_snapshot_reads_only_pre_update_versions() {
    let mut eng = open_engine("snapupd");
    let docs: Vec<Document> = (0..32i64).map(|i| doc(i, i % 4)).collect();
    let rids = eng.insert_many("metrics", &docs).unwrap();
    eng.sync().unwrap();
    let reader = eng.reader();
    let snap = reader.snapshot();

    // Overwrite every document after the pin, then reclaim: the pin
    // must hold the dead pre-update versions back.
    let updates: Vec<(RecordId, Document)> = rids
        .iter()
        .enumerate()
        .map(|(i, &rid)| (rid, doc(i as i64, (i as i64) % 4).set("rev", 1i64)))
        .collect();
    eng.update_many("metrics", &updates).unwrap();
    eng.sync().unwrap();
    eng.reclaim();

    let view = reader.view(&snap).expect("pinned snapshot survives reclaim");
    let mut pre = 0u64;
    for (_rid, bytes) in view.scan_raw_from("metrics", None) {
        let d = Document::decode(bytes).unwrap();
        assert!(d.get("rev").is_none(), "pinned view leaked a post-update version");
        pre += 1;
    }
    assert_eq!(pre, 32, "pinned view must serve every pre-update version exactly once");
    drop(view);

    let snap2 = reader.snapshot();
    let view2 = reader.view(&snap2).unwrap();
    let mut post = 0u64;
    for (_rid, bytes) in view2.scan_raw_from("metrics", None) {
        let d = Document::decode(bytes).unwrap();
        assert_eq!(d.get_i64("rev"), Some(1), "fresh view must serve the replacement");
        post += 1;
    }
    assert_eq!(post, 32);
    drop(view2);

    drop(snap);
    drop(snap2);
    eng.reclaim();
    assert_eq!(eng.garbage_len(), 0, "unpinning must release the overwritten versions");
}

#[test]
fn concurrent_reads_match_quiesced_rerun_at_pinned_epoch() {
    let seeds = seeds();
    assert!(!seeds.is_empty(), "SNAPSHOT_FUZZ_SEEDS selected no seeds");
    for seed in seeds {
        engine_battery(seed);
    }
}

#[test]
fn reader_pool_serves_exact_frozen_results_under_live_ingest() {
    let seeds = seeds();
    assert!(!seeds.is_empty(), "SNAPSHOT_FUZZ_SEEDS selected no seeds");
    for seed in seeds {
        pool_battery(seed);
    }
}

#[test]
fn aggregation_replies_are_snapshot_uniform_under_churn() {
    let seeds = seeds();
    assert!(!seeds.is_empty(), "SNAPSHOT_FUZZ_SEEDS selected no seeds");
    for seed in seeds {
        aggregation_battery(seed);
    }
}
