//! MVCC primitives: epoch stamps, the visibility rule, and the open-
//! snapshot tracker.
//!
//! The engine stamps every record and index posting with the epoch it
//! was **born** at and the epoch it **died** at (`LIVE` = still alive).
//! Each mutating engine call commits under one fresh epoch, so a whole
//! ingest batch, range delete, or migration publish becomes visible
//! atomically. A snapshot pins the committed epoch at open time and
//! evaluates [`visible`] against it; the *latest* view passes
//! [`LATEST`] and sees exactly the live set.
//!
//! Dead versions are retained until every snapshot that could still
//! read them has closed — [`SnapshotTracker`] keeps the open-pin
//! multiset and yields the reclamation floor. The rule, spelled out in
//! docs/ARCHITECTURE.md §9:
//!
//! * a record dead at epoch `D` is readable by snapshots pinned at
//!   `at < D`;
//! * therefore it is reclaimable once the oldest open pin is `>= D`
//!   (or no snapshot is open at all).
//!
//! This module is pure in-memory bookkeeping (no I/O, no threads), so
//! the Miri CI job runs its tests in full.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Commit epoch. Epoch 0 is the recovered/initial state; every mutating
/// engine call commits at the next epoch.
pub type Epoch = u64;

/// `dead` stamp of a live record or posting — never reached by real
/// commits (an engine would need `u64::MAX` batches).
pub const LIVE: Epoch = u64::MAX;

/// Read epoch meaning "the latest committed state" — sees exactly the
/// records whose `dead` stamp is [`LIVE`].
pub const LATEST: Epoch = u64::MAX;

/// The visibility rule: is a version stamped `[born, dead)` readable at
/// epoch `at`?
///
/// * `at == LATEST`: the version is visible iff it is still live.
/// * otherwise: visible iff it was born at or before `at` and died
///   strictly after it — a version killed *at* epoch `e` is invisible
///   to the snapshot pinned at `e` only if `e >= dead`; the pin taken
///   *before* the kill (`at < dead`) still reads it.
#[inline]
pub fn visible(born: Epoch, dead: Epoch, at: Epoch) -> bool {
    if at == LATEST {
        dead == LIVE
    } else {
        born <= at && at < dead
    }
}

/// Multiset of open snapshot pins, keyed by pinned epoch.
///
/// Shared by the writer (reclamation floor, retention expiry) and every
/// reader thread (pin on snapshot open, unpin on cursor close/drain).
/// The lock is taken for a map probe only — never across I/O.
#[derive(Default)]
pub struct SnapshotTracker {
    pins: Mutex<BTreeMap<Epoch, usize>>,
}

impl SnapshotTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin `at`: the caller promises to [`SnapshotTracker::unpin`] it
    /// exactly once (the [`super::engine::Snapshot`] handle does this
    /// on drop).
    pub fn pin(&self, at: Epoch) {
        let mut pins = match self.pins.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        *pins.entry(at).or_insert(0) += 1;
    }

    /// Release one pin of `at`. Unknown epochs are tolerated (a poisoned
    /// panic unwind may race a drop); the multiset never underflows.
    pub fn unpin(&self, at: Epoch) {
        let mut pins = match self.pins.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some(n) = pins.get_mut(&at) {
            *n -= 1;
            if *n == 0 {
                pins.remove(&at);
            }
        }
    }

    /// Oldest open pin at or above `floor` — pins below the retention
    /// floor are *expired* (their snapshots fail with a retryable error
    /// on next use) and no longer hold reclamation back.
    pub fn oldest_open(&self, floor: Epoch) -> Option<Epoch> {
        let pins = match self.pins.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        pins.range(floor..).next().map(|(e, _)| *e)
    }

    /// Number of open pins (the `shard.snapshots_open` gauge).
    pub fn open_count(&self) -> u64 {
        let pins = match self.pins.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        pins.values().map(|n| *n as u64).sum()
    }

    /// The reclamation floor given the committed epoch and the retention
    /// knob: every version dead at or below the returned epoch is
    /// unreachable by any open (non-expired) or future snapshot.
    ///
    /// `retention == 0` keeps versions for as long as any snapshot is
    /// open (unbounded); `retention = R` additionally expires pins older
    /// than `epoch - R`, bounding how far behind the writer a reader
    /// can hold the garbage queue.
    pub fn reclaim_floor(&self, epoch: Epoch, retention: u64) -> Epoch {
        let expiry = if retention == 0 { 0 } else { epoch.saturating_sub(retention) };
        match self.oldest_open(expiry) {
            Some(oldest) => oldest.max(expiry),
            None => epoch.max(expiry),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_versions_visible_at_any_snapshot_after_birth() {
        assert!(visible(0, LIVE, 0));
        assert!(visible(3, LIVE, 3));
        assert!(visible(3, LIVE, 1000));
        assert!(!visible(3, LIVE, 2), "not yet born at the pinned epoch");
        assert!(visible(3, LIVE, LATEST));
    }

    #[test]
    fn dead_versions_visible_only_before_their_death_epoch() {
        // Born at 2, killed at 5: snapshots 2..=4 read it, 5+ do not.
        for at in 2..5 {
            assert!(visible(2, 5, at), "at={at}");
        }
        assert!(!visible(2, 5, 5));
        assert!(!visible(2, 5, 100));
        assert!(!visible(2, 5, 1), "pre-birth snapshot");
        assert!(!visible(2, 5, LATEST), "latest never sees dead versions");
    }

    #[test]
    fn born_and_killed_in_one_epoch_is_never_visible() {
        // Replay uses epoch 0 for both stamps: insert+remove nets out.
        assert!(!visible(0, 0, 0));
        assert!(!visible(7, 7, 7));
        assert!(!visible(7, 7, LATEST));
    }

    #[test]
    fn tracker_pins_unpin_and_count() {
        let t = SnapshotTracker::new();
        assert_eq!(t.open_count(), 0);
        assert_eq!(t.oldest_open(0), None);
        t.pin(5);
        t.pin(5);
        t.pin(9);
        assert_eq!(t.open_count(), 3);
        assert_eq!(t.oldest_open(0), Some(5));
        t.unpin(5);
        assert_eq!(t.oldest_open(0), Some(5), "one pin of 5 remains");
        t.unpin(5);
        assert_eq!(t.oldest_open(0), Some(9));
        t.unpin(9);
        assert_eq!(t.open_count(), 0);
        // Unpinning an unknown epoch must not underflow or panic.
        t.unpin(9);
        assert_eq!(t.open_count(), 0);
    }

    #[test]
    fn reclaim_floor_tracks_oldest_open_pin() {
        let t = SnapshotTracker::new();
        // No snapshots: everything up to the committed epoch reclaims.
        assert_eq!(t.reclaim_floor(10, 0), 10);
        t.pin(4);
        t.pin(8);
        assert_eq!(t.reclaim_floor(10, 0), 4);
        t.unpin(4);
        assert_eq!(t.reclaim_floor(10, 0), 8);
        t.unpin(8);
        assert_eq!(t.reclaim_floor(10, 0), 10);
    }

    #[test]
    fn retention_expires_stale_pins() {
        let t = SnapshotTracker::new();
        t.pin(2);
        // Unbounded retention: the stale pin holds the floor at 2.
        assert_eq!(t.reclaim_floor(100, 0), 2);
        // Retention 10: pins below 90 expire; the floor advances.
        assert_eq!(t.reclaim_floor(100, 10), 90);
        // A fresh pin above the expiry still holds the floor.
        t.pin(95);
        assert_eq!(t.reclaim_floor(100, 10), 95);
        // The expired pin alone never drags the floor back down.
        t.unpin(95);
        assert_eq!(t.reclaim_floor(100, 10), 90);
        t.unpin(2);
    }

    #[test]
    fn reclaim_floor_with_retention_and_no_pins_is_the_epoch() {
        let t = SnapshotTracker::new();
        assert_eq!(t.reclaim_floor(100, 10), 100);
        assert_eq!(t.reclaim_floor(5, 10), 5, "saturating expiry below retention");
    }
}
