//! Concurrent conditional-find serving with a latency report — the live
//! analogue of Figure 3's workload on one machine.
//!
//! ```sh
//! cargo run --release --example query_serving
//! ```

use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::util::fmt::{human_duration_ns, markdown_table};
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::{IngestDriver, QueryDriver};

fn main() -> anyhow::Result<()> {
    let kernels = Kernels::load_or_fallback("artifacts");
    println!("kernel backend: {:?}", kernels.backend());
    let cluster = Cluster::start(
        ClusterSpec::small(3, 2),
        |sid| Ok(Box::new(LocalDir::temp(&format!("qserve-{sid}"))?)),
        kernels,
        Registry::new(),
    )?;
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).map_err(anyhow::Error::msg)?;
    client.create_index(IndexSpec::single("node_id")).map_err(anyhow::Error::msg)?;

    let wl = WorkloadConfig {
        monitored_nodes: 128,
        metrics_per_doc: 20,
        days: 45.0 / 1440.0,
        query_jobs: 48,
        ..Default::default()
    };
    let gen = OvisGenerator::new(wl.clone());
    println!("ingesting {} docs...", gen.total_docs());
    IngestDriver::new(gen, 1000, 4).run(&client)?;

    // Sweep concurrency like the paper ("servicing more concurrent
    // queries" as clusters grow).
    let mut rows = Vec::new();
    for conc in [1usize, 4, 8, 16] {
        let report = QueryDriver::new(generate_jobs(&wl), conc).run(&client)?;
        anyhow::ensure!(report.count_mismatches == 0, "bad counts at conc {conc}");
        rows.push(vec![
            conc.to_string(),
            report.queries.to_string(),
            format!("{:.1}", report.queries_per_sec()),
            human_duration_ns(report.latency.p50()),
            human_duration_ns(report.latency.p95()),
            human_duration_ns(report.latency.p99()),
        ]);
        println!("concurrency {conc}: {}", report.summary());
    }
    println!("\n## Live conditional-find latency vs concurrency\n");
    print!(
        "{}",
        markdown_table(&["concurrency", "finds", "finds/s", "p50", "p95", "p99"], &rows)
    );
    cluster.shutdown();
    Ok(())
}
