//! `pallas-lint` — run the in-tree protocol-invariant lints
//! (ARCHITECTURE.md §8) over a repository checkout.
//!
//! Usage: `pallas-lint [REPO_ROOT]`. With no argument the repo root is
//! found by walking up from the current directory to the first parent
//! containing `rust/Cargo.toml`. Exit status: 0 clean, 1 violations,
//! 2 usage/IO error.

#![deny(unsafe_code)]

use std::path::{Path, PathBuf};

use hpcstore::analysis::{run_all, SourceTree};

fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(dir) = cur {
        if dir.join("rust/Cargo.toml").is_file() {
            return Some(dir);
        }
        cur = dir.parent().map(Path::to_path_buf);
    }
    None
}

fn main() {
    let arg = std::env::args().nth(1);
    let root = match &arg {
        Some(p) => PathBuf::from(p),
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|e| {
                eprintln!("pallas-lint: cannot read current dir: {e}");
                std::process::exit(2);
            });
            match find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "pallas-lint: no rust/Cargo.toml above {} — pass the repo root explicitly",
                        cwd.display()
                    );
                    std::process::exit(2);
                }
            }
        }
    };
    let tree = match SourceTree::from_repo_root(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("pallas-lint: failed to read {}: {e}", root.display());
            std::process::exit(2);
        }
    };
    let violations = run_all(&tree);
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("pallas-lint: clean ({} root)", root.display());
    } else {
        println!("pallas-lint: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}
