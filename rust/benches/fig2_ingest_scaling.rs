//! F2 — regenerate Figure 2: ingest rate vs cluster size (32/64/128/256
//! nodes, Table-1 workloads).
//!
//! Paper: "MongoDB scales close to linear between 32, 64, and 128 nodes.
//! We are still investigating the limitations at 256 nodes." The DES
//! (calibrated from the live implementation; `hpcstore calibrate`)
//! reproduces that shape: near-ideal speedup through 128, sub-linear at
//! 256 with the config-server metadata churn as the binding resource.
//!
//! A live small-scale cross-check (1/2/4 shards, real threads) prints
//! alongside unless `--quick`.

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_count;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::IngestDriver;

fn main() {
    let raw = CostModel::load_or_default(std::path::Path::new("artifacts"));
    let cost = raw.clone().with_network_floor();

    let mut report = Report::new("Figure 2 — ingest scaling (DES, calibrated service times + TCP-class metadata RPC floor)");
    report.set_custom(
        ["nodes", "shards", "client PEs", "docs", "docs/s", "speedup", "ideal", "shard util", "config util", "splits"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let mut base: Option<(f64, f64)> = None;
    for nodes in [32u32, 64, 128, 256] {
        let spec = SimSpec::paper_preset(nodes, cost.clone()).unwrap();
        let r = ClusterSim::new(spec).run();
        let (b_dps, b_shards) = *base.get_or_insert((r.docs_per_sec, r.shards as f64));
        report.add_row(vec![
            nodes.to_string(),
            r.shards.to_string(),
            r.client_pes.to_string(),
            human_count(r.docs),
            human_count(r.docs_per_sec as u64),
            format!("{:.2}x", r.docs_per_sec / b_dps),
            format!("{:.2}x", r.shards as f64 / b_shards),
            format!("{:.0}%", r.util_shard * 100.0),
            format!("{:.0}%", r.util_config * 100.0),
            r.splits.to_string(),
        ]);
    }
    report.print();
    println!("\npaper: close-to-linear 32→64→128, degradation at 256 — shape reproduced\n");

    // Sensitivity: the 256-node shortfall is driven by config metadata
    // RPC cost, which our in-process transport substitution makes
    // artificially cheap. Sweep it from the measured mpsc value to
    // TCP-class figures.
    let mut sens = Report::new("F2 sensitivity — 256-node efficiency vs metadata RPC cost");
    sens.set_custom(
        ["refresh_fixed", "docs/s", "speedup vs 32", "config util"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let spec32 = SimSpec::paper_preset(32, cost.clone()).unwrap();
    let base32 = ClusterSim::new(spec32).run().docs_per_sec;
    for (label, ns) in [
        ("measured (in-process mpsc)", raw.refresh_fixed_ns),
        ("60 µs (TCP-class floor)", 60_000.0),
        ("250 µs (loaded config server)", 250_000.0),
        ("1 ms (production mongos refresh)", 1_000_000.0),
    ] {
        let mut c = raw.clone();
        c.refresh_fixed_ns = ns;
        let spec = SimSpec::paper_preset(256, c).unwrap();
        let r = ClusterSim::new(spec).run();
        sens.add_row(vec![
            label.to_string(),
            human_count(r.docs_per_sec as u64),
            format!("{:.2}x (ideal 9.0x)", r.docs_per_sec / base32),
            format!("{:.0}%", r.util_config * 100.0),
        ]);
    }
    sens.print();
    println!();

    // Batch-size axis: the bulk-ingest pipeline swept over insertMany
    // batch sizes on a fixed cluster shape, the way the paper sweeps
    // cluster shape on a fixed batch. Per-message overhead (router hop,
    // route-kernel invocation, per-frame journaling) amortizes with the
    // batch; ≥ 2x is expected by batch 64 vs batch 1.
    let mut axis = Report::new("F2 batch axis — ingest vs insertMany batch size (DES, 32 nodes)");
    axis.set_custom(
        ["batch", "docs/s", "speedup vs batch=1"].iter().map(|s| s.to_string()).collect(),
    );
    let mut b1 = None;
    for batch in [1usize, 16, 64, 256, 1000, 4096] {
        let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
        spec.batch = batch;
        let r = ClusterSim::new(spec).run();
        let base = *b1.get_or_insert(r.docs_per_sec);
        axis.add_row(vec![
            batch.to_string(),
            human_count(r.docs_per_sec as u64),
            format!("{:.2}x", r.docs_per_sec / base),
        ]);
    }
    axis.print();
    println!();

    // Lifecycle axis: checkpoint compaction keeps the on-disk journal
    // bounded; this sweep shows what that bound costs in ingest rate
    // (snapshot serialization + OST traffic per compaction).
    let mut life = Report::new("F2 lifecycle — ingest vs compaction threshold (DES, 32 nodes)");
    life.set_custom(
        ["checkpoint-bytes", "docs/s", "vs unbounded", "compactions"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let mut unbounded = None;
    for (label, threshold) in [
        ("off (unbounded journal)", 0u64),
        ("256 MiB", 256 * 1024 * 1024),
        ("64 MiB", 64 * 1024 * 1024),
        ("16 MiB", 16 * 1024 * 1024),
    ] {
        let mut spec = SimSpec::paper_preset(32, cost.clone()).unwrap();
        spec.checkpoint_bytes = threshold;
        let r = ClusterSim::new(spec).run();
        let base = *unbounded.get_or_insert(r.docs_per_sec);
        life.add_row(vec![
            label.to_string(),
            human_count(r.docs_per_sec as u64),
            format!("{:.2}x", r.docs_per_sec / base),
            r.checkpoints.to_string(),
        ]);
    }
    life.print();
    println!();

    if quick_mode() {
        return;
    }

    // Live batch axis: real cluster threads, fixed 2 shards / 2 routers
    // / 4 PEs, batch swept — shows the group-commit win end-to-end
    // (one journal frame + one sync per batch instead of per document).
    let live_kernels = Kernels::load_or_fallback("artifacts");
    let mut lbatch = Report::new("F2 batch axis — live mini-cluster (2 shards, 4 PEs)");
    lbatch.set_custom(
        ["batch", "docs", "docs/s", "speedup vs batch=1", "group commits"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let mut lbase = None;
    for batch in [1usize, 64, 512] {
        let metrics = Registry::new();
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            move |sid| Ok(Box::new(LocalDir::temp(&format!("f2b-{batch}-{sid}"))?)),
            live_kernels.clone(),
            metrics.clone(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::single("ts")).unwrap();
        client.create_index(IndexSpec::single("node_id")).unwrap();
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 64,
            metrics_per_doc: 75,
            days: 32.0 / 1440.0, // 2048 docs
            ..Default::default()
        });
        let rep = IngestDriver::new(gen, batch, 4).run(&client).unwrap();
        let b = *lbase.get_or_insert(rep.docs_per_sec);
        lbatch.add_row(vec![
            batch.to_string(),
            rep.docs.to_string(),
            format!("{:.0}", rep.docs_per_sec),
            format!("{:.2}x", rep.docs_per_sec / b),
            metrics.counter("shard.group_commits").get().to_string(),
        ]);
        cluster.shutdown();
    }
    lbatch.print();
    println!();
    // Live cross-check: real cluster threads at laptop scale.
    let kernels = Kernels::load_or_fallback("artifacts");
    let mut live = Report::new("Figure 2 cross-check — live mini-clusters (one machine, CPU-bound)");
    live.set_custom(
        ["shards", "PEs", "docs", "docs/s", "speedup"].iter().map(|s| s.to_string()).collect(),
    );
    let mut base = None;
    for (shards, pes) in [(1u32, 2usize), (2, 4), (4, 8)] {
        let cluster = Cluster::start(
            ClusterSpec::small(shards, shards.max(1)),
            move |sid| Ok(Box::new(LocalDir::temp(&format!("f2-{shards}-{sid}"))?)),
            kernels.clone(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::single("ts")).unwrap();
        client.create_index(IndexSpec::single("node_id")).unwrap();
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 128,
            metrics_per_doc: 75,
            days: 8.0 / 1440.0,
            ..Default::default()
        });
        let rep = IngestDriver::new(gen, 500, pes).run(&client).unwrap();
        let b = *base.get_or_insert(rep.docs_per_sec);
        live.add_row(vec![
            shards.to_string(),
            pes.to_string(),
            rep.docs.to_string(),
            format!("{:.0}", rep.docs_per_sec),
            format!("{:.2}x", rep.docs_per_sec / b),
        ]);
        cluster.shutdown();
    }
    live.print();
}
