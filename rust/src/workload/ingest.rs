//! Ingest driver — the paper's `insertMany(ordered=False)` workload.
//!
//! "Ingest is run with 4 processing elements per node, thus 64
//! insertMany will be processed concurrently across 7 MongoDB routers."
//! Each PE thread takes a disjoint slice of the corpus (by document
//! index), builds `insert_batch`-sized document lists, and calls
//! `insert_many` on its pinned router.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::ovis::OvisGenerator;
use crate::metrics::Histogram;
use crate::mongo::client::MongoClient;

/// Outcome of an ingest run.
#[derive(Clone, Debug)]
pub struct IngestReport {
    pub docs: u64,
    pub batches: u64,
    pub rerouted: u64,
    pub wall_ns: u64,
    pub docs_per_sec: f64,
    /// Per-batch insertMany latency.
    pub batch_latency: Histogram,
    pub pes: usize,
}

impl IngestReport {
    pub fn summary(&self) -> String {
        format!(
            "{} docs in {:.2}s over {} PEs → {:.0} docs/s (batch p50 {}, p95 {})",
            self.docs,
            self.wall_ns as f64 / 1e9,
            self.pes,
            self.docs_per_sec,
            crate::util::fmt::human_duration_ns(self.batch_latency.p50()),
            crate::util::fmt::human_duration_ns(self.batch_latency.p95()),
        )
    }
}

/// Ingest driver.
pub struct IngestDriver {
    pub gen: OvisGenerator,
    pub batch: usize,
    pub pes: usize,
    /// Send batches through the router's ingest buffer
    /// ([`MongoClient::insert_buffered`]) so the router group-commits
    /// across PEs, instead of one direct `insertMany` per batch.
    pub buffered: bool,
}

impl IngestDriver {
    pub fn new(gen: OvisGenerator, batch: usize, pes: usize) -> Self {
        Self { gen, batch, pes: pes.max(1), buffered: false }
    }

    /// Toggle the router-buffered ingest path.
    pub fn buffered(mut self, on: bool) -> Self {
        self.buffered = on;
        self
    }

    /// Run the full corpus through `client` (each PE pins a router like
    /// the paper's layout). Returns the aggregate report.
    pub fn run(&self, client: &MongoClient) -> Result<IngestReport> {
        let total = self.gen.total_docs();
        let gen = Arc::new(self.gen.clone());
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for pe in 0..self.pes {
            let gen = gen.clone();
            let client = client.pinned(pe);
            let batch = self.batch;
            let buffered = self.buffered;
            let (lo, hi) = slice_bounds(total, self.pes, pe);
            handles.push(std::thread::spawn(move || -> Result<(u64, u64, u64, Histogram)> {
                let mut lat = Histogram::new();
                let mut docs = 0u64;
                let mut batches = 0u64;
                let mut rerouted = 0u64;
                let mut i = lo;
                while i < hi {
                    let n = batch.min((hi - i) as usize);
                    let list: Vec<_> = (i..i + n as u64).map(|k| gen.doc_at(k)).collect();
                    let t = Instant::now();
                    let rep = if buffered {
                        client
                            .insert_buffered(list)
                            .map_err(|e| anyhow::anyhow!("insert_buffered: {e}"))?
                    } else {
                        client
                            .insert_many(list)
                            .map_err(|e| anyhow::anyhow!("insert_many: {e}"))?
                    };
                    lat.record(t.elapsed().as_nanos() as u64);
                    docs += rep.inserted as u64;
                    rerouted += rep.rerouted as u64;
                    batches += 1;
                    i += n as u64;
                }
                Ok((docs, batches, rerouted, lat))
            }));
        }
        let mut docs = 0;
        let mut batches = 0;
        let mut rerouted = 0;
        let mut lat = Histogram::new();
        for h in handles {
            let (d, b, r, l) = h.join().expect("ingest PE panicked")?;
            docs += d;
            batches += b;
            rerouted += r;
            lat.merge(&l);
        }
        let wall_ns = t0.elapsed().as_nanos() as u64;
        Ok(IngestReport {
            docs,
            batches,
            rerouted,
            wall_ns,
            docs_per_sec: docs as f64 * 1e9 / wall_ns.max(1) as f64,
            batch_latency: lat,
            pes: self.pes,
        })
    }
}

/// Document-index range `[lo, hi)` for PE `pe` of `pes`.
pub fn slice_bounds(total: u64, pes: usize, pe: usize) -> (u64, u64) {
    let pes = pes as u64;
    let pe = pe as u64;
    let base = total / pes;
    let extra = total % pes;
    let lo = pe * base + pe.min(extra);
    let len = base + if pe < extra { 1 } else { 0 };
    (lo, lo + len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::metrics::Registry;
    use crate::mongo::cluster::{Cluster, ClusterSpec};
    use crate::mongo::query::Filter;
    use crate::mongo::storage::LocalDir;
    use crate::runtime::Kernels;

    #[test]
    fn slices_partition_exactly() {
        for (total, pes) in [(100u64, 7usize), (13, 4), (5, 8), (0, 3)] {
            let mut covered = 0;
            let mut prev_hi = 0;
            for pe in 0..pes {
                let (lo, hi) = slice_bounds(total, pes, pe);
                assert_eq!(lo, prev_hi, "gap at pe {pe}");
                covered += hi - lo;
                prev_hi = hi;
            }
            assert_eq!(covered, total, "total={total} pes={pes}");
            assert_eq!(prev_hi, total);
        }
    }

    #[test]
    fn ingest_drives_full_corpus() {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            |sid| Ok(Box::new(LocalDir::temp(&format!("ing-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 8,
            metrics_per_doc: 5,
            days: 10.0 / 1440.0, // 10 minutes → 80 docs
            ..Default::default()
        });
        let driver = IngestDriver::new(gen.clone(), 16, 3);
        let report = driver.run(&cluster.client()).unwrap();
        assert_eq!(report.docs, 80);
        assert!(report.batches >= 5);
        assert!(report.docs_per_sec > 0.0);
        assert_eq!(
            cluster.client().count_documents(Filter::True).unwrap(),
            80
        );
        cluster.shutdown();
    }

    #[test]
    fn buffered_ingest_drives_full_corpus() {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            |sid| Ok(Box::new(LocalDir::temp(&format!("ingb-{sid}"))?)),
            Kernels::fallback(),
            Registry::new(),
        )
        .unwrap();
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 8,
            metrics_per_doc: 5,
            days: 10.0 / 1440.0, // 10 minutes → 80 docs
            ..Default::default()
        });
        let driver = IngestDriver::new(gen.clone(), 16, 3).buffered(true);
        let report = driver.run(&cluster.client()).unwrap();
        assert_eq!(report.docs, 80, "router buffer must ack every doc");
        assert_eq!(
            cluster.client().count_documents(Filter::True).unwrap(),
            80
        );
        // The routers actually flushed through the buffer path.
        assert!(cluster.metrics().counter("router.ingest_flushes").get() > 0);
        cluster.shutdown();
    }
}
