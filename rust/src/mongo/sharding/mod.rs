//! Sharding: shard keys, chunks, the config-server metadata state, the
//! balancer policy, and the streaming chunk-migration protocol.

pub mod balancer;
pub mod chunk;
pub mod config_server;
pub mod migration;

pub use balancer::{plan_moves, BalancerPolicy, ShardLoad};
pub use chunk::{ChunkMap, ShardKey};
pub use config_server::ConfigState;
pub use migration::{MState, MigrationOutcome};
