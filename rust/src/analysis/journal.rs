//! Rule: **journal-op exhaustiveness** (invariants I1/I2).
//!
//! The journal format is append-only and replayed after every
//! scheduler kill, so an op code that is encoded but not replayed is
//! silent data loss, and one that is never crash-tested is an
//! unverified recovery path. For every `const OP_X: u8` declared in
//! `storage/engine.rs` / `storage/delta.rs` this rule requires, in
//! non-test code:
//!
//! 1. an **encode site** — `journal_record(OP_X, ...)` in the same
//!    file (an op nobody writes is dead protocol surface);
//! 2. a **replay arm** — `OP_X =>` in a match (recovery handles it);
//! 3. a **crash-test marker** — a `// lint: journal-op(OP_X)` comment
//!    in some `rust/tests/*.rs`, placed on the test that kills and
//!    replays that frame kind.
//!
//! Markers naming an op that no longer exists are also flagged, so the
//! test link rots loudly instead of silently.

use super::lexer::TokKind;
use super::{SourceTree, Violation};

const RULE: &str = "journal-op";
const OP_FILES: &[&str] =
    &["rust/src/mongo/storage/engine.rs", "rust/src/mongo/storage/delta.rs"];

pub fn check(tree: &SourceTree) -> Vec<Violation> {
    let mut out = Vec::new();
    // op name -> (file, decl line)
    let mut ops: Vec<(String, String, usize)> = Vec::new();
    for &path in OP_FILES {
        let Some(f) = tree.lexed(path) else { continue };
        let t = &f.tokens;
        for i in 0..t.len() {
            let is_op_decl = t[i].text == "const"
                && t.get(i + 1).is_some_and(|n| {
                    n.kind == TokKind::Ident && n.text.starts_with("OP_")
                })
                && t.get(i + 2).is_some_and(|c| c.text == ":")
                && t.get(i + 3).is_some_and(|u| u.text == "u8");
            if is_op_decl && !f.is_test_line(t[i].line) {
                ops.push((t[i + 1].text.clone(), path.to_string(), t[i + 1].line));
            }
        }
    }

    for (op, path, decl_line) in &ops {
        let f = tree.lexed(path).expect("op file was lexed above");
        let t = &f.tokens;
        let mut encoded = false;
        let mut replayed = false;
        for i in 0..t.len() {
            if f.is_test_line(t[i].line) {
                continue;
            }
            if t[i].text == "journal_record"
                && t.get(i + 1).is_some_and(|p| p.text == "(")
                && t.get(i + 2).is_some_and(|o| o.text == *op)
            {
                encoded = true;
            }
            if t[i].text == *op && t.get(i + 1).is_some_and(|a| a.text == "=>") {
                replayed = true;
            }
        }
        if !encoded {
            out.push(Violation {
                file: path.clone(),
                line: *decl_line,
                rule: RULE,
                message: format!("journal op {op} is declared but never encoded via journal_record({op}, ..)"),
            });
        }
        if !replayed {
            out.push(Violation {
                file: path.clone(),
                line: *decl_line,
                rule: RULE,
                message: format!("journal op {op} has no replay arm ({op} => ...) — recovery would bail on frames it wrote"),
            });
        }
        let tested = tree.paths_under("rust/tests/", ".rs").any(|tp| {
            tree.lexed(tp).is_some_and(|tf| {
                tf.comments.iter().any(|c| c.text.contains(&format!("lint: journal-op({op})")))
            })
        });
        if !tested {
            out.push(Violation {
                file: path.clone(),
                line: *decl_line,
                rule: RULE,
                message: format!("journal op {op} has no crash test — add a `// lint: journal-op({op})` marker on the rust/tests/ test that kills and replays it"),
            });
        }
    }

    // Stale markers: a test claims coverage of an op that is gone.
    for tp in tree.paths_under("rust/tests/", ".rs") {
        let Some(tf) = tree.lexed(tp) else { continue };
        for c in &tf.comments {
            let Some(rest) = c.text.split("lint: journal-op(").nth(1) else { continue };
            let Some(name) = rest.split(')').next() else { continue };
            if !ops.iter().any(|(op, _, _)| op == name) {
                out.push(Violation {
                    file: tp.to_string(),
                    line: c.line,
                    rule: RULE,
                    message: format!("crash-test marker references unknown journal op {name}"),
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(body: &str) -> String {
        format!("const OP_A: u8 = 1;\nconst OP_B: u8 = 2;\n{body}")
    }

    fn tree(engine_body: &str, test_src: &str) -> SourceTree {
        let mut t = SourceTree::new();
        t.add("rust/src/mongo/storage/engine.rs", &engine(engine_body));
        t.add("rust/tests/crash.rs", test_src);
        t
    }

    #[test]
    fn complete_op_passes() {
        let t = tree(
            "fn w(&mut self) { self.journal_record(OP_A, c, &p); self.journal_record(OP_B, c, &p); }\nfn r(op: u8) { match op { OP_A => {} OP_B => {} _ => {} } }",
            "// lint: journal-op(OP_A)\n// lint: journal-op(OP_B)\nfn t() {}",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn missing_replay_arm_is_flagged_with_decl_line() {
        let t = tree(
            "fn w(&mut self) { self.journal_record(OP_A, c, &p); self.journal_record(OP_B, c, &p); }\nfn r(op: u8) { match op { OP_A => {} _ => {} } }",
            "// lint: journal-op(OP_A)\n// lint: journal-op(OP_B)\nfn t() {}",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("OP_B") && v[0].message.contains("replay"));
        assert_eq!(v[0].line, 2); // the OP_B declaration
    }

    #[test]
    fn missing_encode_and_test_marker_are_flagged() {
        let t = tree(
            "fn w(&mut self) { self.journal_record(OP_A, c, &p); }\nfn r(op: u8) { match op { OP_A => {} OP_B => {} _ => {} } }",
            "// lint: journal-op(OP_A)\nfn t() {}",
        );
        let v = check(&t);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().any(|x| x.message.contains("never encoded")));
        assert!(v.iter().any(|x| x.message.contains("no crash test")));
    }

    #[test]
    fn stale_marker_is_flagged() {
        let t = tree(
            "fn w(&mut self) { self.journal_record(OP_A, c, &p); self.journal_record(OP_B, c, &p); }\nfn r(op: u8) { match op { OP_A => {} OP_B => {} _ => {} } }",
            "// lint: journal-op(OP_A)\n// lint: journal-op(OP_B)\n// lint: journal-op(OP_GONE)\nfn t() {}",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("OP_GONE"));
        assert_eq!(v[0].file, "rust/tests/crash.rs");
    }

    #[test]
    fn test_module_ops_are_ignored() {
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/storage/engine.rs",
            "#[cfg(test)]\nmod tests {\n    const OP_FAKE: u8 = 9;\n}\n",
        );
        assert!(check(&t).is_empty());
    }

    #[test]
    fn atomic_multi_op_satisfied_by_a_failover_kill_window_marker() {
        // The replica-set path journals a data op and its oplog entry
        // as one OP_MULTI frame; the marker for it lives in the
        // failover kill-window tests, a *different* test file from the
        // storage crash suite — markers must be collected from every
        // rust/tests/*.rs file, not one blessed suite.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/storage/engine.rs",
            "const OP_MULTI: u8 = 7;\nfn w(&mut self) { self.journal_record(OP_MULTI, c, &p); }\nfn r(op: u8) { match op { OP_MULTI => {} _ => {} } }",
        );
        t.add("rust/tests/crash_matrix.rs", "// lint: journal-op(OP_MULTI)\nfn t() {}");
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn atomic_multi_op_without_a_kill_window_test_is_flagged() {
        // An atomic frame op that no crash test exercises is exactly
        // the gap the replica-set proof obligation closes: the frame's
        // all-or-nothing replay is a *claim* until a kill window pins
        // it.
        let mut t = SourceTree::new();
        t.add(
            "rust/src/mongo/storage/engine.rs",
            "const OP_MULTI: u8 = 7;\nfn w(&mut self) { self.journal_record(OP_MULTI, c, &p); }\nfn r(op: u8) { match op { OP_MULTI => {} _ => {} } }",
        );
        t.add("rust/tests/crash_matrix.rs", "fn t() {}");
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(
            v[0].message.contains("OP_MULTI") && v[0].message.contains("no crash test"),
            "{:?}",
            v[0]
        );
    }
}
